//! VBD (variance-based decomposition) study — the Fig. 20 experiment at
//! example scale, with the Sobol indices of Table 2.
//!
//! The paper's two-phase flow: the 8 parameters surviving the MOAT
//! screen feed a Saltelli design; the study executes with RTMA reuse on
//! PJRT workers and reports first-order and total-order Sobol indices.
//!
//! Usage: `cargo run --release --example vbd_study -- [n] [workers]`

use rtf_reuse::analysis::sobol_indices;
use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_pjrt, y_per_set, SampleInfo};
use rtf_reuse::merging::FineAlgorithm;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(6);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let cfg = StudyConfig {
        method: SaMethod::Vbd { n, k_active: 8 },
        algorithm: FineAlgorithm::Rtma(7),
        workers,
        ..StudyConfig::default()
    };
    println!("config: {}", cfg.describe());

    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    println!(
        "VBD design: {} evaluations; fine reuse {:.1}% (merge time {})",
        prepared.n_evals(),
        plan.fine_reuse() * 100.0,
        fmt_secs(plan.merge_time.as_secs_f64())
    );

    let outcome = run_pjrt(&cfg, &prepared, &plan).expect("run `make artifacts` first");
    println!("executed in {}", fmt_secs(outcome.wall.as_secs_f64()));

    let SampleInfo::Vbd(sample, active) = &prepared.sample else { unreachable!() };
    let y = y_per_set(&outcome.y, sample.sets.len(), cfg.tiles);
    let idx = sobol_indices(sample, &y);
    let mut t = Table::new(&["param", "S_i (main)", "ST_i (total)", "interaction"]);
    for (i, &p) in active.iter().enumerate() {
        t.row(&[
            prepared.space.params[p].name.clone(),
            format!("{:.4}", idx.first[i]),
            format!("{:.4}", idx.total[i]),
            format!("{:.4}", idx.interaction(i)),
        ]);
    }
    t.print("VBD Sobol indices (paper Table 2, right)");
    println!("output variance: {:.6}", idx.variance);
}
