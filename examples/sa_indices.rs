//! The paper's two-phase SA flow end-to-end (Table 2): a MOAT screen
//! over all 15 parameters followed by a VBD study over the surviving 8,
//! both executed for real on PJRT workers.
//!
//! Usage: `cargo run --release --example sa_indices -- [r] [n] [workers]`

use rtf_reuse::analysis::sobol_indices;
use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{moat_screen, prepare, prepare_with_active, run_pjrt, y_per_set, SampleInfo};
use rtf_reuse::merging::FineAlgorithm;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let r: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(3);
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);
    let workers: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(2);

    // ---- phase 1: MOAT screening over all 15 parameters ----------------
    let moat_cfg = StudyConfig {
        method: SaMethod::Moat { r },
        algorithm: FineAlgorithm::Rtma(7),
        workers,
        ..StudyConfig::default()
    };
    let moat = prepare(&moat_cfg);
    let moat_plan = moat.plan(&moat_cfg);
    let moat_out = run_pjrt(&moat_cfg, &moat, &moat_plan).expect("run `make artifacts` first");
    let (idx, top) = moat_screen(&moat_cfg, &moat, &moat_out.y, 8);

    let mut t = Table::new(&["param", "first-order effect", "mu*", "sigma"]);
    for p in 0..moat.space.dim() {
        t.row(&[
            moat.space.params[p].name.clone(),
            format!("{:+.4}", idx.mean[p]),
            format!("{:.4}", idx.mu_star[p]),
            format!("{:.4}", idx.sigma[p]),
        ]);
    }
    t.print(&format!(
        "phase 1 — MOAT, all 15 parameters, r={r} ({}, reuse {:.1}%)",
        fmt_secs(moat_out.wall.as_secs_f64()),
        moat_plan.fine_reuse() * 100.0
    ));
    let names: Vec<&str> = top.iter().map(|&p| moat.space.params[p].name.as_str()).collect();
    println!("surviving parameters: {}", names.join(", "));

    // ---- phase 2: VBD over the screened parameters ----------------------
    let vbd_cfg = StudyConfig {
        method: SaMethod::Vbd { n, k_active: top.len() },
        algorithm: FineAlgorithm::Rtma(7),
        workers,
        ..StudyConfig::default()
    };
    let vbd = prepare_with_active(&vbd_cfg, Some(top.clone()));
    let vbd_plan = vbd.plan(&vbd_cfg);
    let vbd_out = run_pjrt(&vbd_cfg, &vbd, &vbd_plan).expect("vbd execution");
    let SampleInfo::Vbd(sample, active) = &vbd.sample else { unreachable!() };
    let y = y_per_set(&vbd_out.y, sample.sets.len(), vbd_cfg.tiles);
    let s = sobol_indices(sample, &y);

    let mut t2 = Table::new(&["param", "S_i (main)", "ST_i (total)"]);
    for (i, &p) in active.iter().enumerate() {
        t2.row(&[
            vbd.space.params[p].name.clone(),
            format!("{:.4}", s.first[i]),
            format!("{:.4}", s.total[i]),
        ]);
    }
    t2.print(&format!(
        "phase 2 — VBD, top-{} parameters, n={n} ({}, reuse {:.1}%)",
        active.len(),
        fmt_secs(vbd_out.wall.as_secs_f64()),
        vbd_plan.fine_reuse() * 100.0
    ));
}
