//! Maximum fine-grain reuse potential per experiment generator
//! (paper Table 4): MC vs LHS vs QMC over VBD designs of growing sample
//! size. Reuse is measured *after* coarse-grain merging, with unbounded
//! bucket size — exactly the paper's "maximum computation reuse
//! potential".
//!
//! Usage: `cargo run --release --example reuse_potential`

use rtf_reuse::benchx::Table;
use rtf_reuse::config::{SaMethod, SamplerKind, StudyConfig};
use rtf_reuse::driver::prepare;
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};

fn main() {
    let mut t = Table::new(&["sampler", "n=200", "n=600", "n=1000"]);
    for kind in [SamplerKind::Mc, SamplerKind::Lhs, SamplerKind::Qmc] {
        let mut cells = vec![kind.name().to_string()];
        for n in [200usize, 600, 1000] {
            let cfg = StudyConfig {
                method: SaMethod::Vbd { n, k_active: 8 },
                sampler: kind,
                // one bucket per merge group = the maximum fine reuse
                algorithm: FineAlgorithm::Trtma(TrtmaOptions::new(1)),
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            cells.push(format!("{:.2}%", plan.fine_reuse() * 100.0));
        }
        t.row(&cells);
    }
    t.print("maximum fine-grain reuse potential, VBD — paper Table 4");
    println!(
        "(paper: 33–37% across all cells, QMC slightly below MC/LHS; the VBD design\n\
         reuses matrix rows across the A/B/AB_i blocks, which dominates the figure)"
    );
}
