//! Reuse potential, predicted and measured.
//!
//! Part 1 — maximum fine-grain reuse potential per experiment generator
//! (paper Table 4): MC vs LHS vs QMC over VBD designs of growing sample
//! size. Reuse is measured *after* coarse-grain merging, with unbounded
//! bucket size — exactly the paper's "maximum computation reuse
//! potential".
//!
//! Part 2 — measured *cross-study* reuse: a MOAT screen followed by a
//! wider MOAT study over the same tile, sharing one content-addressed
//! reuse cache. The second study's overlapping task prefixes are served
//! from the cache instead of re-executing; the report compares the
//! planning-time prediction (`prune_cached`) with the engine counters.
//!
//! Usage: `cargo run --release --example reuse_potential`

use rtf_reuse::benchx::Table;
use rtf_reuse::config::{CacheSettings, SaMethod, SamplerKind, StudyConfig};
use rtf_reuse::driver::{
    build_cache, make_inputs, prepare, prune_plan_with_inputs, run_pjrt_with_inputs,
};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};

fn main() {
    let mut t = Table::new(&["sampler", "n=200", "n=600", "n=1000"]);
    for kind in [SamplerKind::Mc, SamplerKind::Lhs, SamplerKind::Qmc] {
        let mut cells = vec![kind.name().to_string()];
        for n in [200usize, 600, 1000] {
            let cfg = StudyConfig {
                method: SaMethod::Vbd { n, k_active: 8 },
                sampler: kind,
                // one bucket per merge group = the maximum fine reuse
                algorithm: FineAlgorithm::Trtma(TrtmaOptions::new(1)),
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            cells.push(format!("{:.2}%", plan.fine_reuse() * 100.0));
        }
        t.row(&cells);
    }
    t.print("maximum fine-grain reuse potential, VBD — paper Table 4");
    println!(
        "(paper: 33–37% across all cells, QMC slightly below MC/LHS; the VBD design\n\
         reuses matrix rows across the A/B/AB_i blocks, which dominates the figure)"
    );

    // ---- measured cross-study reuse -------------------------------------
    let base = StudyConfig {
        method: SaMethod::Moat { r: 1 },
        algorithm: FineAlgorithm::Rtma(7),
        cache: CacheSettings { enabled: true, ..CacheSettings::default() },
        ..StudyConfig::default()
    };
    let cache = build_cache(&base).expect("cache enabled");

    let prepared1 = prepare(&base);
    let plan1 = prepared1.plan(&base);
    // both studies run on the same tile set: build the inputs once
    let inputs = make_inputs(&base, &prepared1).expect("study inputs");
    let out1 = run_pjrt_with_inputs(&base, &prepared1, &plan1, Some(cache.clone()), &inputs)
        .expect("study 1");
    let after1 = out1.cache.expect("cache stats");

    // the follow-up study widens the screen; its first trajectory repeats
    // the first study's design, so a large task-prefix overlap exists
    let wide = StudyConfig { method: SaMethod::Moat { r: 2 }, ..base.clone() };
    let prepared2 = prepare(&wide);
    let mut plan2 = prepared2.plan(&wide);
    let predicted = prune_plan_with_inputs(&prepared2, &mut plan2, &cache, &inputs);
    let out2 = run_pjrt_with_inputs(&wide, &prepared2, &plan2, Some(cache.clone()), &inputs)
        .expect("study 2");
    let after2 = out2.cache.expect("cache stats");

    let mut t = Table::new(&["metric", "study 1 (r=1)", "study 2 (r=2, warm)"]);
    t.row(&[
        "planned tasks".into(),
        plan1.tasks_to_execute().to_string(),
        (plan2.tasks_to_execute() + predicted).to_string(),
    ]);
    t.row(&["predicted cached".into(), "0".into(), predicted.to_string()]);
    t.row(&[
        "measured state hits".into(),
        (after1.hits + after1.disk_hits).to_string(),
        (after2.hits + after2.disk_hits - after1.hits - after1.disk_hits).to_string(),
    ]);
    t.row(&[
        "measured metric hits".into(),
        after1.metric_hits.to_string(),
        (after2.metric_hits - after1.metric_hits).to_string(),
    ]);
    t.row(&[
        "wall".into(),
        format!("{:.2?}", out1.wall),
        format!("{:.2?}", out2.wall),
    ]);
    t.print("measured cross-study reuse (shared content-addressed cache)");
    let mut s = Table::new(&["counter", "value"]);
    for (k, v) in after2.summary() {
        s.row(&[k, v.to_string()]);
    }
    s.print("cache counters (cumulative over both studies)");
}
