//! Quickstart: the full three-layer stack on one small SA study.
//!
//! Generates a MOAT screening design over the paper's 15-parameter
//! space, composes the two-level reuse plan (coarse compact graph +
//! fine-grain RTMA buckets), executes it for real on PJRT worker
//! threads running the AOT-compiled JAX/Pallas segmentation pipeline,
//! and prints the elementary-effects screen.
//!
//! Run with: `cargo run --release --example quickstart`
//! (requires `make artifacts` first).

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{moat_screen, prepare, run_pjrt};
use rtf_reuse::merging::FineAlgorithm;

fn main() {
    let cfg = StudyConfig {
        method: SaMethod::Moat { r: 2 }, // 2·(15+1) = 32 evaluations
        algorithm: FineAlgorithm::Rtma(7),
        workers: 2,
        ..StudyConfig::default()
    };
    println!("config: {}", cfg.describe());

    // 1. generate experiments + instantiate the hierarchical workflow
    let prepared = prepare(&cfg);
    println!(
        "generated {} parameter sets -> {} stage instances",
        prepared.sample.n_sets(),
        prepared.instances.len()
    );

    // 2. multi-level computation reuse
    let plan = prepared.plan(&cfg);
    println!(
        "reuse plan: {} coarse-saved stages, {:.1}% fine-grain task reuse, {} schedule units",
        plan.coarse_saved,
        plan.fine_reuse() * 100.0,
        plan.units.len()
    );

    // 3. real execution: PJRT workers running the AOT artifacts
    let outcome = run_pjrt(&cfg, &prepared, &plan).expect("run `make artifacts` first");
    println!(
        "executed in {} on {} workers (peak inter-stage state: {} KiB)",
        fmt_secs(outcome.wall.as_secs_f64()),
        cfg.workers,
        outcome.peak_state_bytes / 1024
    );

    // 4. the SA outcome: Morris elementary effects per parameter
    let (idx, top) = moat_screen(&cfg, &prepared, &outcome.y, 8);
    let mut t = Table::new(&["param", "mean EE", "mu*", "sigma"]);
    for p in 0..prepared.space.dim() {
        t.row(&[
            prepared.space.params[p].name.clone(),
            format!("{:+.4}", idx.mean[p]),
            format!("{:.4}", idx.mu_star[p]),
            format!("{:.4}", idx.sigma[p]),
        ]);
    }
    t.print("MOAT elementary effects");
    let names: Vec<&str> =
        top.iter().map(|&p| prepared.space.params[p].name.as_str()).collect();
    println!("parameters surviving the screen: {}", names.join(", "));
}
