//! MOAT study across all application versions — the Fig. 19 experiment
//! at example scale.
//!
//! Runs the same MOAT design through the five versions the paper
//! compares (No reuse / Stage level / Naïve / SCA / RTMA), executing for
//! real on PJRT workers, and prints makespan, merge-analysis time and
//! reuse per version. Shapes to expect (paper §4.2.1): every reuse
//! version beats "No reuse"; Naïve barely beats stage-level; SCA and
//! RTMA reach ~33% task reuse with RTMA's merge time far below SCA's.
//!
//! Usage: `cargo run --release --example moat_study -- [r] [workers]`

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_pjrt};
use rtf_reuse::merging::FineAlgorithm;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let r: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);

    let versions: [(&str, bool, FineAlgorithm); 5] = [
        ("no reuse", false, FineAlgorithm::None),
        ("stage level", true, FineAlgorithm::None),
        ("task level - naive", true, FineAlgorithm::Naive(7)),
        ("task level - sca", true, FineAlgorithm::Sca(7)),
        ("task level - rtma", true, FineAlgorithm::Rtma(7)),
    ];

    let mut t = Table::new(&["version", "makespan", "merge time", "fine reuse %", "speedup"]);
    let mut base = None;
    for (name, coarse, algo) in versions {
        let cfg = StudyConfig {
            method: SaMethod::Moat { r },
            coarse,
            algorithm: algo,
            workers,
            ..StudyConfig::default()
        };
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        let outcome = run_pjrt(&cfg, &prepared, &plan).expect("run `make artifacts` first");
        let wall = outcome.wall.as_secs_f64();
        let speedup = base.map(|b: f64| b / wall).unwrap_or(1.0);
        if base.is_none() {
            base = Some(wall);
        }
        t.row(&[
            name.to_string(),
            fmt_secs(wall),
            fmt_secs(plan.merge_time.as_secs_f64()),
            format!("{:.1}", plan.fine_reuse() * 100.0),
            format!("{speedup:.2}x"),
        ]);
    }
    t.print(&format!("MOAT study, r={r} ({} evals), {workers} workers — paper Fig. 19", r * 16));
}
