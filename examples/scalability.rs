//! Worker-scaling study: No-Reuse vs RTMA vs TRTMA over 8..256 workers
//! (paper Figs 22/23, Table 5) on the discrete-event cluster simulator.
//!
//! Shapes to expect: RTMA wins at low WP, collapses below NR once the
//! stages-per-worker ratio drops; TRTMA (MaxBuckets = 3×WP) tracks RTMA
//! at low WP and never falls below NR; its speedup over NR fades toward
//! 1.0 at WP 256.
//!
//! Usage: `cargo run --release --example scalability -- [sample-size]`

use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sample: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(1000);
    let r = sample / 16; // MOAT: sample = r(k+1), k = 15
    let model = default_cost_model();

    let mut t = Table::new(&[
        "WP", "NR", "RTMA", "TRTMA", "TRTMA/NR", "TRTMA reuse %", "S/W (RTMA)",
    ]);
    let mut prev: Option<(f64, f64, f64)> = None;
    let mut eff = Table::new(&["WP", "eff NR", "eff RTMA", "eff TRTMA"]);

    for wp in [8usize, 16, 32, 64, 128, 256] {
        let mk = |coarse: bool, algo: FineAlgorithm| {
            let cfg = StudyConfig {
                method: SaMethod::Moat { r },
                coarse,
                algorithm: algo,
                workers: wp,
                ..StudyConfig::default()
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            let opts = SimOptions::new(wp).with_cv(0.15, cfg.seed);
            let rep = run_sim(&prepared, &plan, &model, &opts);
            (rep, plan)
        };
        let (nr, _) = mk(true, FineAlgorithm::None);
        let (rtma, rtma_plan) = mk(true, FineAlgorithm::Rtma(10));
        let (trtma, trtma_plan) =
            mk(true, FineAlgorithm::Trtma(TrtmaOptions::new(3 * wp)));

        let seg_units = rtma_plan.units_of_stage(1).len();
        t.row(&[
            wp.to_string(),
            fmt_secs(nr.makespan),
            fmt_secs(rtma.makespan),
            fmt_secs(trtma.makespan),
            format!("{:.2}x", nr.makespan / trtma.makespan),
            format!("{:.2}", trtma_plan.fine_reuse() * 100.0),
            format!("{:.1}", seg_units as f64 / wp as f64),
        ]);
        if let Some((p_nr, p_rt, p_tb)) = prev {
            eff.row(&[
                wp.to_string(),
                format!("{:.2}", p_nr / (nr.makespan * 2.0)),
                format!("{:.2}", p_rt / (rtma.makespan * 2.0)),
                format!("{:.2}", p_tb / (trtma.makespan * 2.0)),
            ]);
        }
        prev = Some((nr.makespan, rtma.makespan, trtma.makespan));
    }
    t.print(&format!(
        "scalability, MOAT sample {} (r={r}) — paper Fig. 22 / Table 5",
        r * 16
    ));
    eff.print("parallel efficiency vs previous WP — paper Fig. 23");
}
