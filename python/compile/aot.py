"""AOT bridge: lower every workflow task to an HLO-text artifact.

``make artifacts`` runs this once; the Rust runtime then loads
``artifacts/<task>.hlo.txt`` through ``HloModuleProto::from_text_file`` and
executes them via the PJRT CPU client. Python never runs on the request
path.

Interchange format is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Usage (from ``python/``):

    python -m compile.aot --out-dir ../artifacts [--size 128]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_SIZE = 128


def to_hlo_text(lowered) -> str:
    """Convert a jax.jit(...).lower(...) result to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_task(name: str, size: int) -> str:
    """Lower one workflow task to HLO text with f32[size,size] planes."""
    img = jax.ShapeDtypeStruct((size, size), jnp.float32)
    par = jax.ShapeDtypeStruct((model.N_PARAMS,), jnp.float32)
    if name == "cmp":
        lowered = jax.jit(model.task_cmp).lower(img, img, img, img, par)
    else:
        lowered = jax.jit(model.TASK_FNS[name]).lower(img, img, img, par)
    return to_hlo_text(lowered)


def emit(out_dir: str, size: int, verbose: bool = True) -> dict:
    """Emit all task artifacts + manifest.json into ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    tasks = []
    for name in list(model.TASKS) + ["cmp"]:
        t0 = time.time()
        text = lower_task(name, size)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        tasks.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "image_inputs": 4 if name == "cmp" else 3,
                "param_inputs": model.N_PARAMS,
                "outputs": 1 if name == "cmp" else 3,
                "output_kind": "metrics3" if name == "cmp" else "planes",
                "sha256_16": digest,
            }
        )
        if verbose:
            print(f"  {name:>5}: {len(text):>9} chars  ({time.time() - t0:.2f}s)  {path}")
    manifest = {
        "height": size,
        "width": size,
        "n_params": model.N_PARAMS,
        "depth_levels": model.DEPTH_LEVELS,
        "task_order": list(model.TASKS),
        "compare_task": "cmp",
        "tasks": tasks,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"  manifest.json: {len(tasks)} tasks, {size}x{size} planes")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--size", type=int, default=DEFAULT_SIZE)
    args = ap.parse_args()
    emit(args.out_dir, args.size)


if __name__ == "__main__":
    main()
