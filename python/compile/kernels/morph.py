"""L1 Pallas kernels for the segmentation workflow's propagation hot spot.

The paper's most expensive operators (morphological reconstruction, hole
filling, connected components, seeded watershed) are all instances of the
*irregular wavefront propagation pattern* (IWPP, paper refs [37, 39]): a
per-pixel extremum over a 4-/8-connected neighborhood, iterated to fixpoint.
The authors run queue-based CPU/Phi implementations; on a TPU-shaped target
the data-dependent queue does not map, so we express one propagation *sweep*
as a dense 3x3 stencil kernel (VPU-friendly; see DESIGN.md
SSHardware-Adaptation) and iterate sweeps with `lax.while_loop` at L2.

All kernels run under ``interpret=True`` — the CPU PJRT client cannot
execute Mosaic custom-calls; real-TPU efficiency is estimated from the
BlockSpec VMEM footprint in EXPERIMENTS.md SSPerf.

Connectivity (4 vs 8) is a *runtime* scalar so a single AOT artifact serves
every parameter set (the paper's FH/RC/WConn parameters).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Toggle for A/B-testing kernels against the pure-jnp oracle at build time.
USE_PALLAS = os.environ.get("RTF_USE_PALLAS", "1") != "0"

_NEG = -jnp.inf
_POS = jnp.inf


def _shifted(x: jax.Array, pad_val) -> tuple[list[jax.Array], list[jax.Array]]:
    """The 4 orthogonal and 4 diagonal unit shifts of ``x``.

    Out-of-bounds pixels take ``pad_val`` (identity of the extremum), i.e.
    border pixels simply see fewer neighbors.
    """
    h, w = x.shape
    p = jnp.pad(x, 1, constant_values=pad_val)

    def sl(dy: int, dx: int) -> jax.Array:
        return jax.lax.dynamic_slice(p, (1 + dy, 1 + dx), (h, w))

    orth = [sl(-1, 0), sl(1, 0), sl(0, -1), sl(0, 1)]
    diag = [sl(-1, -1), sl(-1, 1), sl(1, -1), sl(1, 1)]
    return orth, diag


def _select_conn(x4: jax.Array, x8: jax.Array, conn: jax.Array) -> jax.Array:
    """Pick the 8-connected result when ``conn >= 8`` (conn is f32)."""
    return jnp.where(conn >= 8.0, x8, x4)


# ---------------------------------------------------------------------------
# kernel bodies (shared by max / min through the extremum fn)
# ---------------------------------------------------------------------------


def _nbr_extremum(x: jax.Array, conn: jax.Array, ext, pad_val) -> jax.Array:
    """Extremum of the (conn)-neighborhood *including* the center pixel."""
    orth, diag = _shifted(x, pad_val)
    e4 = functools.reduce(ext, orth, x)
    e8 = functools.reduce(ext, diag, e4)
    return _select_conn(e4, e8, conn)


def _nbr_max_kernel(x_ref, conn_ref, o_ref):
    o_ref[...] = _nbr_extremum(x_ref[...], conn_ref[0], jnp.maximum, _NEG)


def _nbr_min_kernel(x_ref, conn_ref, o_ref):
    o_ref[...] = _nbr_extremum(x_ref[...], conn_ref[0], jnp.minimum, _POS)


def _recon_sweep_kernel(marker_ref, mask_ref, conn_ref, o_ref):
    """One greyscale-reconstruction sweep: min(dilate(marker), mask).

    Fusing the dilation with the clamp keeps the whole sweep in VMEM: three
    HBM reads + one write per sweep instead of five (dilate out + clamp
    in/out), which is what double-buffered strip-mining would stream on TPU.
    """
    m = _nbr_extremum(marker_ref[...], conn_ref[0], jnp.maximum, _NEG)
    o_ref[...] = jnp.minimum(m, mask_ref[...])


def _label_sweep_kernel(lab_ref, active_ref, conn_ref, o_ref):
    """One label-propagation sweep for seeded growing / watershed levels.

    Unlabeled (0) active pixels adopt the *maximum* neighbor label; labeled
    or inactive pixels are unchanged. Labels only move 0 -> id, so iterating
    to fixpoint is monotone.
    """
    lab = lab_ref[...]
    act = active_ref[...]
    nbr = _nbr_extremum(lab, conn_ref[0], jnp.maximum, _NEG)
    grow = (lab == 0.0) & (act > 0.5)
    o_ref[...] = jnp.where(grow, nbr, lab)


def _pallas_unop(kernel, x: jax.Array, conn: jax.Array) -> jax.Array:
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, conn.reshape(1).astype(x.dtype))


def _pallas_binop(kernel, a: jax.Array, b: jax.Array, conn: jax.Array) -> jax.Array:
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        interpret=True,
    )(a, b, conn.reshape(1).astype(a.dtype))


# ---------------------------------------------------------------------------
# public ops — dispatch to pallas or the pure-jnp oracle
# ---------------------------------------------------------------------------


def neighborhood_max(x: jax.Array, conn) -> jax.Array:
    """Max of each pixel's (4|8)-neighborhood including itself (dilation)."""
    conn = jnp.asarray(conn, x.dtype)
    if USE_PALLAS:
        return _pallas_unop(_nbr_max_kernel, x, conn)
    from . import ref

    return ref.neighborhood_max_ref(x, conn)


def neighborhood_min(x: jax.Array, conn) -> jax.Array:
    """Min of each pixel's (4|8)-neighborhood including itself (erosion)."""
    conn = jnp.asarray(conn, x.dtype)
    if USE_PALLAS:
        return _pallas_unop(_nbr_min_kernel, x, conn)
    from . import ref

    return ref.neighborhood_min_ref(x, conn)


def recon_sweep(marker: jax.Array, mask: jax.Array, conn) -> jax.Array:
    """One sweep of greyscale morphological reconstruction by dilation."""
    conn = jnp.asarray(conn, marker.dtype)
    if USE_PALLAS:
        return _pallas_binop(_recon_sweep_kernel, marker, mask, conn)
    from . import ref

    return ref.recon_sweep_ref(marker, mask, conn)


def label_sweep(labels: jax.Array, active: jax.Array, conn) -> jax.Array:
    """One seeded label-growing sweep (watershed level propagation)."""
    conn = jnp.asarray(conn, labels.dtype)
    if USE_PALLAS:
        return _pallas_binop(_label_sweep_kernel, labels, active, conn)
    from . import ref

    return ref.label_sweep_ref(labels, active, conn)
