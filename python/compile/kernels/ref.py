"""Pure-jnp oracles for the Pallas kernels in :mod:`morph`.

These are the CORE correctness signal: every kernel must agree exactly with
its oracle for all shapes / dtypes / connectivities (pytest + hypothesis
sweep in ``python/tests/test_kernel.py``). They are also the fallback
implementation when ``RTF_USE_PALLAS=0``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _shifted_ref(x: jax.Array, pad_val):
    h, w = x.shape
    p = jnp.pad(x, 1, constant_values=pad_val)
    orth = [p[0:h, 1 : w + 1], p[2 : h + 2, 1 : w + 1], p[1 : h + 1, 0:w], p[1 : h + 1, 2 : w + 2]]
    diag = [p[0:h, 0:w], p[0:h, 2 : w + 2], p[2 : h + 2, 0:w], p[2 : h + 2, 2 : w + 2]]
    return orth, diag


def _nbr_ext_ref(x: jax.Array, conn: jax.Array, ext, pad_val) -> jax.Array:
    orth, diag = _shifted_ref(x, pad_val)
    e4 = functools.reduce(ext, orth, x)
    e8 = functools.reduce(ext, diag, e4)
    return jnp.where(jnp.asarray(conn, x.dtype) >= 8.0, e8, e4)


def neighborhood_max_ref(x: jax.Array, conn) -> jax.Array:
    """Oracle for :func:`morph.neighborhood_max`."""
    return _nbr_ext_ref(x, conn, jnp.maximum, -jnp.inf)


def neighborhood_min_ref(x: jax.Array, conn) -> jax.Array:
    """Oracle for :func:`morph.neighborhood_min`."""
    return _nbr_ext_ref(x, conn, jnp.minimum, jnp.inf)


def recon_sweep_ref(marker: jax.Array, mask: jax.Array, conn) -> jax.Array:
    """Oracle for :func:`morph.recon_sweep`."""
    return jnp.minimum(neighborhood_max_ref(marker, conn), mask)


def label_sweep_ref(labels: jax.Array, active: jax.Array, conn) -> jax.Array:
    """Oracle for :func:`morph.label_sweep`."""
    nbr = neighborhood_max_ref(labels, conn)
    grow = (labels == 0.0) & (active > 0.5)
    return jnp.where(grow, nbr, labels)


def reconstruct_ref(marker: jax.Array, mask: jax.Array, conn, max_iter: int = 512) -> jax.Array:
    """Full greyscale reconstruction-by-dilation fixpoint (oracle loop).

    Python-level loop with early exit; used only in tests (the L2 model uses
    ``lax.while_loop`` so it lowers into the AOT artifact).
    """
    cur = jnp.minimum(marker, mask)
    for _ in range(max_iter):
        nxt = recon_sweep_ref(cur, mask, conn)
        if bool(jnp.all(nxt == cur)):
            return nxt
        cur = nxt
    return cur
