"""L2: the microscopy segmentation workflow as 9 AOT-compilable JAX tasks.

This is a JAX re-implementation of the nscale glioblastoma segmentation
pipeline the paper runs SA over (paper Fig 1 / Table 1): normalization,
seven fine-grain segmentation tasks t1..t7, and a mask-comparison task.
Each task is lowered to its own HLO artifact by :mod:`compile.aot` with the
uniform signature

    (a: f32[H,W], b: f32[H,W], c: f32[H,W], params: f32[5]) -> (a', b', c')

so the Rust coordinator (L3) can execute any task generically, and — key
for reuse — the paper's 15 parameters are *runtime inputs*: one compiled
executable serves every parameter set the SA method generates.

State-plane convention along the chain:

    synth tile:  (r, g, b)            raw channels, [0, 255]
    norm  ->     (r, g, b)            stain-normalized channels
    t1    ->     (grey, fg,   zero)   inverted grey + foreground mask
    t2    ->     (grey, cand, domes)  candidate nuclei + h-dome prominence
    t3    ->     (grey, fill, domes)  hole-filled candidates
    t4    ->     (grey, kept, domes)  area/prominence-filtered components
    t5    ->     (grey, kept, depth)  pre-watershed filter + erosion depth
    t6    ->     (grey, seg,  labels) watershed-split nuclei
    t7    ->     (grey, final, labels) final area filter
    cmp(state, ref_mask) -> f32[3]    (dice, jaccard, |diff|) vs reference

The propagation-style operators (reconstruction, fill, CC, watershed) call
the L1 Pallas sweep kernels from :mod:`compile.kernels.morph` inside
``lax.while_loop`` / ``lax.fori_loop`` so the iteration lowers into the
same HLO artifact and runs data-dependently inside XLA, never in Python.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import morph

# Maximum erosion depth tracked for watershed seeding. Nuclei radii in the
# synthetic tiles are <= ~12 px, so 16 levels always reach the core.
DEPTH_LEVELS = 16

# Iteration caps for the while-loops (safety net; convergence checks exit
# earlier). Propagation distance is bounded by the tile diagonal.
_MAX_SWEEPS = 4096

# Normalization targets (paper stage 1 fixes staining/illumination). The
# mean is chosen so normalized *background* lands in the paper's B/G/R
# background-threshold range [210, 240] (Table 1) — otherwise those
# parameters could never be influential.
_NORM_MEAN = 210.0
_NORM_STD = 40.0

# h-maxima suppression height for watershed seeding: regional maxima less
# than this far above their separating saddle are merged into one seed,
# which removes the satellite-maxima artifacts of discrete L-inf erosion.
_SEED_H = 2.0

# Fixed h-dome height for candidate extraction (t2). The reconstruction
# marker is grey - _DOME_H; the paper's G1 then *thresholds* the dome
# image, so candidate count is monotone in G1 (as in nscale).
_DOME_H = 100.0

#: number of padded scalar parameters every task artifact accepts
N_PARAMS = 5

#: task names in chain order (cmp handled separately: extra ref input)
TASKS = ("norm", "t1", "t2", "t3", "t4", "t5", "t6", "t7")


# ---------------------------------------------------------------------------
# propagation helpers (fixpoint loops over L1 sweep kernels)
# ---------------------------------------------------------------------------


def _fixpoint(sweep_fn, init: jax.Array) -> jax.Array:
    """Iterate ``sweep_fn`` until the image stops changing (monotone ops)."""

    def cond(state):
        it, cur, changed = state
        return jnp.logical_and(changed, it < _MAX_SWEEPS)

    def body(state):
        it, cur, _ = state
        nxt = sweep_fn(cur)
        return it + 1, nxt, jnp.any(nxt != cur)

    _, out, _ = jax.lax.while_loop(cond, body, (jnp.int32(0), init, jnp.bool_(True)))
    return out


def morph_reconstruct(marker: jax.Array, mask: jax.Array, conn) -> jax.Array:
    """Greyscale morphological reconstruction by dilation (IWPP fixpoint)."""
    init = jnp.minimum(marker, mask)
    return _fixpoint(lambda m: morph.recon_sweep(m, mask, conn), init)


def fill_holes(binary: jax.Array, conn) -> jax.Array:
    """Fill holes: background not reachable from the border becomes object.

    Binary reconstruction of the complement from a border marker; matches
    the paper's FillHoles operator with its 4/8-conn parameter.
    """
    comp = 1.0 - binary
    h, w = binary.shape
    border = jnp.zeros_like(binary).at[0, :].set(1.0).at[h - 1, :].set(1.0)
    border = border.at[:, 0].set(1.0).at[:, w - 1].set(1.0)
    marker = border * comp
    outside = _fixpoint(lambda m: morph.recon_sweep(m, comp, conn), marker)
    return jnp.where(outside > 0.5, 0.0, 1.0) * jnp.maximum(binary, comp)


def connected_components(mask: jax.Array, conn=8.0) -> jax.Array:
    """Label connected components with the min linear index + 1 (0 = bg).

    Min-propagation fixpoint: the negated-label trick reuses the max-sweep
    reconstruction kernel (min over labels == max over negated labels
    clamped by the mask), so CC shares the same L1 hot kernel.
    """
    h, w = mask.shape
    idx = (jnp.arange(h * w, dtype=mask.dtype) + 1.0).reshape(h, w)
    big = h * w + 2.0
    lab = jnp.where(mask > 0.5, idx, big)
    # propagate min over the component: -lab propagated by max-reconstruction
    # under the ceiling -lab_init_masked keeps bg pinned at `big`.
    neg = -lab
    ceil = jnp.where(mask > 0.5, jnp.zeros_like(lab), neg)
    out = _fixpoint(lambda m: morph.recon_sweep(m, ceil, conn), neg)
    lab = -out
    return jnp.where(mask > 0.5, lab, 0.0)


def component_sizes(labels: jax.Array) -> jax.Array:
    """Per-pixel size of the pixel's component (0 on background)."""
    h, w = labels.shape
    flat = labels.astype(jnp.int32).reshape(-1)
    areas = jnp.zeros(h * w + 2, dtype=labels.dtype).at[flat].add(1.0)
    sizes = areas[flat].reshape(h, w)
    return jnp.where(labels > 0.5, sizes, 0.0)


def component_max(labels: jax.Array, values: jax.Array) -> jax.Array:
    """Per-pixel max of ``values`` over the pixel's component (0 on bg)."""
    h, w = labels.shape
    flat = labels.astype(jnp.int32).reshape(-1)
    m = jnp.full(h * w + 2, -jnp.inf, dtype=values.dtype).at[flat].max(values.reshape(-1))
    out = m[flat].reshape(h, w)
    return jnp.where(labels > 0.5, out, 0.0)


def area_filter(mask: jax.Array, min_size, max_size, conn=8.0) -> jax.Array:
    """Drop connected components with size outside [min_size, max_size]."""
    labels = connected_components(mask, conn)
    sizes = component_sizes(labels)
    keep = (sizes >= min_size) & (sizes <= max_size)
    return jnp.where(keep, mask, 0.0)


def erosion_depth(mask: jax.Array, levels: int = DEPTH_LEVELS) -> jax.Array:
    """Number of 8-conn erosions each pixel survives, + 1 on the mask.

    A cheap discrete stand-in for the distance transform the watershed
    seeds from (higher = deeper inside a nucleus).
    """

    def body(_, state):
        cur, depth = state
        nxt = morph.neighborhood_min(cur, 8.0)
        return nxt, depth + nxt

    _, depth = jax.lax.fori_loop(0, levels - 1, body, (mask, mask))
    return depth


def watershed(mask: jax.Array, depth: jax.Array, conn) -> jax.Array:
    """Seeded watershed by level-ordered label growing (dense IWPP form).

    Seeds are the *h-maxima* of ``depth`` (h = ``_SEED_H``): regional maxima
    that rise at least h above their surroundings, computed with the same
    reconstruction kernel (``depth - reconstruct(depth - h, depth) >= h``).
    Plain regional maxima would over-segment — discrete L-inf erosion of a
    digital disc produces satellite maxima one level below the core.
    Low-relief components (peak depth < h) get their peak plateau as the
    seed so thin objects are not dropped. Labels then grow outward one
    depth level at a time so each basin claims its slope before basins
    merge — splitting touching nuclei the way the paper's queue-based
    watershed does.
    """
    inside = mask > 0.5
    hrecon = morph_reconstruct(jnp.maximum(depth - _SEED_H, 0.0), depth, 8.0)
    hseed = (depth - hrecon >= _SEED_H) & inside
    comp = connected_components(mask, 8.0)
    peak = component_max(comp, depth)
    lowseed = (peak < _SEED_H) & (depth >= peak) & inside
    is_seed = hseed | lowseed
    plateau = connected_components(jnp.where(is_seed, 1.0, 0.0), 8.0)
    labels = plateau  # 0 where not seed

    def level_body(i, labels):
        level = jnp.asarray(DEPTH_LEVELS, depth.dtype) - i.astype(depth.dtype)
        active = jnp.where((depth >= level) & (mask > 0.5), 1.0, 0.0)
        return _fixpoint(lambda l: morph.label_sweep(l, active, conn), labels)

    labels = jax.lax.fori_loop(0, DEPTH_LEVELS, level_body, labels)
    return jnp.where(mask > 0.5, labels, 0.0)


# ---------------------------------------------------------------------------
# the 9 workflow tasks (uniform signatures -> per-task HLO artifacts)
# ---------------------------------------------------------------------------


def task_norm(a, b, c, params):
    """Stage 1 — stain/illumination normalization (no varied parameters).

    Per-channel affine map to fixed target statistics, clipped to [0, 255].
    The zero-weight ``params`` term keeps the uniform 4-input artifact
    signature: jax drops unused arguments from the lowered entry layout,
    which would break the generic Rust task executor.
    """
    anchor = 0.0 * params[0]

    def norm1(x):
        mu = jnp.mean(x)
        sd = jnp.std(x) + 1e-6
        return jnp.clip((x - mu) / sd * _NORM_STD + _NORM_MEAN + anchor, 0.0, 255.0)

    return norm1(a), norm1(b), norm1(c)


def task_t1(a, b, c, params):
    """t1 — background detection + red-blood-cell masking.

    params = [B, G, R, T1, T2]: a pixel is background when all channels
    exceed the B/G/R thresholds; RBC pixels have red/green and red/blue
    ratios above T1/T2 (paper Table 1).
    """
    r, g, bl = a, b, c
    B, G, R, T1, T2 = params[0], params[1], params[2], params[3], params[4]
    background = (r > B) & (g > G) & (bl > R)
    rbc = ((r + 1.0) / (g + 1.0) > T1) & ((r + 1.0) / (bl + 1.0) > T2)
    grey = 255.0 - (0.299 * r + 0.587 * g + 0.114 * bl)  # nuclei stain dark -> bright
    fg = jnp.where(background | rbc, 0.0, 1.0)
    return grey, fg, jnp.zeros_like(grey)


def task_t2(a, b, c, params):
    """t2 — candidate nuclei via h-dome morphological reconstruction.

    params = [G1, RC, _, _, _]: reconstruct (grey - _DOME_H) under grey with
    RC-connectivity; domes = grey - recon; candidates are foreground pixels
    whose dome prominence reaches the G1 threshold (monotone in G1, as in
    nscale's diffIm > G1).
    """
    grey, fg = a, b
    G1, RC = params[0], params[1]
    # zero-weight anchor keeps the unused aux plane in the lowered entry
    # signature (see task_norm docstring)
    marker = jnp.maximum(grey - _DOME_H + 0.0 * c[0, 0], 0.0) * fg
    recon = morph_reconstruct(marker, grey, RC)
    domes = (grey - recon) * fg
    cand = jnp.where(domes >= G1, 1.0, 0.0)
    return grey, cand, domes


def task_t3(a, b, c, params):
    """t3 — fill holes in the candidate mask. params = [FH, _, _, _, _]."""
    grey, cand, domes = a, b, c
    FH = params[0]
    return grey, fill_holes(cand, FH), domes


def task_t4(a, b, c, params):
    """t4 — component filter by area and dome prominence.

    params = [G2, minS, maxS, _, _]: keep components with size in
    [minS, maxS] whose peak dome prominence reaches G2.
    """
    grey, filled, domes = a, b, c
    G2, minS, maxS = params[0], params[1], params[2]
    labels = connected_components(filled, 8.0)
    sizes = component_sizes(labels)
    peak = component_max(labels, domes)
    keep = (sizes >= minS) & (sizes <= maxS) & (peak >= G2)
    kept = jnp.where(keep, filled, 0.0)
    return grey, kept, domes


def task_t5(a, b, c, params):
    """t5 — pre-watershed area filter + erosion-depth map.

    params = [minSPL, _, _, _, _] (paper: area threshold before watershed).
    """
    grey, kept, domes = a, b, c
    minSPL = params[0]
    # zero-weight anchor keeps the (otherwise unused) domes plane in the
    # lowered entry signature (see task_norm docstring)
    mask = area_filter(kept, minSPL + 0.0 * domes[0, 0], float(10**9), 8.0)
    depth = erosion_depth(mask)
    return grey, mask, depth


def task_t6(a, b, c, params):
    """t6 — seeded watershed split. params = [WConn, _, _, _, _]."""
    grey, mask, depth = a, b, c
    WConn = params[0]
    labels = watershed(mask, depth, WConn)
    seg = jnp.where(labels > 0.5, 1.0, 0.0)
    return grey, seg, labels


def task_t7(a, b, c, params):
    """t7 — final object area filter. params = [minSS, maxSS, _, _, _]."""
    grey, seg, labels = a, b, c
    minSS, maxSS = params[0], params[1]
    sizes = component_sizes(labels)
    keep = (sizes >= minSS) & (sizes <= maxSS) & (seg > 0.5)
    final = jnp.where(keep, 1.0, 0.0)
    return grey, final, jnp.where(keep, labels, 0.0)


def task_cmp(a, b, c, ref_mask, params):
    """cmp — compare the final mask against the reference segmentation.

    Returns f32[3] = (dice, jaccard, mean |diff|). The SA output metric the
    paper feeds MOAT/VBD is the mask *difference*, i.e. 1 - dice.
    """
    # zero-weight anchor keeps the unused planes/params in the lowered
    # entry signature (see task_norm docstring)
    anchor = 0.0 * (params[0] + a[0, 0] + c[0, 0])
    m = jnp.where(b > 0.5, 1.0, 0.0)
    r = jnp.where(ref_mask > 0.5, 1.0, 0.0)
    inter = jnp.sum(m * r)
    sm, sr = jnp.sum(m), jnp.sum(r)
    union = sm + sr - inter
    dice = (2.0 * inter + 1e-6) / (sm + sr + 1e-6) + anchor
    jacc = (inter + 1e-6) / (union + 1e-6)
    diff = jnp.mean(jnp.abs(m - r))
    return jnp.stack([dice, jacc, diff])


TASK_FNS = {
    "norm": task_norm,
    "t1": task_t1,
    "t2": task_t2,
    "t3": task_t3,
    "t4": task_t4,
    "t5": task_t5,
    "t6": task_t6,
    "t7": task_t7,
}


def run_chain(r, g, b, param_vectors: dict[str, jax.Array]):
    """Execute the full task chain in-process (test/debug path only).

    ``param_vectors`` maps task name -> f32[5]; returns the final state.
    The production path never calls this: Rust executes the per-task HLO
    artifacts instead.
    """
    state = (r, g, b)
    for name in TASKS:
        state = TASK_FNS[name](*state, param_vectors[name])
    return state


@partial(jax.jit, static_argnames=())
def run_chain_jit(r, g, b, pnorm, p1, p2, p3, p4, p5, p6, p7):
    pv = dict(zip(TASKS, (pnorm, p1, p2, p3, p4, p5, p6, p7)))
    return run_chain(r, g, b, pv)
