"""Shared fixtures: deterministic synthetic tissue tiles.

Mirrors (loosely — exact equality is not required) the Rust-side generator
in ``rust/src/data/synth.rs``: bright background, dark-purple elliptical
nuclei, strongly-red RBC discs, mild Gaussian noise.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest


def synth_tile(h: int = 64, w: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    r = np.full((h, w), 230.0)
    g = np.full((h, w), 225.0)
    b = np.full((h, w), 228.0)
    yy, xx = np.mgrid[0:h, 0:w]
    n_nuclei = max(3, h * w // 700)
    for _ in range(n_nuclei):
        cy, cx = rng.integers(4, h - 4), rng.integers(4, w - 4)
        rad = rng.integers(3, max(4, min(h, w) // 10))
        blob = (yy - cy) ** 2 + (xx - cx) ** 2 <= rad * rad
        stain = rng.uniform(0.05, 1.0)  # per-nucleus stain intensity
        for ch, dark in ((r, 120.0), (g, 90.0), (b, 160.0)):
            ch[blob] += (dark - ch[blob]) * stain
    for _ in range(max(1, n_nuclei // 4)):
        cy, cx = rng.integers(3, h - 3), rng.integers(3, w - 3)
        disc = (yy - cy) ** 2 + (xx - cx) ** 2 <= 9
        redness = rng.uniform(0.6, 1.0)  # per-RBC hemoglobin strength
        r[disc] = 140.0 + 70.0 * redness
        g[disc] = 90.0 - 55.0 * redness
        b[disc] = 90.0 - 55.0 * redness

    def blur3(x):  # 3x3 box blur with edge replication -> soft edges
        p = np.pad(x, 1, mode="edge")
        out = np.zeros_like(x)
        for dy in range(3):
            for dx in range(3):
                out += p[dy : dy + h, dx : dx + w]
        return out / 9.0

    out = []
    for ch in (r, g, b):
        ch = blur3(blur3(ch))  # ~2 px gradient skirt around objects
        ch += rng.normal(0.0, 2.0, (h, w))
        np.clip(ch, 0.0, 255.0, out=ch)
        out.append(ch)
    return tuple(jnp.asarray(x, jnp.float32) for x in out)


DEFAULT_PARAMS = {
    "norm": [0.0, 0.0, 0.0, 0.0, 0.0],
    "t1": [210.0, 210.0, 210.0, 2.5, 2.5],
    "t2": [40.0, 8.0, 0.0, 0.0, 0.0],
    "t3": [8.0, 0.0, 0.0, 0.0, 0.0],
    "t4": [2.0, 10.0, 1500.0, 0.0, 0.0],
    "t5": [10.0, 0.0, 0.0, 0.0, 0.0],
    "t6": [8.0, 0.0, 0.0, 0.0, 0.0],
    "t7": [10.0, 1200.0, 0.0, 0.0, 0.0],
}


@pytest.fixture
def tile():
    return synth_tile()


@pytest.fixture
def default_params():
    return {k: jnp.asarray(v, jnp.float32) for k, v in DEFAULT_PARAMS.items()}
