"""AOT path: lowering fidelity + artifact emission round-trip.

Checks that (i) the HLO text artifacts are structurally sound, (ii) the
compiled lowering computes the same numbers as the traced task functions,
and (iii) the manifest matches what the Rust loader expects.
"""

from __future__ import annotations

import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from tests.conftest import synth_tile, DEFAULT_PARAMS

SIZE = 32


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.emit(str(out), SIZE, verbose=False)
    return out, manifest


def test_manifest_shape(emitted):
    out, manifest = emitted
    assert manifest["height"] == manifest["width"] == SIZE
    assert manifest["n_params"] == model.N_PARAMS
    assert manifest["task_order"] == list(model.TASKS)
    names = [t["name"] for t in manifest["tasks"]]
    assert names == list(model.TASKS) + ["cmp"]
    for t in manifest["tasks"]:
        assert (out / t["file"]).exists()
        if t["name"] == "cmp":
            assert t["image_inputs"] == 4 and t["outputs"] == 1
        else:
            assert t["image_inputs"] == 3 and t["outputs"] == 3


def test_manifest_json_is_what_rust_parses(emitted):
    out, _ = emitted
    with open(out / "manifest.json") as f:
        m = json.load(f)
    assert set(m) >= {"height", "width", "n_params", "task_order", "tasks", "compare_task"}


def test_hlo_text_structure(emitted):
    out, manifest = emitted
    for t in manifest["tasks"]:
        text = (out / t["file"]).read_text()
        assert "ENTRY" in text and "HloModule" in text
        # parameters: image planes + the padded param vector
        n_inputs = t["image_inputs"] + 1
        for i in range(n_inputs):
            assert f"parameter({i})" in text, (t["name"], i)
        # iterative tasks must carry their fixpoint loop into the artifact
        # (t7 reuses the labels produced by t6 — no propagation loop)
        if t["name"] in ("t2", "t3", "t4", "t5", "t6"):
            assert "while" in text, t["name"]


def test_lowered_t1_matches_traced():
    img = jax.ShapeDtypeStruct((SIZE, SIZE), jnp.float32)
    par = jax.ShapeDtypeStruct((model.N_PARAMS,), jnp.float32)
    compiled = jax.jit(model.task_t1).lower(img, img, img, par).compile()
    r, g, b = synth_tile(SIZE, SIZE, seed=3)
    rn, gn, bn = model.task_norm(r, g, b, jnp.zeros(5))
    p = jnp.asarray(DEFAULT_PARAMS["t1"], jnp.float32)
    got = compiled(rn, gn, bn, p)
    want = model.task_t1(rn, gn, bn, p)
    # XLA fuses/reorders float math, so exact equality does not hold
    for x, y in zip(got, want):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-3)


def test_lowered_full_chain_matches_traced():
    """Every task, lowered+compiled exactly as the artifact, chained end to
    end, must reproduce the traced chain's segmentation (tiny float
    reorderings may flip individual threshold pixels, so the masks are
    compared with a small mismatch budget, and the run must be
    deterministic across repeated compiled executions)."""
    img = jax.ShapeDtypeStruct((SIZE, SIZE), jnp.float32)
    par = jax.ShapeDtypeStruct((model.N_PARAMS,), jnp.float32)
    r, g, b = synth_tile(SIZE, SIZE, seed=4)
    traced = model.run_chain(
        r, g, b, {k: jnp.asarray(v, jnp.float32) for k, v in DEFAULT_PARAMS.items()}
    )

    def run_compiled():
        state = (r, g, b)
        for name in model.TASKS:
            fn = model.TASK_FNS[name]
            compiled = jax.jit(fn).lower(img, img, img, par).compile()
            state = compiled(*state, jnp.asarray(DEFAULT_PARAMS[name], jnp.float32))
        return state

    state1 = run_compiled()
    state2 = run_compiled()
    for x, y in zip(state1, state2):  # compiled path is deterministic
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    mask_c = np.asarray(state1[1]) > 0.5
    mask_t = np.asarray(traced[1]) > 0.5
    mismatch = (mask_c != mask_t).mean()
    assert mismatch < 0.01, f"compiled vs traced masks diverge: {mismatch:.3%}"


def test_artifact_reemission_is_deterministic(tmp_path):
    m1 = aot.emit(str(tmp_path / "a"), SIZE, verbose=False)
    m2 = aot.emit(str(tmp_path / "b"), SIZE, verbose=False)
    d1 = {t["name"]: t["sha256_16"] for t in m1["tasks"]}
    d2 = {t["name"]: t["sha256_16"] for t in m2["tasks"]}
    assert d1 == d2
