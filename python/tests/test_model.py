"""L2 semantics: the 9 workflow tasks behave like their nscale counterparts."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from tests.conftest import synth_tile, DEFAULT_PARAMS


def P(*vals):
    v = list(vals) + [0.0] * (model.N_PARAMS - len(vals))
    return jnp.asarray(v, jnp.float32)


# ---------------------------------------------------------------------------
# operator helpers
# ---------------------------------------------------------------------------


def test_fill_holes_fills_interior():
    m = np.zeros((9, 9), np.float32)
    m[2:7, 2:7] = 1.0
    m[4, 4] = 0.0  # interior hole
    out = np.asarray(model.fill_holes(jnp.asarray(m), 8.0))
    assert out[4, 4] == 1.0
    assert out[0, 0] == 0.0
    assert out.sum() == 25.0


def test_fill_holes_keeps_border_notch_open():
    m = np.zeros((9, 9), np.float32)
    m[2:7, 2:7] = 1.0
    m[0:5, 4] = 0.0  # channel to the border: not a hole
    out = np.asarray(model.fill_holes(jnp.asarray(m), 4.0))
    assert out[4, 4] == 0.0


def test_fill_holes_conn8_can_leak_through_diagonal_gap():
    # a diagonal crack from the border is passable for 8-conn background
    # but is a chain of isolated holes for 4-conn background
    m = np.ones((7, 7), np.float32)
    for i in range(4):
        m[i, i] = 0.0  # diagonal background path from (0,0) to (3,3)
    out4 = np.asarray(model.fill_holes(jnp.asarray(m), 4.0))
    out8 = np.asarray(model.fill_holes(jnp.asarray(m), 8.0))
    assert out4[3, 3] == 1.0  # 4-conn bg cannot traverse the diagonal
    assert out8[3, 3] == 0.0  # 8-conn bg escapes -> not filled
    assert out4[0, 0] == 0.0  # border pixel itself is never filled


def test_connected_components_two_blobs():
    m = np.zeros((8, 8), np.float32)
    m[1:3, 1:3] = 1.0
    m[5:8, 5:8] = 1.0
    lab = np.asarray(model.connected_components(jnp.asarray(m), 8.0))
    ids = sorted(set(lab[lab > 0].tolist()))
    assert len(ids) == 2
    assert (lab[1:3, 1:3] == ids[0]).all()
    assert (lab[5:8, 5:8] == ids[1]).all()
    assert (lab[m == 0] == 0).all()


def test_connected_components_diag_conn4_vs_conn8():
    m = np.zeros((4, 4), np.float32)
    m[0, 0] = m[1, 1] = 1.0
    lab4 = np.asarray(model.connected_components(jnp.asarray(m), 4.0))
    lab8 = np.asarray(model.connected_components(jnp.asarray(m), 8.0))
    assert lab4[0, 0] != lab4[1, 1]
    assert lab8[0, 0] == lab8[1, 1]


def test_component_sizes_and_max():
    m = np.zeros((6, 6), np.float32)
    m[0:2, 0:2] = 1.0  # size 4
    m[4:6, 0:3] = 1.0  # size 6
    lab = model.connected_components(jnp.asarray(m), 8.0)
    sizes = np.asarray(model.component_sizes(lab))
    assert sizes[0, 0] == 4.0 and sizes[5, 1] == 6.0 and sizes[2, 2] == 0.0
    vals = np.zeros((6, 6), np.float32)
    vals[1, 1] = 7.0
    vals[5, 2] = 3.0
    peak = np.asarray(model.component_max(lab, jnp.asarray(vals)))
    assert peak[0, 0] == 7.0 and peak[4, 0] == 3.0


def test_area_filter_bounds():
    m = np.zeros((10, 10), np.float32)
    m[0, 0] = 1.0  # size 1
    m[2:4, 2:4] = 1.0  # size 4
    m[5:10, 5:10] = 1.0  # size 25
    out = np.asarray(model.area_filter(jnp.asarray(m), 2.0, 10.0, 8.0))
    assert out[0, 0] == 0.0
    assert out[2, 2] == 1.0
    assert out[7, 7] == 0.0


def test_erosion_depth_square():
    m = np.zeros((11, 11), np.float32)
    m[1:10, 1:10] = 1.0  # 9x9 square: max depth 5 at center
    d = np.asarray(model.erosion_depth(jnp.asarray(m)))
    assert d[5, 5] == 5.0
    assert d[1, 1] == 1.0
    assert d[0, 0] == 0.0
    # depth decreases by at most 1 per step outward
    assert d.max() == 5.0


def test_watershed_splits_touching_blobs():
    # two barely-touching discs (1-px neck): one CC, but the depth saddle
    # (1) sits >= _SEED_H below both peaks (4) -> two h-maxima -> 2 labels
    h = w = 24
    yy, xx = np.mgrid[0:h, 0:w]
    m = (((yy - 12) ** 2 + (xx - 6) ** 2) <= 25) | (((yy - 12) ** 2 + (xx - 17) ** 2) <= 25)
    m = m.astype(np.float32)
    assert len(set(np.asarray(model.connected_components(jnp.asarray(m), 8.0))[m > 0].tolist())) == 1
    depth = model.erosion_depth(jnp.asarray(m))
    lab = np.asarray(model.watershed(jnp.asarray(m), depth, 8.0))
    ids = set(lab[m > 0].tolist()) - {0.0}
    assert len(ids) == 2
    # every mask pixel is claimed by some basin
    assert (lab[m > 0] > 0).all()


def test_watershed_single_blob_single_label():
    h = w = 16
    yy, xx = np.mgrid[0:h, 0:w]
    m = (((yy - 8) ** 2 + (xx - 8) ** 2) <= 20).astype(np.float32)
    depth = model.erosion_depth(jnp.asarray(m))
    lab = np.asarray(model.watershed(jnp.asarray(m), depth, 8.0))
    assert len(set(lab[m > 0].tolist()) - {0.0}) == 1


# ---------------------------------------------------------------------------
# tasks
# ---------------------------------------------------------------------------


def test_task_norm_targets_stats():
    r, g, b = synth_tile(48, 48, seed=7)
    a, bb, c = model.task_norm(r, g, b, P())
    for x in (a, bb, c):
        x = np.asarray(x)
        assert 0.0 <= x.min() and x.max() <= 255.0
        assert abs(x.mean() - 210.0) < 25.0  # clipping skews slightly


def test_task_t1_masks_background_and_rbc():
    r, g, b = synth_tile(48, 48, seed=1)
    rn, gn, bn = model.task_norm(r, g, b, P())
    grey, fg, _ = model.task_t1(rn, gn, bn, P(210.0, 210.0, 210.0, 2.5, 2.5))
    grey, fg = np.asarray(grey), np.asarray(fg)
    assert 0.0 < fg.mean() < 0.9  # some bg detected, some fg kept
    # laxer thresholds (higher B/G/R) classify fewer pixels as background
    _, fg_lax, _ = model.task_t1(rn, gn, bn, P(240.0, 240.0, 240.0, 2.5, 2.5))
    assert np.asarray(fg_lax).sum() >= fg.sum()
    # nuclei pixels (dark red, high blue — unlike RBC) stay foreground
    nuclei = (np.asarray(rn) < 150) & (np.asarray(bn) > 120)
    assert fg[nuclei].mean() > 0.9


def test_task_t2_candidates_shrink_with_G1():
    r, g, b = synth_tile(48, 48, seed=2)
    state = model.task_norm(r, g, b, P())
    state = model.task_t1(*state, P(210.0, 210.0, 210.0, 2.5, 2.5))
    _, cand_lo, _ = model.task_t2(*state, P(20.0, 8.0))
    _, cand_hi, _ = model.task_t2(*state, P(70.0, 8.0))
    assert np.asarray(cand_hi).sum() <= np.asarray(cand_lo).sum()
    assert np.asarray(cand_lo).sum() > 0


def test_task_t4_prominence_and_area():
    grey = jnp.zeros((8, 8))
    filled = np.zeros((8, 8), np.float32)
    filled[0:2, 0:2] = 1.0  # size-4, peak dome 10
    filled[5:6, 5:8] = 1.0  # size-3, peak dome 1
    domes = np.zeros((8, 8), np.float32)
    domes[1, 1] = 10.0
    domes[5, 5] = 1.0
    _, kept, _ = model.task_t4(grey, jnp.asarray(filled), jnp.asarray(domes), P(5.0, 2.0, 100.0))
    kept = np.asarray(kept)
    assert kept[0, 0] == 1.0  # passes both area + prominence
    assert kept[5, 5] == 0.0  # fails prominence G2=5


def test_task_t7_final_filter():
    grey = jnp.zeros((8, 8))
    seg = np.zeros((8, 8), np.float32)
    seg[0:3, 0:3] = 1.0
    seg[6, 6] = 1.0
    labels = model.connected_components(jnp.asarray(seg), 8.0)
    _, final, lab_out = model.task_t7(grey, jnp.asarray(seg), labels, P(2.0, 100.0))
    final = np.asarray(final)
    assert final[1, 1] == 1.0 and final[6, 6] == 0.0
    assert np.asarray(lab_out)[6, 6] == 0.0


def test_task_cmp_metrics():
    a = jnp.zeros((6, 6))
    m = np.zeros((6, 6), np.float32)
    m[0:3, :] = 1.0
    ref = np.zeros((6, 6), np.float32)
    ref[0:3, 0:3] = 1.0
    out = np.asarray(model.task_cmp(a, jnp.asarray(m), a, jnp.asarray(ref), P()))
    dice, jacc, diff = out
    assert abs(dice - 2 * 9 / (18 + 9)) < 1e-5
    assert abs(jacc - 9 / 18) < 1e-5
    assert abs(diff - 9 / 36) < 1e-5


def test_task_cmp_identical_masks_perfect_score():
    a = jnp.zeros((5, 5))
    m = jnp.asarray(np.eye(5, dtype=np.float32))
    out = np.asarray(model.task_cmp(a, m, a, m, P()))
    assert abs(out[0] - 1.0) < 1e-5 and abs(out[1] - 1.0) < 1e-5 and out[2] == 0.0


# ---------------------------------------------------------------------------
# end-to-end chain
# ---------------------------------------------------------------------------


def test_chain_end_to_end_produces_segmentation(default_params, tile):
    r, g, b = tile
    grey, mask, labels = model.run_chain(r, g, b, default_params)
    mask, labels = np.asarray(mask), np.asarray(labels)
    assert mask.sum() > 20  # found nuclei
    assert mask.mean() < 0.5  # did not flood the tile
    n_obj = len(set(labels[labels > 0].tolist()))
    assert n_obj >= 2
    # labels and mask agree
    assert ((labels > 0) == (mask > 0.5)).all()


def test_chain_output_sensitive_to_influential_params(default_params, tile):
    """G1/G2 are the paper's most influential parameters (Table 2) — the
    output must actually move when they move, else SA is meaningless."""
    r, g, b = tile
    _, mask_ref, _ = model.run_chain(r, g, b, default_params)
    perturbed = dict(default_params)
    perturbed["t2"] = jnp.asarray([75.0, 8.0, 0.0, 0.0, 0.0])
    _, mask_hi, _ = model.run_chain(r, g, b, perturbed)
    assert float(jnp.abs(mask_ref - mask_hi).sum()) > 0


def test_chain_deterministic(default_params, tile):
    r, g, b = tile
    out1 = model.run_chain(r, g, b, default_params)
    out2 = model.run_chain(r, g, b, default_params)
    for x, y in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
