"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes / dtypes / connectivity / value distributions and
asserts exact agreement (the ops are max/min/select — no rounding slack is
needed; bf16 compares exactly too because both paths round identically).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import morph, ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _img(draw, h, w, dtype, lo=-100.0, hi=300.0):
    arr = draw(
        st.lists(
            st.floats(lo, hi, allow_nan=False, allow_infinity=False, width=32),
            min_size=h * w,
            max_size=h * w,
        )
    )
    return jnp.asarray(np.array(arr, dtype=np.float32).reshape(h, w), dtype)


shapes = st.tuples(st.integers(1, 24), st.integers(1, 24))
conns = st.sampled_from([4.0, 8.0])
dtypes = st.sampled_from(DTYPES)


@st.composite
def image_case(draw):
    h, w = draw(shapes)
    dtype = draw(dtypes)
    return _img(draw, h, w, dtype), draw(conns)


@st.composite
def image_pair_case(draw):
    h, w = draw(shapes)
    dtype = draw(dtypes)
    return _img(draw, h, w, dtype), _img(draw, h, w, dtype), draw(conns)


@st.composite
def label_case(draw):
    h, w = draw(shapes)
    labels = draw(
        st.lists(st.integers(0, 50), min_size=h * w, max_size=h * w)
    )
    active = draw(st.lists(st.integers(0, 1), min_size=h * w, max_size=h * w))
    lab = jnp.asarray(np.array(labels, np.float32).reshape(h, w))
    act = jnp.asarray(np.array(active, np.float32).reshape(h, w))
    return lab, act, draw(conns)


def _eq(a, b):
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


@settings(max_examples=60, deadline=None)
@given(image_case())
def test_neighborhood_max_matches_ref(case):
    x, conn = case
    _eq(morph.neighborhood_max(x, conn), ref.neighborhood_max_ref(x, conn))


@settings(max_examples=60, deadline=None)
@given(image_case())
def test_neighborhood_min_matches_ref(case):
    x, conn = case
    _eq(morph.neighborhood_min(x, conn), ref.neighborhood_min_ref(x, conn))


@settings(max_examples=60, deadline=None)
@given(image_pair_case())
def test_recon_sweep_matches_ref(case):
    marker, mask, conn = case
    _eq(morph.recon_sweep(marker, mask, conn), ref.recon_sweep_ref(marker, mask, conn))


@settings(max_examples=60, deadline=None)
@given(label_case())
def test_label_sweep_matches_ref(case):
    lab, act, conn = case
    _eq(morph.label_sweep(lab, act, conn), ref.label_sweep_ref(lab, act, conn))


# ---------------------------------------------------------------------------
# directed algebraic properties (catch errors the oracle-diff can't, e.g. a
# bug shared by kernel and oracle)
# ---------------------------------------------------------------------------


def test_max_of_constant_is_constant():
    x = jnp.full((7, 9), 3.5)
    for conn in (4.0, 8.0):
        _eq(morph.neighborhood_max(x, conn), x)
        _eq(morph.neighborhood_min(x, conn), x)


def test_max_dominates_center_and_min_is_dominated():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(16, 16)), jnp.float32)
    for conn in (4.0, 8.0):
        assert bool(jnp.all(morph.neighborhood_max(x, conn) >= x))
        assert bool(jnp.all(morph.neighborhood_min(x, conn) <= x))


def test_conn8_dominates_conn4():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(16, 16)), jnp.float32)
    assert bool(jnp.all(morph.neighborhood_max(x, 8.0) >= morph.neighborhood_max(x, 4.0)))
    assert bool(jnp.all(morph.neighborhood_min(x, 8.0) <= morph.neighborhood_min(x, 4.0)))


def test_single_pixel_dilation_cross_vs_square():
    x = np.zeros((5, 5), np.float32)
    x[2, 2] = 1.0
    d4 = np.asarray(morph.neighborhood_max(jnp.asarray(x), 4.0))
    d8 = np.asarray(morph.neighborhood_max(jnp.asarray(x), 8.0))
    assert d4.sum() == 5  # center + 4-neighborhood cross
    assert d8.sum() == 9  # full 3x3 square
    assert d4[2, 2] == d8[2, 2] == 1.0
    assert d4[1, 1] == 0.0 and d8[1, 1] == 1.0


def test_recon_sweep_clamped_by_mask():
    rng = np.random.default_rng(3)
    marker = jnp.asarray(rng.uniform(0, 1, (12, 12)), jnp.float32)
    mask = jnp.asarray(rng.uniform(0, 1, (12, 12)), jnp.float32)
    out = morph.recon_sweep(jnp.minimum(marker, mask), mask, 8.0)
    assert bool(jnp.all(out <= mask))


def test_label_sweep_preserves_labeled_pixels():
    lab = jnp.asarray([[1.0, 0.0], [0.0, 0.0]])
    act = jnp.ones((2, 2))
    out = morph.label_sweep(lab, act, 8.0)
    assert float(out[0, 0]) == 1.0
    assert bool(jnp.all(out == 1.0))  # all active unlabeled adopt the label


def test_label_sweep_respects_active_mask():
    lab = jnp.asarray([[1.0, 0.0], [0.0, 0.0]])
    act = jnp.asarray([[1.0, 0.0], [0.0, 0.0]])
    out = morph.label_sweep(lab, act, 8.0)
    assert float(out.sum()) == 1.0  # inactive pixels never grow


def test_full_reconstruction_fixpoint_matches_oracle_loop():
    rng = np.random.default_rng(4)
    mask = jnp.asarray(rng.uniform(0, 10, (16, 16)), jnp.float32)
    marker = jnp.maximum(mask - 3.0, 0.0)
    from compile import model

    got = model.morph_reconstruct(marker, mask, 8.0)
    want = ref.reconstruct_ref(marker, mask, 8.0)
    _eq(got, want)


@pytest.mark.parametrize("conn", [4.0, 8.0])
def test_reconstruction_bounds(conn):
    rng = np.random.default_rng(5)
    mask = jnp.asarray(rng.uniform(0, 10, (12, 12)), jnp.float32)
    marker = jnp.asarray(rng.uniform(0, 10, (12, 12)), jnp.float32)
    from compile import model

    rec = model.morph_reconstruct(marker, mask, conn)
    assert bool(jnp.all(rec <= mask))
    assert bool(jnp.all(rec >= jnp.minimum(marker, mask)))
    # idempotence: a second sweep at the fixpoint changes nothing
    _eq(morph.recon_sweep(rec, mask, conn), rec)
