//! Live-membership properties of the rendezvous ring (rtfp v6): the
//! whole point of HRW hashing is that membership changes are *minimally
//! disruptive* — a join moves only the keys the new peer wins, a leave
//! moves only the departed peer's keys, and every other assignment is
//! untouched. These are exactly the properties the background handoff
//! drain and hot-prefix replication lean on (a bounded key share moves,
//! so a trickled handoff converges), so they are pinned here over a
//! large key sample and a seed-pinned membership-event sequence.
//!
//! `RTF_MEMBER_SEED=N` pins the sample (CI runs two fixed seeds); the
//! default keeps local runs to one.

use rtf_reuse::cache::{Key, PeerRing};
use rtf_reuse::testutil::splitmix64 as splitmix;

/// Sample size: ≥10k keys gives every peer of a small ring a shard in
/// the thousands, so share assertions are far from noise.
const KEYS: usize = 10_000;

fn seed() -> u64 {
    match std::env::var("RTF_MEMBER_SEED") {
        Ok(v) => v.parse().expect("RTF_MEMBER_SEED must be a u64"),
        Err(_) => 7,
    }
}

/// A deterministic sample of 128-bit keys from the seed's splitmix
/// stream.
fn sample_keys(seed: u64) -> Vec<Key> {
    let mut s = seed;
    (0..KEYS).map(|_| Key::from_parts(splitmix(&mut s), splitmix(&mut s))).collect()
}

fn addrs(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("10.0.0.{i}:7070")).collect()
}

#[test]
fn every_peer_owns_a_substantial_shard_and_replicas_differ_from_owners() {
    let peers = addrs(4);
    let ring = PeerRing::new(&peers, &peers[0]).expect("ring builds");
    let keys = sample_keys(seed());
    let mut shares = vec![0usize; peers.len()];
    for &k in &keys {
        let owner = ring.owner_of(k);
        shares[owner] += 1;
        let replica = ring.replica_of(k).expect("multi-node ring has a replica");
        assert_ne!(replica, owner, "the replica target is never the owner");
    }
    // uniform in expectation: each of 4 peers gets ~2500 of 10k keys;
    // a quarter of the fair share is a generous floor for FNV mixing
    for (i, &share) in shares.iter().enumerate() {
        assert!(
            share > KEYS / peers.len() / 4,
            "peer {i} owns {share} of {KEYS} keys — partition is badly skewed"
        );
    }
}

#[test]
fn a_join_moves_only_the_keys_the_new_peer_wins() {
    let peers = addrs(3);
    let ring = PeerRing::new(&peers, &peers[0]).expect("ring builds");
    let keys = sample_keys(seed());
    let before: Vec<usize> = keys.iter().map(|&k| ring.owner_of(k)).collect();

    let joined = "10.0.0.9:7070";
    let grown = ring.join(joined).expect("join builds a ring");
    let mut moved = 0usize;
    for (i, &k) in keys.iter().enumerate() {
        let new_owner = grown.addr(grown.owner_of(k));
        let old_owner = ring.addr(before[i]);
        if new_owner != old_owner {
            assert_eq!(
                new_owner, joined,
                "key {k:?} moved from {old_owner} to {new_owner} — a join may only move \
                 keys TO the joined peer"
            );
            moved += 1;
        }
    }
    // the newcomer wins its fair share (~1/4) and nothing close to all
    assert!(moved > KEYS / 8, "join moved only {moved} of {KEYS} keys");
    assert!(moved < KEYS / 2, "join moved {moved} of {KEYS} keys — far too disruptive");
}

#[test]
fn a_leave_moves_only_the_departed_peers_keys() {
    let peers = addrs(4);
    let ring = PeerRing::new(&peers, &peers[0]).expect("ring builds");
    let keys = sample_keys(seed());

    let departed = ring.addr(2).to_string();
    let shrunk = ring.leave(&departed);
    assert_eq!(shrunk.peers().len(), 3);
    for &k in &keys {
        let old_owner = ring.addr(ring.owner_of(k)).to_string();
        let new_owner = shrunk.addr(shrunk.owner_of(k)).to_string();
        if old_owner == departed {
            assert_ne!(new_owner, departed, "departed peers own nothing");
        } else {
            assert_eq!(
                new_owner, old_owner,
                "key {k:?} moved although its owner {old_owner} never left"
            );
        }
    }
}

#[test]
fn ring_rebuilds_are_order_insensitive_and_idempotent() {
    let peers = addrs(3);
    let ring = PeerRing::new(&peers, &peers[1]).expect("ring builds");
    // every node fed the same membership (any order) agrees on owners
    let shuffled = vec![peers[2].clone(), peers[0].clone(), peers[1].clone()];
    let other = PeerRing::new(&shuffled, &peers[1]).expect("ring builds");
    for &k in sample_keys(seed()).iter().take(1000) {
        assert_eq!(ring.owner_of(k), other.owner_of(k), "peer order must not matter");
    }
    // re-joining a member and leaving a stranger are both no-ops
    let rejoin = ring.join(&peers[0]).expect("idempotent join");
    assert_eq!(rejoin.peers(), ring.peers());
    let stranger = ring.leave("10.9.9.9:1");
    assert_eq!(stranger.peers(), ring.peers());
    // leaving yourself collapses to a single-node ring, not an error
    let solo = ring.leave(&peers[1]);
    assert_eq!(solo.peers(), [peers[1].clone()]);
    assert_eq!(solo.self_addr(), peers[1]);
}

/// The satellite property: over a seed-pinned *sequence* of membership
/// events, every single step is minimally disruptive — each key either
/// keeps its owner, moves to the peer that joined, or moves because its
/// owner left. Runs the sequence with a tracked owner map so a
/// violation names the exact step.
#[test]
fn a_seedpinned_membership_sequence_is_minimally_disruptive_at_every_step() {
    let mut s = seed() ^ 0xD15B;
    let keys = sample_keys(seed());
    let pool = addrs(8);
    // start from a 3-node ring; the rest of the pool joins/leaves
    let mut ring = PeerRing::new(&pool[..3].to_vec(), &pool[0]).expect("ring builds");
    let mut owners: Vec<String> =
        keys.iter().map(|&k| ring.addr(ring.owner_of(k)).to_string()).collect();

    for step in 0..12 {
        let candidate = &pool[(splitmix(&mut s) % pool.len() as u64) as usize];
        let is_member = ring.peers().iter().any(|p| p == candidate);
        // self never leaves; otherwise flip the candidate's membership
        let (next, joined, left) = if !is_member {
            (ring.join(candidate).expect("join builds"), Some(candidate.clone()), None)
        } else if candidate != ring.self_addr() && ring.peers().len() > 2 {
            (ring.leave(candidate), None, Some(candidate.clone()))
        } else {
            continue;
        };
        for (i, &k) in keys.iter().enumerate() {
            let new_owner = next.addr(next.owner_of(k)).to_string();
            let old_owner = &owners[i];
            if new_owner != *old_owner {
                let to_joiner = joined.as_deref() == Some(new_owner.as_str());
                let from_departed = left.as_deref() == Some(old_owner.as_str());
                assert!(
                    to_joiner || from_departed,
                    "step {step}: key {k:?} moved {old_owner} -> {new_owner}, but the \
                     event was join={joined:?} leave={left:?} — only keys owned by (or \
                     destined to) the changed peer may move"
                );
            }
            owners[i] = new_owner;
        }
        ring = next;
    }
}
