//! Per-tenant quota enforcement under contention, eviction-owner
//! accounting, and disk warm-start — at the cache layer and through the
//! full multi-tenant service.

use std::path::PathBuf;
use std::sync::Arc;

use rtf_reuse::cache::{CacheConfig, CacheCtx, Key, ReuseCache, ScopedCounters};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::data::Plane;
use rtf_reuse::merging::FineAlgorithm;
use rtf_reuse::serve::{ServeOptions, StudyJob, StudyService};

fn state(v: f32) -> [Plane; 3] {
    [Plane::filled(v, 8, 8), Plane::filled(v, 8, 8), Plane::filled(v, 8, 8)]
}

/// Bytes of one `state()`: 3 planes x 64 px x 4 B.
const S: u64 = 3 * 64 * 4;

#[test]
fn quota_holds_under_concurrent_inserts() {
    // four threads hammer one tenant scope with distinct keys; whenever
    // all puts have returned, the tenant is within its quota — over-
    // admission was evicted from its own entries, not anyone else's
    let cache = Arc::new(ReuseCache::with_capacity(1 << 22));
    let tenant = Arc::new(ScopedCounters::with_quota(4 * S));
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let cache = &cache;
            let ctx = CacheCtx::scoped(Arc::clone(&tenant));
            s.spawn(move || {
                for i in 0..32u64 {
                    cache.put_state(Key::from(t * 100 + i), state(t as f32), &ctx);
                }
            });
        }
    });
    assert!(
        tenant.resident_bytes() <= 4 * S,
        "quota exceeded: {} > {}",
        tenant.resident_bytes(),
        4 * S
    );
    // the books balance: the only owner's residency is the cache's
    assert_eq!(tenant.resident_bytes(), cache.resident_bytes() as u64);
    let st = cache.stats();
    assert_eq!(tenant.evictions(), st.evictions, "every eviction was charged to the owner");
    assert_eq!(st.inserts, 128, "distinct keys all count as inserts");
    assert!(st.evictions >= 128 - 4, "over-quota admissions were evicted again");
}

#[test]
fn contended_eviction_charges_the_owning_scope() {
    // two tenants share one shard whose byte bound forces cross-tenant
    // evictions; whatever the interleaving, the owner ledgers balance
    let cache = Arc::new(ReuseCache::new(CacheConfig {
        capacity_bytes: 8 * S as usize,
        shards: 1,
        ..CacheConfig::default()
    }));
    let a = Arc::new(ScopedCounters::default());
    let b = Arc::new(ScopedCounters::default());
    std::thread::scope(|s| {
        for (t, scope) in [(0u64, &a), (1u64, &b)] {
            let cache = &cache;
            let ctx = CacheCtx::scoped(Arc::clone(scope));
            s.spawn(move || {
                for i in 0..64u64 {
                    cache.put_state(Key::from(t * 1000 + i), state(i as f32), &ctx);
                }
            });
        }
    });
    let st = cache.stats();
    assert_eq!(
        a.resident_bytes() + b.resident_bytes(),
        st.resident_bytes,
        "scoped residency partitions the global gauge"
    );
    assert_eq!(
        a.evictions() + b.evictions(),
        st.evictions,
        "every eviction is charged to exactly one owner"
    );
    assert_eq!(a.stats().inserts + b.stats().inserts, st.inserts);
    assert!(st.resident_bytes <= 8 * S, "the shard byte bound held");
}

fn small_cfg() -> StudyConfig {
    StudyConfig {
        method: SaMethod::Moat { r: 1 }, // 16 evaluations
        algorithm: FineAlgorithm::Rtma(7),
        ..StudyConfig::default()
    }
}

fn service_opts() -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        ..ServeOptions::default()
    }
}

#[test]
fn service_enforces_tenant_quotas_end_to_end() {
    // a tight quota (2 MiB ~ a handful of 128x128 states) cannot be
    // exceeded even while a real study hammers the cache; the job still
    // completes, spilling its own LRU entries instead
    let quota: u64 = 2 * 1024 * 1024;
    let opts = ServeOptions { tenant_quota_bytes: Some(quota), ..service_opts() };
    let svc = StudyService::start(opts).expect("service starts");
    svc.submit(StudyJob { tenant: "capped".into(), cfg: small_cfg() }).unwrap();
    let report = svc.drain();
    assert!(report.jobs.iter().all(|j| j.ok()), "jobs: {:?}", report.jobs);
    let t = report.tenant("capped").expect("tenant report");
    assert_eq!(t.quota_bytes, quota);
    assert!(
        t.cache.resident_bytes <= quota,
        "tenant resident {} exceeds its quota {quota}",
        t.cache.resident_bytes
    );
    assert!(t.cache.evictions > 0, "a tight quota must have evicted something");
    // scoped sums still equal the globals with quotas active
    let sums = report.scoped_totals();
    assert_eq!(sums.hits, report.cache.hits);
    assert_eq!(sums.misses, report.cache.misses);
    assert_eq!(sums.inserts, report.cache.inserts);
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rtf-quota-{tag}-{}", std::process::id()))
}

#[test]
fn warm_start_makes_the_first_job_of_a_restarted_service_warm() {
    let dir = temp_dir("warm");
    let _ = std::fs::remove_dir_all(&dir);
    let disk_cache = CacheConfig {
        capacity_bytes: 512 * 1024 * 1024,
        spill_dir: Some(dir.clone()),
        ..CacheConfig::default()
    };

    // day 1: a cold service persists its work to the disk tier
    let opts = ServeOptions { cache: disk_cache.clone(), ..service_opts() };
    let day1 = StudyService::start(opts).expect("service starts");
    day1.submit(StudyJob { tenant: "early".into(), cfg: small_cfg() }).unwrap();
    let cold = day1.drain();
    assert!(cold.jobs[0].ok(), "cold job: {:?}", cold.jobs[0].error);
    assert_eq!(cold.warm.admitted, 0, "warm start was off on day 1");
    assert!(cold.cache.spilled > 0, "the disk tier was populated");

    // day 2: a fresh process warm-starts from the same tier; its first
    // job is served memory hits and pays far fewer launches
    let opts = ServeOptions { cache: disk_cache, warm_start: true, ..service_opts() };
    let day2 = StudyService::start(opts).expect("service restarts");
    assert!(day2.warm_start_report().admitted > 0, "warm start admitted disk entries");
    day2.submit(StudyJob { tenant: "early".into(), cfg: small_cfg() }).unwrap();
    let warm = day2.drain();
    assert!(warm.jobs[0].ok(), "warm job: {:?}", warm.jobs[0].error);
    assert_eq!(warm.warm, day2.warm_start_report());
    assert!(warm.cache.hits > 0, "the first job of the day found memory hits");
    assert!(
        warm.jobs[0].launches < cold.jobs[0].launches,
        "warm-started job must reuse: cold {} vs warm {}",
        cold.jobs[0].launches,
        warm.jobs[0].launches
    );
    // identical study, identical results across the restart
    assert_eq!(cold.jobs[0].y, warm.jobs[0].y);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn persisted_metrics_make_a_warm_rerun_skip_every_comparison_launch() {
    // regression guard for the comparison-metric sidecar (metrics.log):
    // states alone warm-start the *state* tiers, but before metrics were
    // persisted a restarted service re-ran every comparison. Day 2 must
    // serve all of them from the reloaded metric memo.
    let dir = temp_dir("metrics");
    let _ = std::fs::remove_dir_all(&dir);
    let disk_cache = CacheConfig {
        capacity_bytes: 512 * 1024 * 1024,
        spill_dir: Some(dir.clone()),
        ..CacheConfig::default()
    };

    let opts = ServeOptions { cache: disk_cache.clone(), ..service_opts() };
    let day1 = StudyService::start(opts).expect("service starts");
    day1.submit(StudyJob { tenant: "early".into(), cfg: small_cfg() }).unwrap();
    let cold = day1.drain();
    assert!(cold.jobs[0].ok(), "cold job: {:?}", cold.jobs[0].error);
    assert!(cold.cache.metric_misses > 0, "the cold run computed its comparisons");

    let opts = ServeOptions { cache: disk_cache, warm_start: true, ..service_opts() };
    let day2 = StudyService::start(opts).expect("service restarts");
    let boot = day2.warm_start_report();
    assert!(boot.metrics_loaded > 0, "warm start reloaded the persisted metrics");
    day2.submit(StudyJob { tenant: "early".into(), cfg: small_cfg() }).unwrap();
    let warm = day2.drain();
    assert!(warm.jobs[0].ok(), "warm job: {:?}", warm.jobs[0].error);
    assert_eq!(
        warm.cache.metric_misses, 0,
        "a warm rerun must launch zero comparisons (all served from metrics.log)"
    );
    assert!(warm.cache.metric_hits > 0, "the comparisons were served, not skipped");
    assert_eq!(cold.jobs[0].y, warm.jobs[0].y, "persisted metrics are bit-identical");
    let _ = std::fs::remove_dir_all(&dir);
}
