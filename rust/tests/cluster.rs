//! Two-node cluster fabric end to end: real `StudyService`s behind real
//! TCP listeners, the 128-bit key space partitioned by the rendezvous
//! ring, entries exchanged over rtfp v3 `cache-get`/`cache-put`. The
//! properties under test are the ones the cluster mode sells: results
//! are bit-identical to a single node at every batch width, the second
//! node rides the first node's work through remote hits, the scoped
//! ledgers still sum to the globals on every node, and a dead peer
//! degrades to local launches instead of wedging single-flight.

use std::net::TcpListener;
use std::thread;

use rtf_reuse::cache::CacheConfig;
use rtf_reuse::serve::protocol::WireBill;
use rtf_reuse::serve::{run_jobs, JobSpec, ServeOptions, ServiceReport, StudyService, WireServer};

fn study_args(batch_width: usize) -> Vec<String> {
    vec!["method=moat".into(), "r=1".into(), format!("batch-width={batch_width}")]
}

/// Reserve a loopback address the OS just proved free. There is a
/// window between dropping the listener and rebinding, but loopback
/// ephemeral ports make a collision vanishingly unlikely in a test.
fn reserve_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    listener.local_addr().expect("reserved addr").to_string()
}

fn base_opts() -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        ..ServeOptions::default()
    }
}

fn node_opts(peers: &[String], own: &str) -> ServeOptions {
    ServeOptions {
        peers: peers.to_vec(),
        cluster_addr: Some(own.to_string()),
        ..base_opts()
    }
}

/// Start a node's service and listener at `addr` (previously reserved);
/// the handle yields the node's drained report.
fn spawn_node(opts: ServeOptions, addr: &str) -> thread::JoinHandle<ServiceReport> {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds its reserved addr");
    thread::spawn(move || server.run().expect("node drains cleanly"))
}

/// A plain single-node service on an OS-assigned port, as the ground
/// truth the cluster must reproduce bit for bit.
fn spawn_solo() -> (String, thread::JoinHandle<ServiceReport>) {
    let svc = StudyService::start(base_opts()).expect("solo service starts");
    let server = WireServer::bind(svc, "127.0.0.1:0").expect("bind loopback");
    let addr = server.local_addr().expect("bound address").to_string();
    (addr, thread::spawn(move || server.run().expect("solo drains cleanly")))
}

/// Per-tenant scoped counters must sum exactly to the node's globals on
/// every scoped field — including the new `remote_hits`.
fn assert_scoped_sums_match(bill: &WireBill, node: &str) {
    let sums = bill.tenants.iter().fold((0, 0, 0, 0, 0), |acc, t| {
        (
            acc.0 + t.cache.hits,
            acc.1 + t.cache.disk_hits,
            acc.2 + t.cache.remote_hits,
            acc.3 + t.cache.misses,
            acc.4 + t.cache.inserts,
        )
    });
    assert_eq!(sums.0, bill.cache.hits, "{node}: scoped hits partition the globals");
    assert_eq!(sums.1, bill.cache.disk_hits, "{node}: scoped disk hits partition the globals");
    assert_eq!(sums.2, bill.cache.remote_hits, "{node}: scoped remote hits partition the globals");
    assert_eq!(sums.3, bill.cache.misses, "{node}: scoped misses partition the globals");
    assert_eq!(sums.4, bill.cache.inserts, "{node}: scoped inserts partition the globals");
}

#[test]
fn two_nodes_match_single_node_results_and_the_second_rides_remote_hits() {
    for width in [1usize, 16] {
        let args = study_args(width);

        // ground truth: the same study on a plain single node
        let (solo_addr, solo) = spawn_solo();
        let spec = JobSpec { tenant: "solo".into(), args: args.clone(), tune: false };
        let baseline = run_jobs(&solo_addr, &[spec], true).expect("solo run succeeds");
        assert!(baseline.jobs[0].ok(), "solo job: {:?}", baseline.jobs[0].error);
        solo.join().expect("solo joins");

        // the cluster: two nodes, each told the full peer list
        let addr_a = reserve_addr();
        let addr_b = reserve_addr();
        let peers = vec![addr_a.clone(), addr_b.clone()];
        let node_a = spawn_node(node_opts(&peers, &addr_a), &addr_a);
        let node_b = spawn_node(node_opts(&peers, &addr_b), &addr_b);

        // the cold run on A computes everything; its write-through
        // publishes B-owned entries to B over cache-put
        let spec = JobSpec { tenant: "cold".into(), args: args.clone(), tune: false };
        let out_a = run_jobs(&addr_a, &[spec], false).expect("run on node A succeeds");
        assert!(out_a.jobs[0].ok(), "node A job: {:?}", out_a.jobs[0].error);

        // the same study on B: B-owned keys are already resident (A
        // pushed them), A-owned keys come back over cache-get — B must
        // not recompute state anywhere. A stays up to serve its shard.
        let spec = JobSpec { tenant: "warm".into(), args, tune: false };
        let out_b = run_jobs(&addr_b, &[spec], false).expect("run on node B succeeds");
        assert!(out_b.jobs[0].ok(), "node B job: {:?}", out_b.jobs[0].error);

        // bit-identical across 1-node and 2-node at this batch width
        assert_eq!(baseline.jobs[0].y, out_a.jobs[0].y, "width {width}: node A matches solo");
        assert_eq!(baseline.jobs[0].y, out_b.jobs[0].y, "width {width}: node B matches solo");

        // the headline economy: B launched strictly less than A's cold
        // run because the fabric served it A's states
        assert!(
            out_b.jobs[0].launches < out_a.jobs[0].launches,
            "width {width}: node B must ride the fabric: A {} vs B {}",
            out_a.jobs[0].launches,
            out_b.jobs[0].launches
        );

        // drain B first (it depends on A's shard), then A
        let bill_b = run_jobs(&addr_b, &[], true)
            .expect("drain B")
            .bill
            .expect("B's bill");
        let bill_a = run_jobs(&addr_a, &[], true)
            .expect("drain A")
            .bill
            .expect("A's bill");
        node_a.join().expect("node A joins");
        node_b.join().expect("node B joins");

        assert!(
            bill_b.cache.remote_hits > 0,
            "width {width}: node B's bill must show remote hits"
        );
        assert_scoped_sums_match(&bill_a, "node A");
        assert_scoped_sums_match(&bill_b, "node B");
    }
}

#[test]
fn a_dead_peer_degrades_to_local_launches_without_wedging_single_flight() {
    // ground truth from a plain single node
    let (solo_addr, solo) = spawn_solo();
    let spec = JobSpec { tenant: "solo".into(), args: study_args(16), tune: false };
    let baseline = run_jobs(&solo_addr, &[spec], true).expect("solo run succeeds");
    solo.join().expect("solo joins");

    // one live node clustered with a peer that never comes up: every
    // remote lookup fails fast, falls through to a local launch, and the
    // local single-flight claims settle normally — the study completes
    // with identical results and an all-local bill
    let own = reserve_addr();
    let dead = reserve_addr(); // nothing ever listens here
    let peers = vec![own.clone(), dead];
    let node = spawn_node(node_opts(&peers, &own), &own);
    let spec = JobSpec { tenant: "lone".into(), args: study_args(16), tune: false };
    let out = run_jobs(&own, &[spec], true).expect("run with a dead peer succeeds");
    node.join().expect("node joins");

    assert!(out.jobs[0].ok(), "job: {:?}", out.jobs[0].error);
    assert_eq!(baseline.jobs[0].y, out.jobs[0].y, "dead peer never changes results");
    let bill = out.bill.expect("bill");
    assert_eq!(bill.cache.remote_hits, 0, "a dead peer serves nothing");
    assert!(bill.cache.misses > 0, "the work happened locally");
    assert_scoped_sums_match(&bill, "lone node");
}
