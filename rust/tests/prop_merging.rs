//! Property-based sweep over the merging algorithms (hand-rolled driver
//! — proptest is not vendored): random stage populations through every
//! algorithm, checking the paper's structural invariants.

use rtf_reuse::data::SplitMix64;
use rtf_reuse::merging::reuse_tree::ReuseTree;
use rtf_reuse::merging::{
    naive_merge, reuse_fraction, rtma_merge, sca_merge, trtma_merge, unique_tasks, Bucket,
    MergeStage, TrtmaOptions,
};

/// Random family-structured population: `n` stages of `k` tasks whose
/// prefixes follow a random tree (the shape SA studies produce).
fn population(rng: &mut SplitMix64, n: usize, k: usize) -> Vec<MergeStage> {
    let families = rng.uniform_usize(1, (n / 2).max(2)) as u64;
    (0..n)
        .map(|i| {
            let fam = rng.next_u64() % families;
            let mut path = Vec::with_capacity(k);
            let mut acc = fam + 1;
            for level in 0..k {
                // deeper levels diverge with growing probability
                let spread = 1 + level as u64 * 3;
                acc = acc
                    .wrapping_mul(31)
                    .wrapping_add(rng.next_u64() % (spread * families));
                path.push(acc);
            }
            MergeStage::new(i, path)
        })
        .collect()
}

fn check_partition(n: usize, buckets: &[Bucket], ctx: &str) {
    let mut seen = vec![false; n];
    for b in buckets {
        assert!(!b.is_empty(), "{ctx}: empty bucket");
        for &m in &b.members {
            assert!(m < n, "{ctx}: member out of range");
            assert!(!seen[m], "{ctx}: stage {m} in two buckets");
            seen[m] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "{ctx}: stage not bucketed");
}

#[test]
fn all_algorithms_produce_valid_partitions() {
    let mut rng = SplitMix64::new(0xA11A);
    for case in 0..60 {
        let n = rng.uniform_usize(1, 80);
        let k = rng.uniform_usize(1, 9);
        let mbs = rng.uniform_usize(1, 12);
        let stages = population(&mut rng, n, k);

        for (name, buckets) in [
            ("naive", naive_merge(&stages, mbs)),
            ("rtma", rtma_merge(&stages, mbs)),
            ("sca", sca_merge(&stages, mbs)),
            ("trtma", trtma_merge(&stages, TrtmaOptions::new(mbs))),
        ] {
            let ctx = format!("case {case} ({name}, n={n}, k={k}, mbs={mbs})");
            check_partition(n, &buckets, &ctx);
            let r = reuse_fraction(&stages, &buckets);
            assert!((0.0..1.0).contains(&r), "{ctx}: reuse {r}");
            if name == "naive" || name == "sca" {
                assert!(buckets.iter().all(|b| b.len() <= mbs), "{ctx}: oversize bucket");
            }
        }
    }
}

#[test]
fn merged_task_cost_bounded_by_tree_and_replica() {
    let mut rng = SplitMix64::new(0xBEE);
    for _ in 0..40 {
        let n = rng.uniform_usize(2, 60);
        let k = rng.uniform_usize(2, 8);
        let mbs = rng.uniform_usize(2, 10);
        let stages = population(&mut rng, n, k);
        let replica: usize = stages.iter().map(|s| s.path.len()).sum();
        let tree_min = ReuseTree::build(&stages).unique_task_count();

        for buckets in [
            naive_merge(&stages, mbs),
            rtma_merge(&stages, mbs),
            sca_merge(&stages, mbs),
            trtma_merge(&stages, TrtmaOptions::new(mbs)),
        ] {
            let merged: usize =
                buckets.iter().map(|b| unique_tasks(&stages, &b.members)).sum();
            assert!(merged <= replica, "merging may never add work");
            assert!(
                merged >= tree_min,
                "no bucketing beats the full reuse tree ({merged} < {tree_min})"
            );
        }
    }
}

#[test]
fn trtma_respects_bucket_count_and_never_worse_than_one_bucket_split() {
    let mut rng = SplitMix64::new(0xC0DE);
    for _ in 0..30 {
        let n = rng.uniform_usize(4, 50);
        let k = rng.uniform_usize(2, 6);
        let stages = population(&mut rng, n, k);
        let mb = rng.uniform_usize(1, 8);
        let buckets = trtma_merge(&stages, TrtmaOptions::new(mb));
        check_partition(n, &buckets, "trtma");
        assert!(
            buckets.len() <= mb.max(1),
            "trtma exceeded MaxBuckets: {} > {mb}",
            buckets.len()
        );
    }
}

#[test]
fn rtma_quality_dominates_naive_on_shuffled_order() {
    // the naive algorithm is order-dependent; after shuffling, RTMA must
    // match or beat it in the vast majority of cases (paper §4.2.1)
    let mut rng = SplitMix64::new(0xD1CE);
    let mut rtma_wins = 0usize;
    let cases = 30;
    for _ in 0..cases {
        let n = rng.uniform_usize(10, 60);
        let k = rng.uniform_usize(2, 7);
        let mbs = rng.uniform_usize(2, 8);
        let mut stages = population(&mut rng, n, k);
        // shuffle (Fisher–Yates) and re-id
        for i in (1..stages.len()).rev() {
            let j = rng.uniform_usize(0, i + 1);
            stages.swap(i, j);
        }
        for (i, s) in stages.iter_mut().enumerate() {
            s.id = i;
        }
        let r_naive = reuse_fraction(&stages, &naive_merge(&stages, mbs));
        let r_rtma = reuse_fraction(&stages, &rtma_merge(&stages, mbs));
        if r_rtma >= r_naive - 1e-12 {
            rtma_wins += 1;
        }
    }
    assert!(
        rtma_wins * 10 >= cases * 9,
        "rtma must dominate shuffled naive in >=90% of cases ({rtma_wins}/{cases})"
    );
}

#[test]
fn duplicate_stages_always_merge_for_free() {
    // identical paths cost exactly one chain regardless of algorithm
    // bucketing, as long as duplicates land in one bucket — guaranteed
    // for rtma/trtma by tree construction
    let mut rng = SplitMix64::new(0xF00D);
    for _ in 0..20 {
        let k = rng.uniform_usize(1, 6);
        let dup = rng.uniform_usize(2, 6);
        let path: Vec<u64> = (0..k).map(|_| rng.next_u64()).collect();
        let stages: Vec<MergeStage> =
            (0..dup).map(|i| MergeStage::new(i, path.clone())).collect();
        let buckets = rtma_merge(&stages, dup);
        check_partition(dup, &buckets, "dups");
        assert_eq!(buckets.len(), 1);
        assert_eq!(unique_tasks(&stages, &buckets[0].members), k);
    }
}

#[test]
fn single_task_stages_degenerate_gracefully() {
    let mut rng = SplitMix64::new(0x51);
    let stages: Vec<MergeStage> =
        (0..20).map(|i| MergeStage::new(i, vec![rng.next_u64() % 4])).collect();
    for buckets in [
        naive_merge(&stages, 5),
        rtma_merge(&stages, 5),
        sca_merge(&stages, 5),
        trtma_merge(&stages, TrtmaOptions::new(4)),
    ] {
        check_partition(20, &buckets, "k=1");
    }
}
