//! Telemetry end to end (rtfp v7): real `StudyService`s behind real TCP
//! listeners, each streaming structured spans to a `trace=FILE` sink.
//! The properties under test are the ones `docs/OBSERVABILITY.md`
//! sells: a routed job's spans — emitted on two different nodes —
//! stitch into ONE tree under a single stable trace id (the front
//! door's route span is the root, the executing node's job span its
//! child, owner-side serve spans parent under the requester's lookup
//! spans), every parent link resolves (no orphans), span counts match
//! the billed launch/retry counts, per-tenant metric scopes partition
//! the globals, and a dead peer (breaker opening mid-study) never
//! produces a malformed trace.

use std::collections::HashMap;
use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;

use rtf_reuse::cache::CacheConfig;
use rtf_reuse::config::StudyConfig;
use rtf_reuse::faults::{FaultPlan, Faults};
use rtf_reuse::obs::{parse_event, span, ObsSnapshot, TraceLine};
use rtf_reuse::serve::{
    run_jobs, JobSpec, ServeOptions, ServiceReport, StudyService, WireServer,
};

/// Mirror of `server::ROUTE_BASE`: a client-visible id at or past this
/// mark proves the job was routed.
const ROUTE_BASE: u64 = 1 << 32;

/// batch-width=1 pins one backend call per launch span AND per billed
/// launch, so the two counts must agree exactly.
fn study_args(seed: u64) -> Vec<String> {
    vec!["method=moat".into(), "r=1".into(), "batch-width=1".into(), format!("seed={seed}")]
}

fn reserve_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    listener.local_addr().expect("reserved addr").to_string()
}

fn trace_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rtf-obs-{tag}-{}.jsonl", std::process::id()))
}

fn node_opts(peers: &[String], own: &str, trace: &PathBuf) -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        peers: peers.to_vec(),
        cluster_addr: Some(own.to_string()),
        trace: Some(trace.to_string_lossy().into_owned()),
        ..ServeOptions::default()
    }
}

fn spawn_node(
    opts: ServeOptions,
    addr: &str,
) -> (Arc<StudyService>, thread::JoinHandle<ServiceReport>) {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds its reserved addr");
    let svc = Arc::clone(server.service());
    (svc, thread::spawn(move || server.run().expect("node drains cleanly")))
}

fn read_trace(path: &PathBuf) -> Vec<TraceLine> {
    let text = std::fs::read_to_string(path).expect("trace file exists after drain");
    text.lines()
        .map(|l| parse_event(l).unwrap_or_else(|e| panic!("unparseable trace line `{l}`: {e}")))
        .collect()
}

/// Every span of `trace_id` across both nodes must form one tree:
/// exactly one root, every parent link resolving to a span in the set.
/// Returns the events of that trace keyed by span id.
fn assert_one_tree(all: &[TraceLine], trace_id: u128) -> HashMap<u64, TraceLine> {
    let events: HashMap<u64, TraceLine> = all
        .iter()
        .filter(|l| l.event.trace == trace_id)
        .map(|l| (l.event.span, l.clone()))
        .collect();
    assert!(!events.is_empty(), "trace {trace_id:032x} has no spans");
    let roots: Vec<&TraceLine> =
        events.values().filter(|l| l.event.parent.is_none()).collect();
    assert_eq!(
        roots.len(),
        1,
        "trace {trace_id:032x} must have exactly one root, got {roots:?}"
    );
    for l in events.values() {
        if let Some(p) = l.event.parent {
            assert!(
                events.contains_key(&p),
                "orphan span: {:?} parents {p:016x}, which no node emitted",
                l.event
            );
        }
    }
    events
}

/// Per-tenant counter scopes must sum exactly to the globals, and the
/// job-wall histogram (tenant-attributed at record time) likewise.
fn assert_counters_partition(snap: &ObsSnapshot, node: &str) {
    for (name, global) in &snap.global.counters {
        let sum: u64 = snap
            .tenants
            .iter()
            .map(|(_, m)| m.counters.iter().find(|(n, _)| n == name).map_or(0, |(_, v)| *v))
            .sum();
        assert_eq!(sum, *global, "{node}: tenant scopes must partition counter `{name}`");
    }
    let global_wall = snap.global.hists.iter().find(|h| h.name == "job_wall_us");
    if let Some(g) = global_wall {
        let sum: u64 = snap
            .tenants
            .iter()
            .flat_map(|(_, m)| m.hists.iter().filter(|h| h.name == "job_wall_us"))
            .map(|h| h.count)
            .sum();
        assert_eq!(sum, g.count, "{node}: tenant scopes must partition the job-wall histogram");
    }
}

/// The headline property: a submit through the front door executes on
/// the owning peer, and the spans the two nodes emit — route on the
/// router; job, admit, queue, schedule, levels, launches, lookups on
/// the owner; serve-gets back on the router for the keys it owns —
/// stitch into one tree under one stable trace id, with launch spans
/// equal to the billed launch count.
#[test]
fn a_routed_job_stitches_into_one_cross_node_span_tree() {
    let addrs: Vec<String> = (0..2).map(|_| reserve_addr()).collect();
    let traces: Vec<PathBuf> =
        (0..2).map(|i| trace_path(&format!("routed-{i}"))).collect();
    let nodes: Vec<_> = addrs
        .iter()
        .zip(&traces)
        .map(|(a, t)| {
            let opts = ServeOptions { route: true, ..node_opts(&addrs, a, t) };
            spawn_node(opts, a)
        })
        .collect();

    // exactly one node predicts itself as the owner; the other is the
    // front door this test submits through
    let args = study_args(42);
    let cfg = StudyConfig::from_args(&args).expect("study parses");
    let predictions: Vec<Option<String>> =
        nodes.iter().map(|n| n.0.predict_route(&cfg)).collect();
    let owner = predictions
        .iter()
        .position(|p| p.is_none())
        .expect("one node owns the key plurality");
    let router = 1 - owner;
    assert_eq!(
        predictions[router].as_deref(),
        Some(addrs[owner].as_str()),
        "the router must name the owner"
    );

    let spec = JobSpec { tenant: "traced".into(), args, tune: false };
    let out = run_jobs(&addrs[router], &[spec], false).expect("routed submit succeeds");
    assert!(out.jobs[0].ok(), "routed job: {:?}", out.jobs[0].error);
    assert!(out.jobs[0].job >= ROUTE_BASE, "the job must actually be routed");
    let billed_launches = out.jobs[0].launches;
    assert_eq!(out.jobs[0].retries, 0, "fault-free run retries nothing");

    // drain both nodes (drain flushes each node's trace sink)
    let bill_owner =
        run_jobs(&addrs[owner], &[], true).expect("drain owner").bill.expect("bill");
    run_jobs(&addrs[router], &[], true).expect("drain router");
    let owner_svc = Arc::clone(&nodes[owner].0);
    let router_svc = Arc::clone(&nodes[router].0);
    for (_, handle) in nodes {
        handle.join().expect("node joins");
    }

    let mut all = read_trace(&traces[router]);
    let owner_lines = read_trace(&traces[owner]);
    all.extend(owner_lines);

    // the router emitted exactly one route span; its trace id is the
    // stable id the whole cross-node tree lives under
    let routes: Vec<&TraceLine> =
        all.iter().filter(|l| l.event.kind == span::ROUTE).collect();
    assert_eq!(routes.len(), 1, "one routed submit, one route span");
    let route = routes[0].clone();
    let trace_id = route.event.trace;

    let tree = assert_one_tree(&all, trace_id);
    assert!(tree[&route.event.span].event.parent.is_none(), "the route span is the root");

    // exactly one job span, emitted by the OWNER, child of the route span
    let jobs: Vec<&TraceLine> =
        tree.values().filter(|l| l.event.kind == span::JOB).collect();
    assert_eq!(jobs.len(), 1, "one job root per job");
    assert_eq!(jobs[0].event.parent, Some(route.event.span), "cross-node parent link");
    assert_ne!(jobs[0].node, route.node, "the job ran on the other node");
    assert_eq!(jobs[0].event.tenant, "traced");

    let count = |kind: &str| tree.values().filter(|l| l.event.kind == kind).count() as u64;
    assert_eq!(count(span::ADMIT), 1, "one admit span");
    assert_eq!(count(span::QUEUE), 1, "one queue span");
    assert_eq!(count(span::SCHEDULE), 1, "one attempt, one schedule span");
    assert_eq!(count(span::RETRY), 0, "no retries, no retry spans");
    assert!(count(span::LEVEL) > 0, "frontier levels are spanned");
    assert!(count(span::LOOKUP) > 0, "lower-tier lookups are spanned");
    assert_eq!(
        count(span::LAUNCH),
        billed_launches,
        "at batch-width=1, launch spans must equal the billed launches"
    );

    // owner-side work crossed back: the router served cache-gets for
    // the keys it owns, each span parenting under an owner-side lookup
    let serves: Vec<&TraceLine> =
        tree.values().filter(|l| l.event.kind == span::SERVE_GET).collect();
    assert!(!serves.is_empty(), "a two-node cold study must cross the fabric");
    for s in &serves {
        assert_eq!(s.node, route.node, "serve-get spans are emitted by the serving node");
        let parent = &tree[&s.event.parent.expect("serve spans are never roots")];
        assert_eq!(parent.event.kind, span::LOOKUP, "serve-gets nest under lookups");
        assert_ne!(parent.node, s.node, "…emitted by the requesting node");
    }

    // the registry partitions per tenant on both nodes, and the drain
    // bill carries the per-tier rows (rtfp v7 satellite)
    assert_counters_partition(&owner_svc.stats_snapshot().snapshot, "owner");
    assert_counters_partition(&router_svc.stats_snapshot().snapshot, "router");
    assert!(
        bill_owner.tiers.iter().any(|t| t.tier == "memory" && t.stats.stores > 0),
        "the owner's bill must carry per-tier rows: {:?}",
        bill_owner.tiers
    );

    for t in traces {
        let _ = std::fs::remove_file(t);
    }
}

/// Retries under fault injection: a worker panic fails the first
/// attempt, the retry completes the job — and the trace shows exactly
/// that, with one retry span per billed retry, one schedule span per
/// attempt, and the whole thing still a single tree.
#[test]
fn a_retried_job_traces_every_attempt_and_matches_the_billed_retry_count() {
    let addr = reserve_addr();
    let trace = trace_path("retry");
    let plan = FaultPlan::new().panic_on_launch(2);
    let opts = ServeOptions {
        faults: Faults::hooked(plan.clone()),
        ..node_opts(&[], &addr, &trace)
    };
    let (svc, handle) = spawn_node(opts, &addr);

    let spec = JobSpec { tenant: "bumpy".into(), args: study_args(42), tune: false };
    let out = run_jobs(&addr, &[spec], true).expect("run succeeds");
    handle.join().expect("node joins");
    assert!(out.jobs[0].ok(), "the retry absorbs the panic: {:?}", out.jobs[0].error);
    assert_eq!(out.jobs[0].retries, 1, "the panicked attempt is billed as one retry");
    assert_eq!(plan.fired().launch_panics, 1, "the scripted panic fired");

    let all = read_trace(&trace);
    let job_root = all
        .iter()
        .find(|l| l.event.kind == span::JOB)
        .expect("the job span was emitted");
    assert!(job_root.event.parent.is_none(), "an unrouted job's root is the job span");
    let tree = assert_one_tree(&all, job_root.event.trace);

    let count = |kind: &str| tree.values().filter(|l| l.event.kind == kind).count() as u64;
    assert_eq!(count(span::RETRY), out.jobs[0].retries, "one retry span per billed retry");
    assert_eq!(count(span::SCHEDULE), out.jobs[0].retries + 1, "one schedule span per attempt");
    // the failed attempt's work is traced too, so launch spans can only
    // exceed the (successful-attempt) billed count
    assert!(
        count(span::LAUNCH) >= out.jobs[0].launches,
        "launch spans cover the lost attempt as well"
    );

    let snap = svc.stats_snapshot().snapshot;
    assert_counters_partition(&snap, "retry node");
    assert_eq!(snap.global.counter("retries"), 1, "the registry counted the retry");
    assert_eq!(snap.global.counter("jobs_completed"), 1);
    let backoff = snap.global.hist("retry_backoff_us").expect("retry-backoff histogram");
    assert_eq!(backoff.count, 1, "one backoff observation per retry");
    let wall = snap.global.hist("job_wall_us").expect("job-wall histogram");
    assert_eq!(wall.count, 1, "one job, one wall sample");

    let _ = std::fs::remove_file(trace);
}

/// A peer dying mid-cluster opens the circuit breaker on the survivor —
/// and the survivor's trace stays well-formed through the failed remote
/// lookups, while the breaker transition lands on the drain bill's
/// per-tier rows and the stats surface.
#[test]
fn a_dead_peer_opens_the_breaker_without_malforming_the_survivors_trace() {
    let addrs: Vec<String> = (0..2).map(|_| reserve_addr()).collect();
    let traces: Vec<PathBuf> = (0..2).map(|i| trace_path(&format!("breaker-{i}"))).collect();
    let nodes: Vec<_> = addrs
        .iter()
        .zip(&traces)
        .map(|(a, t)| spawn_node(node_opts(&addrs, a, t), a))
        .collect();

    // a cold study on the survivor warms its local shard (and B's)
    let spec = JobSpec { tenant: "cold".into(), args: study_args(42), tune: false };
    let out = run_jobs(&addrs[0], &[spec], false).expect("cold run succeeds");
    assert!(out.jobs[0].ok(), "cold job: {:?}", out.jobs[0].error);

    // kill node 1; its shard dies with it (no replicas configured)
    let mut nodes = nodes;
    let (dead_svc, dead_handle) = nodes.pop().expect("node 1");
    let (survivor_svc, survivor_handle) = nodes.pop().expect("node 0");
    assert!(run_jobs(&addrs[1], &[], true).expect("drain peer").bill.is_some());
    dead_handle.join().expect("peer joins");
    drop(dead_svc);

    // a DIFFERENT study (fresh keys): lookups for the dead peer's half
    // of the key space dial, fail, and trip the per-address breaker —
    // the job completes by relaunching locally
    let spec = JobSpec { tenant: "probe".into(), args: study_args(43), tune: false };
    let out = run_jobs(&addrs[0], &[spec], false).expect("probe run succeeds");
    assert!(out.jobs[0].ok(), "a dead peer never fails a job: {:?}", out.jobs[0].error);

    let bill = run_jobs(&addrs[0], &[], true).expect("drain survivor").bill.expect("bill");
    survivor_handle.join().expect("survivor joins");

    let remote = bill
        .tiers
        .iter()
        .find(|t| t.tier == "remote")
        .expect("a clustered node bills its remote tier");
    assert!(
        remote.stats.breaker_opens >= 1,
        "the dead peer must trip the breaker: {:?}",
        remote.stats
    );
    assert_eq!(
        survivor_svc.tier_stats().iter().find(|(t, _)| t == "remote").expect("remote tier").1
            .breaker_opens,
        remote.stats.breaker_opens,
        "the stats surface and the bill agree on breaker transitions"
    );

    // both jobs' traces are complete trees despite the failed lookups
    let all = read_trace(&traces[0]);
    let job_roots: Vec<&TraceLine> =
        all.iter().filter(|l| l.event.kind == span::JOB).collect();
    assert_eq!(job_roots.len(), 2, "two jobs, two job roots");
    for root in job_roots {
        assert!(root.event.parent.is_none());
        assert_one_tree(&all, root.event.trace);
    }

    for t in traces {
        let _ = std::fs::remove_file(t);
    }
}
