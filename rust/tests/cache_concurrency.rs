//! The shared cache under contention — the properties the multi-tenant
//! service stands on: no lost updates when many threads hammer one
//! `ReuseCache`, the byte bound honored under concurrent insertion,
//! 128-bit keys separating chains that collide at 64 bits, and
//! single-flight claims collapsing concurrent identical misses into one
//! computation.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use rtf_reuse::cache::{CacheConfig, CacheCtx, Key, ReuseCache, ScopedCounters, StateClaim};
use rtf_reuse::data::Plane;

fn state(v: f32) -> [Plane; 3] {
    [Plane::filled(v, 8, 8), Plane::filled(v, 8, 8), Plane::filled(v, 8, 8)]
}

/// Unscoped accounting context (global counters only).
fn ux() -> CacheCtx {
    CacheCtx::unscoped()
}

/// Bytes of one `state(v)`: 3 planes x 64 px x 4 B.
const SB: usize = 3 * 64 * 4;

#[test]
fn hammering_threads_lose_no_updates() {
    // 8 threads race get/put over 64 fully shared keys; capacity is
    // ample, so after the storm every key must be present with exactly
    // the payload its key encodes — no lost updates, no cross-key
    // corruption, every lookup counted.
    let cache = Arc::new(ReuseCache::new(CacheConfig {
        capacity_bytes: 1 << 22,
        shards: 4,
        ..CacheConfig::default()
    }));
    let threads = 8usize;
    let keys = 64u64;
    let rounds = 4u64;
    std::thread::scope(|scope| {
        for t in 0..threads as u64 {
            let cache = &cache;
            scope.spawn(move || {
                for r in 0..rounds {
                    for i in 0..keys {
                        // interleave access order differently per thread
                        let i = (i + t * 7 + r * 13) % keys;
                        let key = Key::from_parts(0xC0FFEE, i);
                        match cache.get_state(key, &ux()) {
                            Some(got) => assert_eq!(
                                got[0].get(0, 0),
                                i as f32,
                                "cross-key corruption on {i}"
                            ),
                            None => cache.put_state(key, state(i as f32), &ux()),
                        }
                    }
                }
            });
        }
    });
    for i in 0..keys {
        let got = cache.get_state(Key::from_parts(0xC0FFEE, i), &ux()).expect("no lost update");
        assert_eq!(got[0].get(0, 0), i as f32);
    }
    let st = cache.stats();
    assert_eq!(
        st.hits + st.disk_hits + st.misses,
        threads as u64 * keys * rounds + keys,
        "every lookup is counted exactly once"
    );
    assert_eq!(st.evictions, 0, "ample capacity: nothing evicted");
}

#[test]
fn byte_bound_holds_under_concurrent_insertion() {
    // tight budget (4 states per shard, 2 shards), 8 threads inserting
    // 256 distinct keys: the resident total must settle within the
    // configured capacity and the eviction counter must account for
    // exactly the overflow
    let cache = Arc::new(ReuseCache::new(CacheConfig {
        capacity_bytes: 8 * SB,
        shards: 2,
        ..CacheConfig::default()
    }));
    std::thread::scope(|scope| {
        for t in 0..8u64 {
            let cache = &cache;
            scope.spawn(move || {
                for i in 0..32u64 {
                    let key = Key::from_parts(t, i);
                    cache.put_state(key, state((t * 32 + i) as f32), &ux());
                }
            });
        }
    });
    let st = cache.stats();
    assert!(
        cache.resident_bytes() <= 8 * SB,
        "byte bound violated: {} > {}",
        cache.resident_bytes(),
        8 * SB
    );
    assert_eq!(st.inserts, 256, "every distinct key inserted once");
    assert_eq!(
        st.inserts - st.evictions,
        cache.len() as u64,
        "evictions account exactly for the overflow"
    );
    // whatever survived is uncorrupted
    for key in cache.resident_keys() {
        let got = cache.get_state(key, &ux()).expect("resident key readable");
        assert_eq!(got[0].get(0, 0), (key.hi() * 32 + key.lo()) as f32);
    }
}

#[test]
fn chains_that_collide_at_64_bits_no_longer_alias() {
    // THE widening regression test. Before the 128-bit migration the
    // store keyed on u64: two distinct computations whose truncated keys
    // matched were ONE entry — the second publisher silently poisoned
    // the first chain's state, and lookups served wrong pixels as
    // plausible hits. Construct exactly that collision (equal low
    // halves) and prove the widened store keeps the chains apart.
    let cache = ReuseCache::with_capacity(1 << 20);
    let chain_a = Key::from_parts(0x1111_2222_3333_4444, 0xfeed_beef);
    let chain_b = Key::from_parts(0x5555_6666_7777_8888, 0xfeed_beef);
    assert_eq!(chain_a.lo(), chain_b.lo(), "64-bit views collide by construction");
    assert_ne!(chain_a, chain_b, "128-bit keys distinguish the chains");

    cache.put_state(chain_a, state(1.0), &ux());
    cache.put_state(chain_b, state(2.0), &ux());
    assert_eq!(cache.len(), 2, "two chains, two entries — no aliasing");
    assert_eq!(cache.get_state(chain_a, &ux()).unwrap()[0].get(0, 0), 1.0);
    assert_eq!(cache.get_state(chain_b, &ux()).unwrap()[0].get(0, 0), 2.0);

    // and the derivation feeds the width: real chain keys disperse into
    // both halves, so distinct task histories cannot recreate the old
    // truncated collision by construction
    use rtf_reuse::cache::chain_key;
    let x = chain_key(Key::from(7u64), 1);
    let y = chain_key(Key::from(7u64), 2);
    assert_ne!(x.lo(), y.lo());
    assert_ne!(x.hi(), y.hi());
    assert_ne!(x.hi(), 0);
}

#[test]
fn single_flight_collapses_concurrent_identical_misses() {
    // 8 threads demand the same key at once. Exactly one claims and
    // "computes" (slowly); the rest observe the flight, wait, and are
    // served the published state. Computations == 1 is the property the
    // multi-tenant launch bound rests on.
    let cache = Arc::new(ReuseCache::with_capacity(1 << 20));
    let key = Key::from(0xABCDu64);
    let computes = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for _ in 0..8 {
            let cache = &cache;
            let computes = &computes;
            scope.spawn(move || loop {
                match cache.lookup_or_claim(key, &ux()) {
                    StateClaim::Ready(got) => {
                        assert_eq!(got[1].get(3, 3), 42.0);
                        return;
                    }
                    StateClaim::Claimed => {
                        computes.fetch_add(1, Ordering::Relaxed);
                        // a deliberately slow compute: waiters must block,
                        // not spin into their own claims
                        std::thread::sleep(Duration::from_millis(50));
                        cache.put_state(key, state(42.0), &ux());
                        return;
                    }
                    StateClaim::InFlight => cache.wait_for_flight(key),
                }
            });
        }
    });
    assert_eq!(computes.load(Ordering::Relaxed), 1, "exactly one computation");
    let st = cache.stats();
    assert_eq!(st.misses, 1, "one claim = one counted miss");
    assert_eq!(st.hits, 7, "everyone else was served");
}

#[test]
fn abandoned_flights_recover() {
    // an owner that fails without publishing must not wedge the key:
    // release wakes the waiter, which re-claims and completes
    let cache = Arc::new(ReuseCache::with_capacity(1 << 20));
    let key = Key::from(0x5105u64);
    assert!(matches!(cache.lookup_or_claim(key, &ux()), StateClaim::Claimed));
    let waiter = {
        let cache = Arc::clone(&cache);
        std::thread::spawn(move || loop {
            match cache.lookup_or_claim(key, &ux()) {
                StateClaim::Ready(got) => return got[0].get(0, 0),
                StateClaim::Claimed => {
                    cache.put_state(key, state(7.0), &ux());
                    // continue looping: the next lookup serves Ready
                }
                StateClaim::InFlight => cache.wait_for_flight(key),
            }
        })
    };
    std::thread::sleep(Duration::from_millis(20));
    cache.release_flight(key); // the simulated error path
    assert_eq!(waiter.join().expect("waiter completes"), 7.0);
}

#[test]
fn scoped_tenants_partition_the_global_counters_under_contention() {
    // two "tenants" hammer overlapping keys concurrently; whatever the
    // interleaving, the per-tenant scopes must sum exactly to the
    // global counters on every scoped field
    let cache = Arc::new(ReuseCache::with_capacity(1 << 22));
    let scopes = [Arc::new(ScopedCounters::default()), Arc::new(ScopedCounters::default())];
    std::thread::scope(|s| {
        for (t, scope) in scopes.iter().enumerate() {
            let cache = &cache;
            let ctx = CacheCtx::scoped(Arc::clone(scope));
            s.spawn(move || {
                for i in 0..64u64 {
                    let key = Key::from(i % 48); // overlapping ranges
                    match cache.lookup_or_claim(key, &ctx) {
                        StateClaim::Ready(_) => {}
                        StateClaim::Claimed => cache.put_state(key, state(t as f32), &ctx),
                        StateClaim::InFlight => {
                            cache.wait_for_flight(key);
                        }
                    }
                }
            });
        }
    });
    let (a, b, g) = (scopes[0].stats(), scopes[1].stats(), cache.stats());
    assert_eq!(a.hits + b.hits, g.hits);
    assert_eq!(a.disk_hits + b.disk_hits, g.disk_hits);
    assert_eq!(a.misses + b.misses, g.misses);
    assert_eq!(a.inserts + b.inserts, g.inserts);
    assert!(g.misses >= 48, "every first touch of a key is a counted miss");
}
