//! The adaptive-execution safety harness: integration-level properties
//! proving the run-time optimizations of `rtf_reuse::adaptive` can
//! never change what is computed.
//!
//! * **Exactness at threshold=0** — `adaptive=on` with a zero threshold
//!   must reproduce the exhaustive run *bit for bit* at every batch
//!   width: the unit-at-a-time execution order, the per-unit candidate
//!   batching, and the streaming estimator are all reorganizations of
//!   the same floating-point work.
//! * **Survivor bit-identity under pruning** — when the pruner does
//!   fire (a threshold derived from the run's own confidence
//!   intervals), every *surviving* evaluation is still bit-identical to
//!   the exhaustive run, every pruned slot holds the 0.0 sentinel, and
//!   the pruned count on the outcome is exactly the sentinel count.
//! * **Streaming ≡ batch** — the streaming estimator fed the real
//!   pipeline's outputs one unit at a time agrees bit-for-bit with the
//!   batch estimator on every prefix (the unit-level twin of the
//!   synthetic-data prefix tests inside `src/adaptive/stream.rs`).
//!
//! The seed is pinnable (`RTF_ADAPTIVE_SEED=N`) so CI runs fixed seeds
//! and any failure reproduces exactly.

use rtf_reuse::adaptive::{run_adaptive, AdaptiveEstimate, StreamingMoat};
use rtf_reuse::analysis::moat_effects;
use rtf_reuse::config::StudyConfig;
use rtf_reuse::driver::{
    build_cache, make_inputs, prepare, prune_plan_with_inputs, run_pjrt_with_inputs_scoped,
    y_per_set, SampleInfo,
};

/// The seeds this invocation exercises: `RTF_ADAPTIVE_SEED` pins one
/// (CI's adaptive-smoke job runs two fixed ones); the default keeps a
/// local `cargo test` run to a single seed.
fn seeds() -> Vec<u64> {
    match std::env::var("RTF_ADAPTIVE_SEED") {
        Ok(v) => vec![v.parse().expect("RTF_ADAPTIVE_SEED must be a u64")],
        Err(_) => vec![7],
    }
}

fn cfg_from(base: &[&str], seed: u64, batch_width: usize) -> StudyConfig {
    let mut args: Vec<String> = base.iter().map(|s| s.to_string()).collect();
    args.push(format!("seed={seed}"));
    args.push(format!("batch-width={batch_width}"));
    StudyConfig::from_args(&args).expect("test study args parse")
}

/// The exhaustive non-adaptive run: the ground truth every property
/// compares against, through the same prepare → plan → execute path.
fn full_run(cfg: &StudyConfig) -> Vec<f64> {
    let prepared = prepare(cfg);
    let inputs = make_inputs(cfg, &prepared).expect("inputs build");
    let cache = build_cache(cfg);
    let mut plan = prepared.plan(cfg);
    if let Some(c) = &cache {
        prune_plan_with_inputs(&prepared, &mut plan, c, &inputs);
    }
    let out = run_pjrt_with_inputs_scoped(cfg, &prepared, &plan, cache, None, &inputs)
        .expect("exhaustive run completes");
    out.y
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: slot {i} differs: {x} vs {y}");
    }
}

#[test]
fn threshold_zero_is_bit_identical_to_the_full_run_at_every_batch_width() {
    for seed in seeds() {
        let reference = full_run(&cfg_from(&["method=moat", "r=3"], seed, 16));
        for width in [5, 16, 64] {
            // the exhaustive run itself is batch-width invariant...
            let full = full_run(&cfg_from(&["method=moat", "r=3"], seed, width));
            assert_bits_eq(&full, &reference, &format!("full run @ width {width}, seed {seed}"));
            // ...and the adaptive run at threshold=0 prunes nothing and
            // reproduces it exactly, despite executing unit by unit
            let cfg = cfg_from(
                &["method=moat", "r=3", "adaptive=on", "threshold=0", "min-samples=1"],
                seed,
                width,
            );
            let out = run_adaptive(&cfg).expect("adaptive run completes");
            assert_eq!(out.pruned, 0, "threshold=0 never prunes (seed {seed})");
            assert!(out.pruned_params.is_empty());
            assert!(out.survived.iter().all(|&s| s), "every set survived");
            assert_bits_eq(
                &out.y,
                &reference,
                &format!("adaptive @ width {width}, seed {seed}"),
            );
        }
    }
}

#[test]
fn a_derived_threshold_prunes_work_but_survivors_stay_bit_identical() {
    for seed in seeds() {
        let cfg = cfg_from(&["method=moat", "r=4"], seed, 16);
        let reference = full_run(&cfg);
        let prepared = prepare(&cfg);
        let SampleInfo::Moat(sample) = &prepared.sample else { panic!("moat study") };
        let k = prepared.space.dim();
        let n_sets = sample.sets.len();
        let y_sets = y_per_set(&reference, n_sets, cfg.tiles);

        // derive a threshold from the run's own early confidence
        // intervals — exactly the state the online pruner sees at its
        // first decision point (two trajectories in). Sitting just
        // above the (3k/5)-th smallest μ* CI upper edge, it prunes a
        // set dense enough (> half of k) that each later trajectory is
        // guaranteed some evaluation with both neighboring steps
        // pruned: at most 2 per unpruned step of its k+1 evals survive
        let mut stream = StreamingMoat::new(k);
        let executed = vec![true; n_sets];
        for t in &sample.trajectories[..2] {
            stream.update(t, &y_sets, &executed);
        }
        let mut uppers: Vec<f64> = (0..k).map(|p| stream.mu_star_upper(p)).collect();
        uppers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let threshold = uppers[(3 * k) / 5] * (1.0 + 1e-9) + f64::MIN_POSITIVE;
        assert!(threshold.is_finite() && threshold > 0.0);

        let acfg = cfg_from(
            &[
                "method=moat",
                "r=4",
                "adaptive=on",
                &format!("threshold={threshold}"),
                "min-samples=2",
            ],
            seed,
            16,
        );
        let out = run_adaptive(&acfg).expect("adaptive run completes");

        // the pruner fired: below-median parameters were ruled out, so
        // later trajectories really dropped evaluations...
        assert!(out.pruned > 0, "the derived threshold prunes (seed {seed})");
        assert!(!out.pruned_params.is_empty());
        // ...but never the first min-samples trajectories
        assert!(out.survived[..2 * (k + 1)].iter().all(|&s| s));
        assert!(out.survived.iter().any(|&s| !s), "some sets were dropped");

        // THE safety property: a surviving evaluation is bit-identical
        // to the exhaustive run; a pruned slot is exactly the sentinel
        let mut sentinel_evals = 0u64;
        for (g, &alive) in out.survived.iter().enumerate() {
            let (y, r) =
                (&out.y[g * cfg.tiles..(g + 1) * cfg.tiles], &reference[g * cfg.tiles..(g + 1) * cfg.tiles]);
            if alive {
                assert_bits_eq(y, r, &format!("surviving set {g}, seed {seed}"));
            } else {
                assert!(y.iter().all(|&v| v == 0.0), "pruned set {g} holds the sentinel");
                sentinel_evals += cfg.tiles as u64;
            }
        }
        assert_eq!(out.pruned, sentinel_evals, "the pruning account is exact");
    }
}

#[test]
fn streaming_estimator_matches_batch_on_every_real_prefix() {
    for seed in seeds() {
        let cfg = cfg_from(&["method=moat", "r=3"], seed, 16);
        let reference = full_run(&cfg);
        let prepared = prepare(&cfg);
        let SampleInfo::Moat(sample) = &prepared.sample else { panic!("moat study") };
        let k = prepared.space.dim();
        let y_sets = y_per_set(&reference, sample.sets.len(), cfg.tiles);
        let executed = vec![true; sample.sets.len()];

        let mut stream = StreamingMoat::new(k);
        for (m, t) in sample.trajectories.iter().enumerate() {
            stream.update(t, &y_sets, &executed);
            let prefix = rtf_reuse::sampling::MoatSample {
                sets: sample.sets[..(m + 1) * (k + 1)].to_vec(),
                trajectories: sample.trajectories[..m + 1].to_vec(),
            };
            let batch = moat_effects(&prefix, &y_sets[..(m + 1) * (k + 1)], k);
            let ours = stream.indices();
            for p in 0..k {
                assert_eq!(ours.mean[p].to_bits(), batch.mean[p].to_bits(), "mean[{p}] @ {m}");
                assert_eq!(
                    ours.mu_star[p].to_bits(),
                    batch.mu_star[p].to_bits(),
                    "mu*[{p}] @ {m}"
                );
                assert_eq!(ours.sigma[p].to_bits(), batch.sigma[p].to_bits(), "sigma[{p}] @ {m}");
            }
        }
        // the adaptive runner's final estimate IS the streaming one
        let acfg = cfg_from(
            &["method=moat", "r=3", "adaptive=on", "threshold=0", "min-samples=1"],
            seed,
            16,
        );
        let out = run_adaptive(&acfg).expect("adaptive run completes");
        let AdaptiveEstimate::Moat(idx) = out.estimate else { panic!("moat estimate") };
        let last = stream.indices();
        for p in 0..k {
            assert_eq!(idx.mu_star[p].to_bits(), last.mu_star[p].to_bits(), "final mu*[{p}]");
        }
    }
}

#[test]
fn vbd_adaptive_keeps_a_and_b_blocks_and_prunes_only_ab_columns() {
    for seed in seeds() {
        let base = ["method=vbd", "n=6", "k-active=3"];
        let cfg = cfg_from(&base, seed, 16);
        let reference = full_run(&cfg);
        let prepared = prepare(&cfg);
        let SampleInfo::Vbd(sample, _) = &prepared.sample else { panic!("vbd study") };
        let (n, k) = (sample.n, sample.k);

        // threshold=0 is exact for VBD too
        let exact = run_adaptive(&cfg_from(
            &["method=vbd", "n=6", "k-active=3", "adaptive=on", "threshold=0", "min-samples=1"],
            seed,
            16,
        ))
        .expect("adaptive run completes");
        assert_eq!(exact.pruned, 0);
        assert_bits_eq(&exact.y, &reference, &format!("vbd adaptive exact, seed {seed}"));

        // an absurd threshold prunes every active parameter at the
        // first decision point (min-samples=2): the remaining blocks
        // keep their A/B evaluations — every index still needs them —
        // and drop exactly the k AB evaluations per block
        let out = run_adaptive(&cfg_from(
            &["method=vbd", "n=6", "k-active=3", "adaptive=on", "threshold=1e18", "min-samples=2"],
            seed,
            16,
        ))
        .expect("adaptive run completes");
        assert_eq!(out.pruned_params.len(), k, "every parameter pruned");
        assert_eq!(out.pruned, ((n - 2) * k * cfg.tiles) as u64);
        for j in 0..n {
            assert!(out.survived[sample.idx_a(j)], "A_{j} always runs");
            assert!(out.survived[sample.idx_b(j)], "B_{j} always runs");
            for i in 0..k {
                assert_eq!(out.survived[sample.idx_ab(i, j)], j < 2, "AB({i},{j})");
            }
        }
        // surviving evaluations are bit-identical to the exhaustive run
        for (g, &alive) in out.survived.iter().enumerate() {
            if alive {
                assert_bits_eq(
                    &out.y[g * cfg.tiles..(g + 1) * cfg.tiles],
                    &reference[g * cfg.tiles..(g + 1) * cfg.tiles],
                    &format!("vbd surviving set {g}, seed {seed}"),
                );
            }
        }
    }
}
