//! Loopback end-to-end tests of the serve wire protocol: a real
//! `StudyService` behind a real TCP listener, driven by the in-tree
//! client — reuse across the wire, per-tenant accounting in the drain
//! bill, and the protocol's error paths.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::thread;

use rtf_reuse::cache::CacheConfig;
use rtf_reuse::serve::protocol::{self, codes, Message};
use rtf_reuse::serve::{
    run_jobs, JobSpec, ServeOptions, ServiceReport, StudyService, WireServer, PROTOCOL_VERSION,
};

fn serve_opts(service_workers: usize) -> ServeOptions {
    ServeOptions {
        service_workers,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        ..ServeOptions::default()
    }
}

/// Bind a loopback server and run it on a background thread; returns
/// the address and the join handle yielding the drained report.
fn spawn_server(opts: ServeOptions) -> (String, thread::JoinHandle<ServiceReport>) {
    let svc = StudyService::start(opts).expect("service starts");
    let server = WireServer::bind(svc, "127.0.0.1:0").expect("bind loopback");
    let addr = server.local_addr().expect("bound address").to_string();
    let handle = thread::spawn(move || server.run().expect("server drains cleanly"));
    (addr, handle)
}

fn study_args() -> Vec<String> {
    vec!["method=moat".into(), "r=1".into()]
}

#[test]
fn two_tenants_over_tcp_share_the_cache_and_drain_a_bill() {
    let (addr, server) = spawn_server(serve_opts(1));
    let specs = vec![
        JobSpec { tenant: "alice".into(), args: study_args(), tune: false },
        JobSpec { tenant: "bob".into(), args: study_args(), tune: false },
    ];
    let outcome = run_jobs(&addr, &specs, true).expect("client run succeeds");

    // both results came back, in submission order, successfully
    assert_eq!(outcome.jobs.len(), 2);
    assert!(outcome.jobs.iter().all(|j| j.ok()), "jobs: {:?}", outcome.jobs);
    assert_eq!(outcome.jobs[0].tenant, "alice");
    assert_eq!(outcome.jobs[1].tenant, "bob");
    // identical studies agree bit-for-bit across the wire
    assert_eq!(outcome.jobs[0].y, outcome.jobs[1].y);
    // reuse across the wire: the second tenant rides the first's cache
    assert!(
        outcome.jobs[1].launches < outcome.jobs[0].launches,
        "bob must reuse alice's work: alice {} vs bob {}",
        outcome.jobs[0].launches,
        outcome.jobs[1].launches
    );
    assert!(outcome.jobs[1].cached_tasks > 0);

    // the drain bill is complete and internally consistent
    let bill = outcome.bill.expect("drain returns the bill");
    assert_eq!(bill.jobs, 2);
    assert_eq!(bill.failed, 0);
    assert_eq!(bill.tenants.len(), 2);
    let job_launches: u64 = outcome.jobs.iter().map(|j| j.launches).sum();
    assert_eq!(bill.total_launches, bill.input_launches + job_launches);
    // per-tenant scoped counters sum exactly to the shared globals
    let (hits, misses, inserts) = bill.tenants.iter().fold((0, 0, 0), |acc, t| {
        (acc.0 + t.cache.hits, acc.1 + t.cache.misses, acc.2 + t.cache.inserts)
    });
    assert_eq!(hits, bill.cache.hits);
    assert_eq!(misses, bill.cache.misses);
    assert_eq!(inserts, bill.cache.inserts);

    // the server side drained with the same totals
    let report = server.join().expect("server thread joins");
    assert_eq!(report.jobs.len(), 2);
    assert_eq!(report.total_launches(), bill.total_launches);
}

#[test]
fn protocol_errors_are_reported_not_fatal() {
    let (addr, server) = spawn_server(serve_opts(1));

    // a client speaking a future protocol version is refused
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let hello = Message::Hello { version: PROTOCOL_VERSION + 1, role: "client".into() };
        protocol::write_frame(&mut writer, &hello).unwrap();
        writer.flush().unwrap();
        match protocol::read_frame(&mut reader).unwrap() {
            Some(Message::Error { code, .. }) => assert_eq!(code, codes::VERSION_MISMATCH),
            other => panic!("expected version-mismatch error, got {other:?}"),
        }
    }

    // garbage on the wire gets a bad-frame error, not a hang
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        writer.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        writer.flush().unwrap();
        match protocol::read_frame(&mut reader).unwrap() {
            Some(Message::Error { code, .. }) => assert_eq!(code, codes::BAD_FRAME),
            other => panic!("expected bad-frame error, got {other:?}"),
        }
    }

    // a good connection: status works, unknown job ids are refused,
    // and drain shuts the service down cleanly
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let hello = Message::Hello { version: PROTOCOL_VERSION, role: "client".into() };
        protocol::write_frame(&mut writer, &hello).unwrap();
        writer.flush().unwrap();
        assert!(matches!(
            protocol::read_frame(&mut reader).unwrap(),
            Some(Message::Hello { version: PROTOCOL_VERSION, .. })
        ));

        protocol::write_frame(&mut writer, &Message::Status).unwrap();
        writer.flush().unwrap();
        match protocol::read_frame(&mut reader).unwrap() {
            Some(Message::StatusReport { queued, running, done, tiers }) => {
                assert_eq!((queued, running, done), (0, 0, 0));
                // rtfp v7: status always carries per-tier cache counters
                assert!(tiers.iter().any(|t| t.tier == "memory"), "tiers: {tiers:?}");
            }
            other => panic!("expected status-report, got {other:?}"),
        }

        // rtfp v7 stats surface: valid with telemetry off — counters
        // all zero, per-tier rows still live
        protocol::write_frame(&mut writer, &Message::Stats).unwrap();
        writer.flush().unwrap();
        match protocol::read_frame(&mut reader).unwrap() {
            Some(Message::StatsReport(stats)) => {
                assert!(!stats.enabled, "test server runs telemetry off");
                assert_eq!(stats.snapshot.global.counter("jobs_admitted"), 0);
                assert!(stats.tiers.iter().any(|t| t.tier == "memory"));
                assert_eq!((stats.queued, stats.running, stats.done), (0, 0, 0));
            }
            other => panic!("expected stats-report, got {other:?}"),
        }

        protocol::write_frame(&mut writer, &Message::Result { job: 999 }).unwrap();
        writer.flush().unwrap();
        match protocol::read_frame(&mut reader).unwrap() {
            Some(Message::Error { code, .. }) => assert_eq!(code, codes::UNKNOWN_JOB),
            other => panic!("expected unknown-job error, got {other:?}"),
        }

        protocol::write_frame(&mut writer, &Message::Drain).unwrap();
        writer.flush().unwrap();
        match protocol::read_frame(&mut reader).unwrap() {
            Some(Message::Bill(bill)) => assert_eq!(bill.jobs, 0),
            other => panic!("expected the bill, got {other:?}"),
        }
    }
    let report = server.join().expect("server thread joins");
    assert_eq!(report.jobs.len(), 0);
}

#[test]
fn submissions_with_bad_studies_are_refused_but_the_job_stream_continues() {
    let (addr, server) = spawn_server(serve_opts(1));
    let stream = TcpStream::connect(&addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);
    let hello = Message::Hello { version: PROTOCOL_VERSION, role: "client".into() };
    protocol::write_frame(&mut writer, &hello).unwrap();
    writer.flush().unwrap();
    protocol::read_frame(&mut reader).unwrap();

    // a submit whose study options do not parse is refused with
    // bad-study; the connection stays usable
    let bad = Message::Submit { tenant: "eve".into(), study: vec!["bogus=1".into()] };
    protocol::write_frame(&mut writer, &bad).unwrap();
    writer.flush().unwrap();
    match protocol::read_frame(&mut reader).unwrap() {
        Some(Message::Error { code, .. }) => assert_eq!(code, codes::BAD_STUDY),
        other => panic!("expected bad-study error, got {other:?}"),
    }

    // a good submit on the same connection still works end to end
    let good = Message::Submit { tenant: "alice".into(), study: study_args() };
    protocol::write_frame(&mut writer, &good).unwrap();
    writer.flush().unwrap();
    let job = match protocol::read_frame(&mut reader).unwrap() {
        Some(Message::Accepted { job }) => job,
        other => panic!("expected accepted, got {other:?}"),
    };
    protocol::write_frame(&mut writer, &Message::Result { job }).unwrap();
    writer.flush().unwrap();
    match protocol::read_frame(&mut reader).unwrap() {
        Some(Message::JobDone(report)) => {
            assert!(report.ok(), "job failed: {:?}", report.error);
            assert_eq!(report.job, job);
            assert!(report.launches > 0);
        }
        other => panic!("expected job-report, got {other:?}"),
    }

    protocol::write_frame(&mut writer, &Message::Drain).unwrap();
    writer.flush().unwrap();
    match protocol::read_frame(&mut reader).unwrap() {
        Some(Message::Bill(bill)) => {
            assert_eq!(bill.jobs, 1);
            assert_eq!(bill.tenants.len(), 1, "the refused tenant never got a scope");
        }
        other => panic!("expected the bill, got {other:?}"),
    }
    let report = server.join().expect("server thread joins");
    assert_eq!(report.jobs.len(), 1);
}

#[test]
fn tune_jobs_run_over_the_wire_next_to_studies() {
    let (addr, server) = spawn_server(serve_opts(1));
    let tune_args: Vec<String> = ["tuner=ga", "budget=6", "population=3", "k-active=1", "r=1"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let specs = vec![
        JobSpec { tenant: "alice".into(), args: study_args(), tune: false },
        JobSpec { tenant: "bob".into(), args: tune_args, tune: true },
    ];
    let outcome = run_jobs(&addr, &specs, true).expect("client run succeeds");
    assert_eq!(outcome.jobs.len(), 2);
    assert!(outcome.jobs.iter().all(|j| j.ok()), "jobs: {:?}", outcome.jobs);
    assert!(outcome.jobs[0].tune.is_none(), "study reports carry no tune block");
    let tune = outcome.jobs[1].tune.as_ref().expect("tune job reports its summary");
    assert!(tune.evaluated > 0);
    assert_eq!(tune.best_params.len(), 15, "a full Table-1 parameter set");
    assert!(tune.best_score.is_finite());
    assert!(tune.best_score >= tune.initial_best_score);
    // the tune job's y carries the per-generation best scores
    assert_eq!(outcome.jobs[1].y.len() as u64, tune.generations);
    let bill = outcome.bill.expect("bill");
    assert_eq!(bill.tenants.len(), 2, "both kinds bill under their tenants");
    server.join().expect("server joins");
}

#[test]
fn adaptive_studies_over_the_wire_bill_pruned_and_threshold_zero_changes_nothing() {
    let (addr, server) = spawn_server(serve_opts(1));
    let mut adaptive_args = study_args();
    adaptive_args.extend(["adaptive=on".into(), "threshold=0".into(), "min-samples=1".into()]);
    let specs = vec![
        JobSpec { tenant: "plain".into(), args: study_args(), tune: false },
        JobSpec { tenant: "adaptive".into(), args: adaptive_args, tune: false },
    ];
    let outcome = run_jobs(&addr, &specs, true).expect("client run succeeds");
    assert_eq!(outcome.jobs.len(), 2);
    assert!(outcome.jobs.iter().all(|j| j.ok()), "jobs: {:?}", outcome.jobs);

    // threshold=0 can never prune (a CI upper bound is never negative),
    // so the adaptive run reproduces the plain run bit for bit
    assert_eq!(outcome.jobs[0].y, outcome.jobs[1].y, "adaptive at threshold=0 is exact");
    assert_eq!(outcome.jobs[1].pruned, 0, "nothing was pruned at threshold=0");

    // the v5 bill carries the pruning account at every level
    let bill = outcome.bill.expect("bill");
    assert_eq!(bill.pruned, 0);
    let row = bill.tenants.iter().find(|t| t.tenant == "adaptive").expect("adaptive row");
    assert_eq!(row.pruned, 0);
    assert_eq!(bill.speculative_launches, 0, "no tune job ran, nothing to speculate on");
    server.join().expect("server joins");
}

#[test]
fn speculation_changes_timing_only_never_result_bytes_over_the_wire() {
    // the same GA tune job on a speculation-off and a speculation-on
    // service: the tuner's trajectory, scores and parameters must agree
    // bit for bit — speculation may only warm the cache
    let tune_args: Vec<String> = ["tuner=ga", "budget=6", "population=3", "k-active=1", "r=1"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let run = |speculate: bool| {
        let mut opts = serve_opts(2);
        opts.speculate = speculate;
        let (addr, server) = spawn_server(opts);
        let specs = vec![JobSpec { tenant: "carol".into(), args: tune_args.clone(), tune: true }];
        let outcome = run_jobs(&addr, &specs, true).expect("client run succeeds");
        server.join().expect("server joins");
        outcome
    };
    let off = run(false);
    let on = run(true);
    assert!(off.jobs[0].ok() && on.jobs[0].ok(), "off: {:?} on: {:?}", off.jobs, on.jobs);
    assert_eq!(off.jobs[0].y, on.jobs[0].y, "per-generation best scores are bit-identical");
    assert_eq!(off.jobs[0].tune, on.jobs[0].tune, "the tune summary is bit-identical");
    assert_eq!(off.jobs[0].pruned, 0, "tune jobs never prune");

    // whatever speculation spent is billed globally — like shared input
    // building — never to the tenant's row
    let bill_off = off.bill.expect("bill");
    let bill_on = on.bill.expect("bill");
    assert_eq!(bill_off.speculative_launches, 0, "speculation off spends nothing");
    for (bill, outcome) in [(&bill_off, &off), (&bill_on, &on)] {
        assert_eq!(
            bill.total_launches,
            bill.input_launches + bill.speculative_launches + outcome.jobs[0].launches,
            "the launch ledger partitions exactly"
        );
    }
    if bill_on.speculative_launches > 0 {
        let row = bill_on.tenants.iter().find(|t| t.tenant == "~speculative");
        let row = row.expect("speculative spend appears under the pseudo-tenant");
        assert_eq!(row.jobs, 0, "the pseudo-tenant owns no jobs");
    }
}

#[test]
fn demo_workload_matches_in_process_semantics() {
    // the same two-tenant demo the README quickstart runs, but over
    // TCP: on one service worker the first job is the only cold one,
    // and the three warm jobs stay within the multi-tenant launch bound
    let (addr, server) = spawn_server(serve_opts(1));
    let args = study_args();
    let specs = vec![
        JobSpec { tenant: "t0".into(), args: args.clone(), tune: false },
        JobSpec { tenant: "t0".into(), args: args.clone(), tune: false },
        JobSpec { tenant: "t1".into(), args: args.clone(), tune: false },
        JobSpec { tenant: "t1".into(), args, tune: false },
    ];
    let outcome = run_jobs(&addr, &specs, true).expect("client run succeeds");
    assert_eq!(outcome.jobs.len(), 4);
    assert!(outcome.jobs.iter().all(|j| j.ok()));
    let bill = outcome.bill.expect("bill");
    let cold = outcome.jobs[0].launches + bill.input_launches;
    let limit = (cold as f64 * 1.25).ceil() as u64;
    assert!(
        bill.total_launches <= limit,
        "3 warm jobs must ride the first's cache: {} > {limit}",
        bill.total_launches
    );
    server.join().expect("server joins");
}
