//! End-to-end study integration: full SA studies through the real PJRT
//! coordinator, checking the fundamental reuse property — **reuse must
//! not change results** — plus multi-tile studies and both SA methods.

use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::driver::{moat_screen, prepare, run_pjrt, y_per_set, SampleInfo};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};

fn base_cfg() -> StudyConfig {
    StudyConfig {
        method: SaMethod::Moat { r: 1 }, // 16 evaluations
        workers: 2,
        artifacts_dir: format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        ..StudyConfig::default()
    }
}

#[test]
fn reuse_never_changes_study_results() {
    // the paper's core correctness requirement: merged execution skips
    // re-computation but every evaluation's output must be identical
    let mut reference: Option<Vec<f64>> = None;
    for (coarse, algo) in [
        (false, FineAlgorithm::None), // replica baseline
        (true, FineAlgorithm::None),
        (true, FineAlgorithm::Naive(4)),
        (true, FineAlgorithm::Sca(4)),
        (true, FineAlgorithm::Rtma(4)),
        (true, FineAlgorithm::Trtma(TrtmaOptions::new(5))),
    ] {
        let mut cfg = base_cfg();
        cfg.coarse = coarse;
        cfg.algorithm = algo;
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        plan.assert_valid(&prepared.graph);
        let outcome = run_pjrt(&cfg, &prepared, &plan).expect("run `make artifacts` first");
        assert_eq!(outcome.y.len(), prepared.n_evals());
        match &reference {
            None => reference = Some(outcome.y.clone()),
            Some(r) => {
                for (i, (a, b)) in r.iter().zip(&outcome.y).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-6,
                        "eval {i} differs under {:?}: {a} vs {b}",
                        algo.name()
                    );
                }
            }
        }
    }
}

#[test]
fn merged_execution_skips_work_but_metrics_stay_sane() {
    let mut cfg = base_cfg();
    cfg.algorithm = FineAlgorithm::Rtma(7);
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    assert!(plan.fine_reuse() > 0.1, "MOAT study must expose fine reuse");
    let outcome = run_pjrt(&cfg, &prepared, &plan).unwrap();
    for (i, m) in outcome.metrics.iter().enumerate() {
        assert!((0.0..=1.0 + 1e-6).contains(&(m[0] as f64)), "eval {i} dice {}", m[0]);
        assert!((0.0..=1.0 + 1e-6).contains(&(m[1] as f64)), "eval {i} jaccard {}", m[1]);
        assert!(m[2] >= 0.0);
        // dice >= jaccard always
        assert!(m[0] >= m[1] - 1e-6);
    }
    // per-task timings were recorded for the merged execution
    let rows = outcome.timer.summary();
    assert!(rows.iter().any(|(n, _, _)| n == "t6"));
    let t_total: u64 = rows.iter().map(|(_, _, n)| n).sum();
    assert_eq!(t_total as usize, plan.tasks_to_execute());
    assert!(outcome.peak_state_bytes > 0);
}

#[test]
fn multi_tile_study_keeps_tiles_separate() {
    let mut cfg = base_cfg();
    cfg.tiles = 2;
    cfg.algorithm = FineAlgorithm::Rtma(5);
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    plan.assert_valid(&prepared.graph);
    let outcome = run_pjrt(&cfg, &prepared, &plan).unwrap();
    assert_eq!(outcome.y.len(), prepared.n_evals());
    // default-parameter evaluation (trajectory bases are not defaults, so
    // instead check: per-set tile average is well-formed)
    let SampleInfo::Moat(sample) = &prepared.sample else { unreachable!() };
    let y_sets = y_per_set(&outcome.y, sample.sets.len(), cfg.tiles);
    assert_eq!(y_sets.len(), 16);
    assert!(y_sets.iter().all(|v| (0.0..=1.0).contains(v)));
}

#[test]
fn moat_screen_flows_into_vbd() {
    // phase 1
    let mut cfg = base_cfg();
    cfg.method = SaMethod::Moat { r: 2 };
    cfg.algorithm = FineAlgorithm::Rtma(7);
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let outcome = run_pjrt(&cfg, &prepared, &plan).unwrap();
    let (_, top) = moat_screen(&cfg, &prepared, &outcome.y, 4);
    assert_eq!(top.len(), 4);

    // phase 2 on the screened parameters
    let mut vcfg = base_cfg();
    vcfg.method = SaMethod::Vbd { n: 3, k_active: top.len() };
    vcfg.algorithm = FineAlgorithm::Rtma(6);
    let vprep = rtf_reuse::driver::prepare_with_active(&vcfg, Some(top.clone()));
    let vplan = vprep.plan(&vcfg);
    assert!(vplan.fine_reuse() > 0.0, "VBD designs always expose reuse");
    let vout = run_pjrt(&vcfg, &vprep, &vplan).unwrap();
    let SampleInfo::Vbd(sample, active) = &vprep.sample else { unreachable!() };
    assert_eq!(active, &top);
    let y = y_per_set(&vout.y, sample.sets.len(), vcfg.tiles);
    let idx = rtf_reuse::analysis::sobol_indices(sample, &y);
    assert_eq!(idx.first.len(), top.len());
}

#[test]
fn state_limit_spills_without_changing_results() {
    use rtf_reuse::coordinator::{execute_study, ExecuteOptions};
    use rtf_reuse::driver::{make_tiles, reference_masks};
    use rtf_reuse::runtime::PjrtEngine;

    let mut cfg = base_cfg();
    cfg.algorithm = FineAlgorithm::Rtma(5);
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);

    let mut engine = PjrtEngine::load(&cfg.artifacts_dir).unwrap();
    let (h, w) = engine.tile_shape();
    let tiles = make_tiles(&cfg, h, w);
    let refs = reference_masks(&mut engine, &prepared.space, &prepared.workflow, &tiles).unwrap();
    drop(engine);

    let unlimited = ExecuteOptions::new(2, &cfg.artifacts_dir);
    let base = execute_study(
        &unlimited, &plan, &prepared.graph, &prepared.instances, &tiles, &refs,
        prepared.n_evals(),
    )
    .unwrap();

    // a limit far below the working set forces disk spills
    let limited = ExecuteOptions::new(2, &cfg.artifacts_dir).with_state_limit(256 * 1024);
    let spilled = execute_study(
        &limited, &plan, &prepared.graph, &prepared.instances, &tiles, &refs,
        prepared.n_evals(),
    )
    .unwrap();

    for (a, b) in base.y.iter().zip(&spilled.y) {
        assert!((a - b).abs() < 1e-9, "spilling must not change results: {a} vs {b}");
    }
}

#[test]
fn worker_count_does_not_change_results() {
    let mut cfg = base_cfg();
    cfg.algorithm = FineAlgorithm::Rtma(5);
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let y1 = run_pjrt(&cfg, &prepared, &plan).unwrap().y;
    cfg.workers = 4;
    let y4 = run_pjrt(&cfg, &prepared, &plan).unwrap().y;
    assert_eq!(y1.len(), y4.len());
    for (a, b) in y1.iter().zip(&y4) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
}
