//! Cluster phase 2 end to end: front-door routing and hot-prefix
//! replication on real `StudyService`s behind real TCP listeners. The
//! properties under test are the ones the v6 protocol sells: a submit
//! to a non-owner is transparently routed to the peer owning the
//! study's key plurality (and falls back to local execution when that
//! peer is gone), a dead owner past the hot watermark degrades to
//! replica hits instead of local launches, and through all of it the
//! results stay bit-identical to a single node while the scoped
//! ledgers keep partitioning the globals on every node. Plus the
//! regression pin for the breaker hoist: the circuit breaker keys on
//! the peer *address*, never rediscovering a dead peer key by key.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use rtf_reuse::cache::{CacheCtx, CacheConfig, CacheTier, Key, RemoteTier};
use rtf_reuse::config::StudyConfig;
use rtf_reuse::serve::protocol::WireBill;
use rtf_reuse::serve::{run_jobs, JobSpec, ServeOptions, ServiceReport, StudyService, WireServer};

/// Proxy handles live above every local job id (`server::ROUTE_BASE`);
/// a client-visible id at or past this mark proves the job was routed.
const ROUTE_BASE: u64 = 1 << 32;

fn study_args(batch_width: usize) -> Vec<String> {
    vec!["method=moat".into(), "r=1".into(), format!("batch-width={batch_width}")]
}

/// Reserve a loopback address the OS just proved free (same caveat as
/// `tests/cluster.rs`: the rebind window is vanishingly small).
fn reserve_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    listener.local_addr().expect("reserved addr").to_string()
}

fn base_opts() -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        ..ServeOptions::default()
    }
}

fn node_opts(peers: &[String], own: &str) -> ServeOptions {
    ServeOptions {
        peers: peers.to_vec(),
        cluster_addr: Some(own.to_string()),
        ..base_opts()
    }
}

/// Start a node and keep a handle on its service, so the test can ask
/// it questions (`predict_route`, `completed`) while the wire server
/// owns the listener.
fn spawn_node(
    opts: ServeOptions,
    addr: &str,
) -> (Arc<StudyService>, thread::JoinHandle<ServiceReport>) {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds its reserved addr");
    let svc = Arc::clone(server.service());
    (svc, thread::spawn(move || server.run().expect("node drains cleanly")))
}

/// Ground truth: the same study on a plain single node.
fn solo_baseline(args: Vec<String>) -> Vec<f64> {
    let svc = StudyService::start(base_opts()).expect("solo service starts");
    let server = WireServer::bind(svc, "127.0.0.1:0").expect("bind loopback");
    let addr = server.local_addr().expect("bound address").to_string();
    let handle = thread::spawn(move || server.run().expect("solo drains cleanly"));
    let spec = JobSpec { tenant: "solo".into(), args, tune: false };
    let out = run_jobs(&addr, &[spec], true).expect("solo run succeeds");
    handle.join().expect("solo joins");
    assert!(out.jobs[0].ok(), "solo job: {:?}", out.jobs[0].error);
    out.jobs[0].y.clone()
}

fn assert_scoped_sums_match(bill: &WireBill, node: &str) {
    let sums = bill.tenants.iter().fold((0, 0, 0, 0, 0), |acc, t| {
        (
            acc.0 + t.cache.hits,
            acc.1 + t.cache.disk_hits,
            acc.2 + t.cache.remote_hits,
            acc.3 + t.cache.misses,
            acc.4 + t.cache.inserts,
        )
    });
    assert_eq!(sums.0, bill.cache.hits, "{node}: scoped hits partition the globals");
    assert_eq!(sums.1, bill.cache.disk_hits, "{node}: scoped disk hits partition the globals");
    assert_eq!(sums.2, bill.cache.remote_hits, "{node}: scoped remote hits partition the globals");
    assert_eq!(sums.3, bill.cache.misses, "{node}: scoped misses partition the globals");
    assert_eq!(sums.4, bill.cache.inserts, "{node}: scoped inserts partition the globals");
}

/// The front door end to end: three route-enabled nodes, a submit to a
/// non-owner is executed on the predicted owner behind a proxy handle,
/// and when that owner dies the same submit falls back to local
/// execution on the router — riding the third node's shard over remote
/// hits — with bit-identical results throughout.
#[test]
fn a_submit_to_a_non_owner_is_routed_and_falls_back_local_when_the_owner_dies() {
    let args = study_args(16);
    let base_y = solo_baseline(args.clone());

    let addrs: Vec<String> = (0..3).map(|_| reserve_addr()).collect();
    let mut nodes: Vec<_> = addrs
        .iter()
        .map(|a| {
            let opts = ServeOptions { route: true, ..node_opts(&addrs, a) };
            Some(spawn_node(opts, a))
        })
        .collect();

    // the planner probe must agree across the cluster: exactly one node
    // claims the study's key plurality for itself (predicts None), and
    // every other node names that node's address
    let cfg = StudyConfig::from_args(&args).expect("study parses");
    let predictions: Vec<Option<String>> = nodes
        .iter()
        .map(|n| n.as_ref().unwrap().0.predict_route(&cfg))
        .collect();
    let locals = predictions.iter().filter(|p| p.is_none()).count();
    assert_eq!(locals, 1, "exactly one node owns the key plurality: {predictions:?}");
    let winner = predictions.iter().position(|p| p.is_none()).expect("a local predictor");
    assert!(
        predictions.iter().flatten().all(|a| *a == addrs[winner]),
        "the peers disagree on the owner: {predictions:?}"
    );
    let router = (winner + 1) % addrs.len();
    let third = (winner + 2) % addrs.len();

    // cold job through the front door: accepted by the router, executed
    // on the winner, result proxied back on the same connection
    let spec = JobSpec { tenant: "cold".into(), args: args.clone(), tune: false };
    let out = run_jobs(&addrs[router], &[spec], false).expect("routed submit succeeds");
    assert!(out.jobs[0].ok(), "routed job: {:?}", out.jobs[0].error);
    assert_eq!(out.jobs[0].y, base_y, "a routed job is bit-identical to solo");
    assert!(
        out.jobs[0].job >= ROUTE_BASE,
        "the client-visible id {} must be a proxy handle — was the job routed at all?",
        out.jobs[0].job
    );
    assert_eq!(nodes[router].as_ref().unwrap().0.completed(), 0, "the router executed nothing");
    assert_eq!(nodes[winner].as_ref().unwrap().0.completed(), 1, "the owner executed the job");

    // kill the owner; its shard survives on the peers it wrote through to
    let (winner_svc, winner_handle) = nodes[winner].take().expect("winner node");
    let bill_w = run_jobs(&addrs[winner], &[], true).expect("drain winner").bill.expect("bill");
    winner_handle.join().expect("winner joins");
    drop(winner_svc);

    // the same study again: the router still predicts the (dead) owner,
    // the route dial fails, and the submit falls back to LOCAL execution
    // — completing bit-identically by pulling the third node's shard
    // over remote gets and relaunching what died with the owner
    let spec = JobSpec { tenant: "fallback".into(), args, tune: false };
    let out = run_jobs(&addrs[router], &[spec], false).expect("fallback submit succeeds");
    assert!(out.jobs[0].ok(), "fallback job: {:?}", out.jobs[0].error);
    assert_eq!(out.jobs[0].y, base_y, "a dead route never changes results");
    assert!(out.jobs[0].job < ROUTE_BASE, "the fallback runs under a local id");
    assert_eq!(nodes[router].as_ref().unwrap().0.completed(), 1, "the router ran the fallback");

    let bill_t = run_jobs(&addrs[third], &[], true).expect("drain third").bill.expect("bill");
    let bill_r = run_jobs(&addrs[router], &[], true).expect("drain router").bill.expect("bill");
    for node in nodes.into_iter().flatten() {
        node.1.join().expect("node joins");
    }

    assert!(
        bill_r.cache.remote_hits > 0,
        "the fallback run must ride the surviving peer's shard"
    );
    assert_scoped_sums_match(&bill_w, "winner");
    assert_scoped_sums_match(&bill_t, "third node");
    assert_scoped_sums_match(&bill_r, "router");
}

/// One replication round on a four-node ring: a cold run on node 0 and
/// two warm runs (nodes 1, 2) push node 0's shard past the hot
/// watermark — the second remote serve of each key crosses it, so with
/// `replicas=1` node 0 publishes every hot key to its ring replica.
/// Then a probe job on node 3, optionally after killing node 0.
/// Returns the probe's backend launches and node 3's remote hits.
fn replication_round(replicas: usize, kill_owner: bool, base_y: &[f64]) -> (u64, u64) {
    let addrs: Vec<String> = (0..4).map(|_| reserve_addr()).collect();
    let mut nodes: Vec<_> = addrs
        .iter()
        .map(|a| {
            let opts = ServeOptions { replicas, ..node_opts(&addrs, a) };
            Some(spawn_node(opts, a))
        })
        .collect();

    for (i, tenant) in ["cold", "warm1", "warm2"].iter().enumerate() {
        let spec = JobSpec { tenant: tenant.to_string(), args: study_args(16), tune: false };
        let out = run_jobs(&addrs[i], &[spec], false).expect("warm-up job succeeds");
        assert!(out.jobs[0].ok(), "warm-up on node {i}: {:?}", out.jobs[0].error);
        assert_eq!(out.jobs[0].y, base_y, "warm-up on node {i} matches solo");
    }

    let mut bills: Vec<(String, WireBill)> = Vec::new();
    if kill_owner {
        let (svc, handle) = nodes[0].take().expect("owner node");
        let bill = run_jobs(&addrs[0], &[], true).expect("drain owner").bill.expect("bill");
        handle.join().expect("owner joins");
        drop(svc);
        bills.push(("dead owner".into(), bill));
    }

    let spec = JobSpec { tenant: "probe".into(), args: study_args(16), tune: false };
    let out = run_jobs(&addrs[3], &[spec], false).expect("probe job succeeds");
    assert!(out.jobs[0].ok(), "probe job: {:?}", out.jobs[0].error);
    assert_eq!(out.jobs[0].y, base_y, "the probe is bit-identical no matter who serves it");

    let mut probe_remote_hits = 0;
    for i in (0..4).rev() {
        let Some((svc, handle)) = nodes[i].take() else { continue };
        let bill = run_jobs(&addrs[i], &[], true).expect("drain node").bill.expect("bill");
        handle.join().expect("node joins");
        drop(svc);
        if i == 3 {
            probe_remote_hits = bill.cache.remote_hits;
        }
        bills.push((format!("node {i}"), bill));
    }
    for (node, bill) in &bills {
        assert_scoped_sums_match(bill, node);
    }
    (out.jobs[0].launches, probe_remote_hits)
}

/// The replication economy, pinned three ways against the same study:
/// with the owner alive, a warm probe costs some baseline of launches;
/// with the owner dead and `replicas=1` it costs EXACTLY the same
/// (every orphaned key is served from its ring replica — claim-free
/// peeks or the pushed copy already resident); with the owner dead and
/// `replicas=0` it costs strictly more, because the orphaned shard has
/// to be relaunched locally. Results are bit-identical in all three.
#[test]
fn a_dead_owner_is_served_from_its_replica_with_zero_extra_launches() {
    let base_y = solo_baseline(study_args(16));

    let (launches_alive, _) = replication_round(1, false, &base_y);
    let (launches_dead, probe_remote_hits) = replication_round(1, true, &base_y);
    let (launches_unreplicated, _) = replication_round(0, true, &base_y);

    assert_eq!(
        launches_dead, launches_alive,
        "replicas=1: a dead owner must cost zero extra launches \
         (alive {launches_alive}, dead {launches_dead})"
    );
    assert!(
        probe_remote_hits > 0,
        "the probe behind a dead owner must show remote (replica) hits on its bill"
    );
    assert!(
        launches_unreplicated > launches_dead,
        "replicas=0 must relaunch the orphaned shard: {launches_unreplicated} launches \
         vs {launches_dead} with replication"
    );
}

/// Regression pin for the breaker hoist: the circuit breaker keys on
/// the peer ADDRESS. Before the fix it was rediscovered per key, so a
/// dead peer cost a fresh dial streak for every distinct key; now
/// failures on distinct keys share one streak, the breaker opens once,
/// and every further lookup to that address fails fast without dialing.
#[test]
fn the_circuit_breaker_is_per_peer_address_not_per_key() {
    let own = reserve_addr();
    let dead = reserve_addr(); // nothing ever listens here
    let tier = RemoteTier::new(&[own.clone(), dead.clone()], &own)
        .expect("tier builds")
        .with_replicas(0);
    let ctx = CacheCtx::unscoped();

    // distinct keys, all owned by the dead peer
    let ring = tier.ring();
    let dead_keys: Vec<Key> = (0..200u64)
        .map(Key::from)
        .filter(|&k| ring.addr(ring.owner_of(k)) == dead)
        .take(8)
        .collect();
    assert!(dead_keys.len() >= 4, "too few sampled keys land on the dead peer");

    for &k in &dead_keys {
        assert!(tier.lookup(k, &ctx).is_none(), "a dead owner serves nothing");
    }
    let stats = tier.stats();
    assert_eq!(
        stats.breaker_opens, 1,
        "one address, one breaker: failures on distinct keys must share a streak"
    );

    // while the breaker is open, a lookup of yet another key fails fast
    // — in-memory, no dial, no connect timeout
    let t0 = Instant::now();
    assert!(tier.lookup(dead_keys[0], &ctx).is_none());
    assert!(
        t0.elapsed() < Duration::from_millis(50),
        "an open breaker must fail fast, not re-dial: took {:?}",
        t0.elapsed()
    );
}
