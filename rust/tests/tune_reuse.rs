//! Tuning determinism and reuse acceptance:
//!
//! * fixed-seed tuning runs are **bit-identical** with the cache on vs
//!   off and across frontier batch widths — caching and batching change
//!   launch counts, never results;
//! * revisited quantized parameter points cause **zero new kernel
//!   launches** — within one run via the per-run memo table, and across
//!   runs via the shared reuse cache;
//! * two tenants tuning concurrently on one service keep the scoped
//!   counter sums equal to the shared cache's globals.

use std::sync::Arc;

use rtf_reuse::cache::CacheConfig;
use rtf_reuse::config::{CacheSettings, StudyConfig};
use rtf_reuse::driver::{build_cache, make_inputs, prepare_candidates};
use rtf_reuse::sampling::default_space;
use rtf_reuse::serve::{ServeOptions, StudyService};
use rtf_reuse::tune::{
    run_tune_standalone, CandidateEvaluator, Objective, ObjectiveKind, TuneOptions, TunerKind,
};

fn study_cfg(cache: bool) -> StudyConfig {
    StudyConfig {
        cache: CacheSettings { enabled: cache, ..CacheSettings::default() },
        workers: 2,
        ..StudyConfig::default()
    }
}

fn tune_opts(kind: TunerKind) -> TuneOptions {
    TuneOptions {
        method: kind,
        budget: 10,
        population: 4,
        active: vec![5, 6], // G1, G2
        init_window: (0.5, 1.0),
        ..TuneOptions::default()
    }
}

/// The bit-comparable fingerprint of a tuning outcome.
fn fingerprint(o: &rtf_reuse::tune::TuneOutcome) -> (Vec<u64>, u64, Vec<u64>, usize, usize) {
    (
        o.best_params.iter().map(|v| v.to_bits()).collect(),
        o.best_score.to_bits(),
        o.history.iter().map(|g| g.best_score.to_bits()).collect(),
        o.evaluated,
        o.memo_hits,
    )
}

#[test]
fn fixed_seed_runs_are_bit_identical_across_cache_and_width() {
    for kind in [TunerKind::Genetic, TunerKind::Simplex] {
        let opts = tune_opts(kind);
        let base = run_tune_standalone(&study_cfg(false), &opts).expect("cache-off run");
        let cached = run_tune_standalone(&study_cfg(true), &opts).expect("cache-on run");
        let narrow = {
            let cfg = StudyConfig { batch_width: 1, ..study_cfg(true) };
            run_tune_standalone(&cfg, &opts).expect("width-1 run")
        };
        assert_eq!(
            fingerprint(&base),
            fingerprint(&cached),
            "{:?}: the cache must not change tuning results",
            kind
        );
        assert_eq!(
            fingerprint(&cached),
            fingerprint(&narrow),
            "{:?}: batch width must not change tuning results",
            kind
        );
        assert!(base.evaluated > 0);
        assert!(base.launches >= cached.launches, "caching never adds launches");
    }
}

#[test]
fn revisited_quantized_points_cause_zero_new_launches() {
    let cfg = study_cfg(true);
    let cache = build_cache(&cfg).expect("cache enabled");
    let space = default_space();
    let probe = prepare_candidates(&cfg, &[space.defaults()]);
    let inputs = make_inputs(&cfg, &probe).expect("inputs build");
    let objective = || Objective::for_study(&cfg, ObjectiveKind::Dice, 0.0);

    let mut a = space.defaults();
    a[5] = 10.0; // on-grid G1 variation
    let mut b = space.defaults();
    b[5] = 20.0;

    // one tuning run: the second visit of each point is a memo hit
    let mut ev =
        CandidateEvaluator::new(&cfg, objective(), Some(Arc::clone(&cache)), None, &inputs);
    let first = ev.score_batch(&[a.clone(), b.clone()]).expect("cold generation");
    let cold_launches = ev.launches;
    assert!(cold_launches > 0, "a cold generation must launch kernels");
    assert_eq!(ev.evaluated, 2);
    let again = ev.score_batch(&[b.clone(), a.clone()]).expect("revisit generation");
    assert_eq!(ev.launches, cold_launches, "revisits must not launch");
    assert_eq!(ev.evaluated, 2, "revisits never re-run studies");
    assert_eq!(ev.memo_hits, 2);
    assert_eq!(again, vec![first[1], first[0]]);
    // duplicates inside one generation collapse onto one evaluation
    let dup = ev.score_batch(&[a.clone(), a.clone()]).expect("duplicate generation");
    assert_eq!(dup[0].to_bits(), dup[1].to_bits());
    assert_eq!(ev.launches, cold_launches);

    // a NEW tuning run (fresh memo) over the same shared cache: every
    // chain task and metric is already cached — still zero launches
    let mut warm =
        CandidateEvaluator::new(&cfg, objective(), Some(Arc::clone(&cache)), None, &inputs);
    let rerun = warm.score_batch(&[a, b]).expect("warm generation");
    assert_eq!(warm.launches, 0, "a warm rerun must be fully cache-served");
    assert!(warm.cached_tasks > 0);
    assert_eq!(warm.evaluated, 2, "the warm run still scores through studies");
    assert_eq!(rerun[0].to_bits(), first[0].to_bits());
    assert_eq!(rerun[1].to_bits(), first[1].to_bits());
}

#[test]
fn concurrent_tenant_tuning_keeps_scoped_sums_equal_to_globals() {
    let opts = ServeOptions {
        service_workers: 2,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        ..ServeOptions::default()
    };
    let svc = StudyService::start(opts).expect("service starts");
    let tune = TuneOptions { budget: 6, population: 3, ..tune_opts(TunerKind::Genetic) };
    svc.submit_tune("alice", StudyConfig::default(), tune.clone()).expect("submit alice");
    svc.submit_tune("bob", StudyConfig::default(), tune).expect("submit bob");
    let report = svc.drain();

    assert_eq!(report.jobs.len(), 2);
    assert!(report.jobs.iter().all(|j| j.ok()), "jobs: {:?}", report.jobs);
    let summaries: Vec<_> =
        report.jobs.iter().map(|j| j.tune.clone().expect("tune summary")).collect();
    // identical fixed-seed tuning jobs agree bit-for-bit across tenants
    assert_eq!(summaries[0], summaries[1]);
    assert!(summaries[0].evaluated > 0);

    // per-tenant scoped counters sum exactly to the shared globals
    let sums = report.scoped_totals();
    assert_eq!(sums.hits, report.cache.hits);
    assert_eq!(sums.disk_hits, report.cache.disk_hits);
    assert_eq!(sums.misses, report.cache.misses);
    assert_eq!(sums.inserts, report.cache.inserts);
    assert_eq!(sums.metric_hits, report.cache.metric_hits);
    assert_eq!(sums.metric_misses, report.cache.metric_misses);
}
