//! End-to-end runtime check: the AOT artifacts load, compile and execute
//! through the PJRT CPU client, the chain segments a synthetic tile, and
//! the comparison task returns sane metrics.

use std::collections::HashMap;

use rtf_reuse::data::{synth_tile, SynthConfig};
use rtf_reuse::runtime::PjrtEngine;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn default_params() -> HashMap<String, Vec<f32>> {
    let mut m = HashMap::new();
    m.insert("norm".into(), vec![]);
    m.insert("t1".into(), vec![220.0, 220.0, 220.0, 4.0, 4.0]);
    m.insert("t2".into(), vec![40.0, 8.0]);
    m.insert("t3".into(), vec![8.0]);
    m.insert("t4".into(), vec![20.0, 10.0, 1200.0]);
    m.insert("t5".into(), vec![10.0]);
    m.insert("t6".into(), vec![8.0]);
    m.insert("t7".into(), vec![10.0, 1200.0]);
    m
}

#[test]
fn chain_executes_and_segments() {
    let mut engine = PjrtEngine::load(artifacts_dir()).expect("run `make artifacts` first");
    let (h, w) = engine.tile_shape();
    let tile = synth_tile(&SynthConfig::new(h, w, 42));

    let state = engine.run_chain(&tile, &default_params()).unwrap();
    let mask = &state[1];
    let on = mask.count_above(0.5);
    assert!(on > 50, "expected segmented nuclei pixels, got {on}");
    assert!(
        (on as f64) < (h * w) as f64 * 0.5,
        "mask flooded the tile: {on} of {}",
        h * w
    );

    // self-comparison is perfect
    let m = engine.execute_compare(&state, mask).unwrap();
    assert!((m[0] - 1.0).abs() < 1e-4, "self-dice {}", m[0]);
    assert!((m[1] - 1.0).abs() < 1e-4, "self-jaccard {}", m[1]);
    assert!(m[2].abs() < 1e-6, "self-diff {}", m[2]);

    // determinism across re-execution
    let state2 = engine.run_chain(&tile, &default_params()).unwrap();
    assert_eq!(state[1], state2[1]);

    // perturbing the influential G1 parameter changes the output
    let mut params = default_params();
    params.insert("t2".into(), vec![75.0, 8.0]);
    let state3 = engine.run_chain(&tile, &params).unwrap();
    let d = engine.execute_compare(&state3, mask).unwrap();
    assert!(d[0] < 0.999, "G1 perturbation must change the mask, dice={}", d[0]);

    // timer collected per-task stats
    let rows = engine.timer().summary();
    assert!(rows.iter().any(|(name, mean, n)| name == "t2" && *mean > 0.0 && *n >= 3));
}
