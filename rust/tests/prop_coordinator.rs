//! Property-based sweep over study planning and simulated execution:
//! random study shapes through `plan_study` + the DES, checking the
//! coordinator-level invariants (every task exactly once, dependency
//! order, work conservation, scaling monotonicity).

use rtf_reuse::config::{SaMethod, SamplerKind, StudyConfig};
use rtf_reuse::data::SplitMix64;
use rtf_reuse::driver::{prepare, run_sim};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions, UnitKind};
use rtf_reuse::simulate::{default_cost_model, SimOptions};

fn random_cfg(rng: &mut SplitMix64) -> StudyConfig {
    let method = if rng.next_f64() < 0.5 {
        SaMethod::Moat { r: rng.uniform_usize(1, 6) }
    } else {
        SaMethod::Vbd { n: rng.uniform_usize(2, 20), k_active: rng.uniform_usize(2, 9) }
    };
    let sampler = match rng.uniform_usize(0, 3) {
        0 => SamplerKind::Qmc,
        1 => SamplerKind::Mc,
        _ => SamplerKind::Lhs,
    };
    let algorithm = match rng.uniform_usize(0, 5) {
        0 => FineAlgorithm::None,
        1 => FineAlgorithm::Naive(rng.uniform_usize(1, 9)),
        2 => FineAlgorithm::Sca(rng.uniform_usize(1, 6)),
        3 => FineAlgorithm::Rtma(rng.uniform_usize(1, 9)),
        _ => FineAlgorithm::Trtma(TrtmaOptions::new(rng.uniform_usize(1, 12))),
    };
    StudyConfig {
        method,
        sampler,
        algorithm,
        coarse: rng.next_f64() < 0.8,
        workers: rng.uniform_usize(1, 9),
        tiles: rng.uniform_usize(1, 3),
        seed: rng.next_u64() % 1000,
        ..StudyConfig::default()
    }
}

#[test]
fn random_studies_plan_and_simulate_consistently() {
    let mut rng = SplitMix64::new(0x5EED);
    let model = default_cost_model();
    for case in 0..40 {
        let cfg = random_cfg(&mut rng);
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        plan.assert_valid(&prepared.graph); // partition + dep direction

        // every instance's tasks are covered exactly once per unique node
        let node_tasks: usize = prepared
            .graph
            .nodes
            .iter()
            .map(|n| prepared.instances[n.rep].tasks.len())
            .sum();
        assert_eq!(plan.fine.tasks_replica, node_tasks, "case {case}");
        assert!(plan.fine.tasks_merged <= node_tasks);

        let opts = SimOptions::new(cfg.workers).with_cores(rng.uniform_usize(1, 17));
        let rep = run_sim(&prepared, &plan, &model, &opts);
        assert_eq!(rep.units, plan.units.len(), "case {case}: every unit exactly once");
        assert_eq!(rep.tasks, plan.tasks_to_execute(), "case {case}");
        assert!(rep.makespan > 0.0);
        // work conservation: busy time == sum of unit durations
        let busy: f64 = rep.worker_busy.iter().sum();
        assert!(
            (busy - rep.total_work).abs() < 1e-6 * rep.total_work.max(1.0),
            "case {case}: busy {busy} vs work {}",
            rep.total_work
        );
        assert!(rep.utilization() <= 1.0 + 1e-9);
        // makespan bounds: critical work <= makespan <= total work (1 wp)
        assert!(rep.makespan <= rep.total_work + 1e-6);
    }
}

#[test]
fn worker_scaling_is_monotone_and_bounded() {
    let mut rng = SplitMix64::new(0xACE);
    let model = default_cost_model();
    for _ in 0..8 {
        let mut cfg = random_cfg(&mut rng);
        cfg.workers = 1;
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        let mut last = f64::INFINITY;
        let one_wp = run_sim(&prepared, &plan, &model, &SimOptions::new(1)).makespan;
        for wp in [1usize, 2, 4, 8, 32, 1024] {
            let rep = run_sim(&prepared, &plan, &model, &SimOptions::new(wp));
            assert!(rep.makespan <= last + 1e-9, "wp={wp}");
            // never better than the longest unit (critical path >= max dur)
            assert!(rep.makespan * wp as f64 >= one_wp * 0.999 / wp as f64);
            last = rep.makespan;
        }
    }
}

#[test]
fn reuse_never_changes_the_work_multiset_semantics() {
    // plans with reuse execute a subset of the replica tasks; the plan's
    // unique-task accounting must agree between planner and simulator
    // for every algorithm on the same study
    let mut rng = SplitMix64::new(0x7777);
    let model = default_cost_model();
    for _ in 0..10 {
        let mut cfg = random_cfg(&mut rng);
        cfg.coarse = true;
        let prepared = prepare(&cfg);
        let mut merged_costs = Vec::new();
        for algo in [
            FineAlgorithm::None,
            FineAlgorithm::Naive(5),
            FineAlgorithm::Rtma(5),
            FineAlgorithm::Trtma(TrtmaOptions::new(6)),
        ] {
            let mut c = cfg.clone();
            c.algorithm = algo;
            let plan = prepared.plan(&c);
            let rep = run_sim(&prepared, &plan, &model, &SimOptions::new(4));
            assert_eq!(rep.tasks, plan.fine.tasks_merged);
            merged_costs.push(plan.fine.tasks_merged);
        }
        // "None" executes the most tasks; every real algorithm at most that
        let none_cost = merged_costs[0];
        for &c in &merged_costs[1..] {
            assert!(c <= none_cost);
        }
    }
}

#[test]
fn merged_units_only_in_multi_task_stages() {
    let mut rng = SplitMix64::new(0x31337);
    for _ in 0..10 {
        let cfg = random_cfg(&mut rng);
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        for u in &plan.units {
            if u.kind == UnitKind::Merged {
                assert!(u.nodes.len() >= 2);
                // merged units share their input signature
                let sig =
                    prepared.instances[prepared.graph.nodes[u.nodes[0]].rep].input_sig;
                for &n in &u.nodes {
                    assert_eq!(
                        prepared.instances[prepared.graph.nodes[n].rep].input_sig,
                        sig
                    );
                }
            }
        }
    }
}
