//! The chaos capstone: a real two-node cluster (TCP listeners, rtfp v4,
//! partitioned key space) runs studies while a *scripted* fault plan
//! panics a worker mid-study, tears and fails disk-tier writes, refuses
//! and drops peer connections, and corrupts a cache-state frame on the
//! wire. The properties under test are the robustness claims as a
//! bundle:
//!
//! * every submitted job still completes (retries absorb the panic,
//!   the breaker and bounded waits absorb the flapping peer),
//! * the results are **bit-identical** to a fault-free run of the same
//!   seed — self-healing must never change what is computed,
//! * the retried attempts show up in the drain bill (billed work is
//!   work performed, not work requested),
//! * drain completes — no scripted fault may wedge the service, and
//! * the per-tenant scoped ledgers still partition the node globals.
//!
//! The plan is derived deterministically from a seed so CI can pin
//! seeds (`RTF_CHAOS_SEED=N`) and any failure reproduces exactly.
//!
//! Cluster phase 2 adds a **membership-chaos** schedule: a peer leaves
//! mid-study over the wire admin path (`peers remove=`), rejoins, and
//! the study's runner gets a scripted streak of refused dials that
//! opens a circuit breaker toward an owner — degrading its lookups to
//! replica peeks. Same bundle of claims: every job completes, results
//! stay bit-identical to a fault-free single node, and drain never
//! wedges.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use rtf_reuse::cache::{CacheConfig, CacheTier};
use rtf_reuse::config::StudyConfig;
use rtf_reuse::faults::{DiskFault, FaultPlan, Faults, PeerFault};
use rtf_reuse::serve::protocol::{WireBill, WireJobReport};
use rtf_reuse::serve::{
    run_jobs, run_lines, JobLine, JobSpec, ServeOptions, ServiceReport, StudyJob, StudyService,
    WireServer,
};

fn study_args() -> Vec<String> {
    vec!["method=moat".into(), "r=1".into(), "batch-width=16".into()]
}

/// Reserve a loopback address the OS just proved free (same idiom as
/// `tests/cluster.rs`; the rebind window is negligible on loopback).
fn reserve_addr() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").expect("reserve a port");
    listener.local_addr().expect("reserved addr").to_string()
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rtf-chaos-{tag}-{}", std::process::id()))
}

/// The seeds this invocation exercises: `RTF_CHAOS_SEED` pins one (CI's
/// chaos-smoke job runs two fixed ones); the default keeps the local
/// `cargo test` run to a single cluster pair.
fn seeds() -> Vec<u64> {
    match std::env::var("RTF_CHAOS_SEED") {
        Ok(v) => vec![v.parse().expect("RTF_CHAOS_SEED must be a u64")],
        Err(_) => vec![7],
    }
}

/// The shared splitmix64 stream expands a seed into fault ordinals —
/// one definition in `rtf_reuse::testutil` keeps CI's pinned chaos
/// seeds meaning the same fault schedule everywhere.
use rtf_reuse::testutil::splitmix64 as splitmix;

/// Node A hosts the cold study, so it gets the heavy script: a worker
/// panic early in the run, one torn and one failed disk write, a
/// refused peer dial, and a corrupted outbound cache-state frame. The
/// ordinals are kept small so every scripted site is guaranteed to be
/// reached by a MOAT r=1 study (dozens of launches and inserts).
fn plan_for_node_a(seed: u64) -> FaultPlan {
    let mut s = seed;
    FaultPlan::new()
        .panic_on_launch(2 + splitmix(&mut s) % 4)
        .disk_fault(1 + splitmix(&mut s) % 3, DiskFault::ShortWrite)
        .disk_fault(5 + splitmix(&mut s) % 3, DiskFault::IoError)
        .peer_fault(1 + splitmix(&mut s) % 2, PeerFault::Refuse)
        .corrupt_frame(1 + splitmix(&mut s) % 2)
}

/// Node B rides the fabric for its warm study, so its script flaps the
/// peer link: a refused dial, a dropped connection, added latency.
fn plan_for_node_b(seed: u64) -> FaultPlan {
    let mut s = seed ^ 0xB0B;
    FaultPlan::new()
        .peer_fault(1 + splitmix(&mut s) % 2, PeerFault::Refuse)
        .peer_fault(3 + splitmix(&mut s) % 2, PeerFault::Drop)
        .peer_fault(6, PeerFault::Delay(Duration::from_millis(10)))
}

fn node_opts(peers: &[String], own: &str, faults: Faults, dir: PathBuf) -> ServeOptions {
    ServeOptions {
        service_workers: 1,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig {
            capacity_bytes: 512 * 1024 * 1024,
            spill_dir: Some(dir),
            ..CacheConfig::default()
        },
        peers: peers.to_vec(),
        cluster_addr: Some(own.to_string()),
        faults,
        ..ServeOptions::default()
    }
}

fn spawn_node(opts: ServeOptions, addr: &str) -> thread::JoinHandle<ServiceReport> {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds its reserved addr");
    thread::spawn(move || server.run().expect("node drains cleanly"))
}

/// One full cluster round: cold study on A, warm study on B, drain B
/// then A. Returns both job reports and both bills; panics if either
/// node fails to drain (the no-wedge assertion is the join itself).
struct ClusterRun {
    cold: WireJobReport,
    warm: WireJobReport,
    bill_a: WireBill,
    bill_b: WireBill,
}

fn run_cluster(tag: &str, faults_a: Faults, faults_b: Faults) -> ClusterRun {
    let dir_a = temp_dir(&format!("{tag}-a"));
    let dir_b = temp_dir(&format!("{tag}-b"));
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);

    let addr_a = reserve_addr();
    let addr_b = reserve_addr();
    let peers = vec![addr_a.clone(), addr_b.clone()];
    let node_a = spawn_node(node_opts(&peers, &addr_a, faults_a, dir_a.clone()), &addr_a);
    let node_b = spawn_node(node_opts(&peers, &addr_b, faults_b, dir_b.clone()), &addr_b);

    let spec = JobSpec { tenant: "cold".into(), args: study_args(), tune: false };
    let cold = run_jobs(&addr_a, &[spec], false).expect("cold run completes");
    let spec = JobSpec { tenant: "warm".into(), args: study_args(), tune: false };
    let warm = run_jobs(&addr_b, &[spec], false).expect("warm run completes");

    let bill_b = run_jobs(&addr_b, &[], true).expect("drain B").bill.expect("B's bill");
    let bill_a = run_jobs(&addr_a, &[], true).expect("drain A").bill.expect("A's bill");
    node_a.join().expect("node A joins");
    node_b.join().expect("node B joins");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);

    ClusterRun { cold: cold.jobs[0].clone(), warm: warm.jobs[0].clone(), bill_a, bill_b }
}

/// Per-tenant scoped counters must still sum exactly to the node's
/// globals under chaos — faults may change *how much* work each tier
/// did, never the ledger arithmetic.
fn assert_scoped_sums_match(bill: &WireBill, node: &str) {
    let sums = bill.tenants.iter().fold((0, 0, 0, 0, 0), |acc, t| {
        (
            acc.0 + t.cache.hits,
            acc.1 + t.cache.disk_hits,
            acc.2 + t.cache.remote_hits,
            acc.3 + t.cache.misses,
            acc.4 + t.cache.inserts,
        )
    });
    assert_eq!(sums.0, bill.cache.hits, "{node}: scoped hits partition the globals");
    assert_eq!(sums.1, bill.cache.disk_hits, "{node}: scoped disk hits partition the globals");
    assert_eq!(sums.2, bill.cache.remote_hits, "{node}: scoped remote hits partition the globals");
    assert_eq!(sums.3, bill.cache.misses, "{node}: scoped misses partition the globals");
    assert_eq!(sums.4, bill.cache.inserts, "{node}: scoped inserts partition the globals");
}

#[test]
fn scripted_chaos_is_survived_and_bit_identical_to_the_fault_free_run() {
    for seed in seeds() {
        // ground truth: the same cluster shape with no faults installed
        let base =
            run_cluster(&format!("base-{seed}"), Faults::none(), Faults::none());
        assert!(base.cold.ok(), "seed {seed}: baseline cold job: {:?}", base.cold.error);
        assert!(base.warm.ok(), "seed {seed}: baseline warm job: {:?}", base.warm.error);
        assert_eq!(base.bill_a.retries, 0, "seed {seed}: fault-free run retries nothing");

        // the same cluster under the seed's scripted chaos
        let plan_a = Arc::new(plan_for_node_a(seed));
        let plan_b = Arc::new(plan_for_node_b(seed));
        let chaos = run_cluster(
            &format!("chaos-{seed}"),
            Faults::hooked(plan_a.clone()),
            Faults::hooked(plan_b.clone()),
        );

        // every job completes despite the panic, the torn disk writes
        // and the flapping peer link
        assert!(chaos.cold.ok(), "seed {seed}: chaos cold job: {:?}", chaos.cold.error);
        assert!(chaos.warm.ok(), "seed {seed}: chaos warm job: {:?}", chaos.warm.error);

        // the robustness invariant: self-healing never changes results
        assert_eq!(base.cold.y, chaos.cold.y, "seed {seed}: cold results bit-identical");
        assert_eq!(base.warm.y, chaos.warm.y, "seed {seed}: warm results bit-identical");

        // the scripted faults actually fired (the plan exercised the
        // machinery, it did not just schedule events past the end)
        let fired_a = plan_a.fired();
        assert_eq!(fired_a.launch_panics, 1, "seed {seed}: the worker panic fired");
        assert_eq!(fired_a.disk_faults, 2, "seed {seed}: both disk faults fired");
        assert!(
            fired_a.peer_faults + plan_b.fired().peer_faults >= 1,
            "seed {seed}: at least one scripted peer fault fired"
        );

        // the panic cost one retried attempt, and the bill says so —
        // on the job, on the tenant row, and on the aggregate
        assert_eq!(chaos.cold.retries, 1, "seed {seed}: the panicked job retried once");
        assert_eq!(chaos.bill_a.retries, 1, "seed {seed}: the bill carries the retry");
        let cold_row = chaos
            .bill_a
            .tenants
            .iter()
            .find(|t| t.tenant == "cold")
            .expect("cold tenant billed");
        assert_eq!(cold_row.retries, 1, "seed {seed}: the tenant row carries the retry");
        assert_eq!(cold_row.failed, 0, "seed {seed}: a retried-then-ok job is not a failure");

        // ledgers stay exact under chaos
        assert_scoped_sums_match(&chaos.bill_a, "chaos node A");
        assert_scoped_sums_match(&chaos.bill_b, "chaos node B");
    }
}

/// Start a node and keep its service handle too — the membership test
/// asks nodes for their ring size and submits in-process to overlap a
/// study with admin traffic.
fn spawn_node_with_svc(
    opts: ServeOptions,
    addr: &str,
) -> (Arc<StudyService>, thread::JoinHandle<ServiceReport>) {
    let svc = StudyService::start(opts).expect("node starts");
    let server = WireServer::bind(svc, addr).expect("node binds its reserved addr");
    let svc = Arc::clone(server.service());
    (svc, thread::spawn(move || server.run().expect("node drains cleanly")))
}

fn ring_size(svc: &StudyService) -> usize {
    svc.remote_tier().expect("cluster node").ring().peers().len()
}

/// Node C runs the mid-chaos study, so its script refuses a streak of
/// six consecutive outbound peer dials starting near the front. Six
/// consecutive failures split over two remote addresses put at least
/// three unbroken failures on one of them — a guaranteed breaker open,
/// wherever the seed lands the streak.
fn plan_for_node_c(seed: u64) -> FaultPlan {
    let mut s = seed ^ 0xC0C;
    let start = 1 + splitmix(&mut s) % 2;
    let mut plan = FaultPlan::new();
    for i in 0..6 {
        plan = plan.peer_fault(start + i, PeerFault::Refuse);
    }
    plan
}

/// The membership-chaos schedule: on a three-node ring (replicas=1), a
/// peer leaves mid-study through the wire admin path and later rejoins,
/// while the running node's scripted dial refusals open a breaker
/// toward an owner. Every job completes, every result is bit-identical
/// to a fault-free single-node run, the rings converge after each
/// change, and the ledgers stay exact.
#[test]
fn a_peer_leaving_and_rejoining_mid_study_never_changes_results() {
    for seed in seeds() {
        // ground truth: the same study on a fault-free single node
        let solo_dir = temp_dir(&format!("member-solo-{seed}"));
        let _ = std::fs::remove_dir_all(&solo_dir);
        let solo_opts =
            node_opts(&[], "", Faults::none(), solo_dir.clone());
        let solo_opts = ServeOptions { peers: vec![], cluster_addr: None, ..solo_opts };
        let solo = StudyService::start(solo_opts).expect("solo starts");
        let server = WireServer::bind(solo, "127.0.0.1:0").expect("bind loopback");
        let solo_addr = server.local_addr().expect("bound").to_string();
        let solo_handle = thread::spawn(move || server.run().expect("solo drains"));
        let spec = JobSpec { tenant: "solo".into(), args: study_args(), tune: false };
        let base = run_jobs(&solo_addr, &[spec], true).expect("solo run succeeds");
        solo_handle.join().expect("solo joins");
        let _ = std::fs::remove_dir_all(&solo_dir);
        assert!(base.jobs[0].ok(), "seed {seed}: solo job: {:?}", base.jobs[0].error);
        let solo_y = &base.jobs[0].y;

        let dirs: Vec<PathBuf> =
            (0..3).map(|i| temp_dir(&format!("member-{seed}-{i}"))).collect();
        for d in &dirs {
            let _ = std::fs::remove_dir_all(d);
        }
        let addrs: Vec<String> = (0..3).map(|_| reserve_addr()).collect();
        let plan_c = Arc::new(plan_for_node_c(seed));
        let faults =
            [Faults::none(), Faults::none(), Faults::hooked(plan_c.clone())];
        let nodes: Vec<_> = addrs
            .iter()
            .zip(faults)
            .zip(&dirs)
            .map(|((a, f), d)| {
                (spawn_node_with_svc(node_opts(&addrs, a, f, d.clone()), a), a.clone())
            })
            .collect();
        let svc = |i: usize| -> &StudyService { &nodes[i].0 .0 };

        // warm the fabric: cold on A, warm on B — B now holds a full
        // copy, which is what the replica peeks lean on later
        for (i, tenant) in ["cold", "warm"].iter().enumerate() {
            let spec = JobSpec { tenant: tenant.to_string(), args: study_args(), tune: false };
            let out = run_jobs(&addrs[i], &[spec], false).expect("warm-up completes");
            assert!(out.jobs[0].ok(), "seed {seed}: warm-up {i}: {:?}", out.jobs[0].error);
            assert_eq!(&out.jobs[0].y, solo_y, "seed {seed}: warm-up {i} matches solo");
        }

        // the chaos window: submit on C in-process, then while it runs
        // (through C's scripted dial refusals) pull B out of the ring
        // over the wire admin path — exactly what a jobs-file
        // `peers remove=` line sends
        let cfg = StudyConfig::from_args(&study_args()).expect("study parses");
        let job = svc(2)
            .submit(StudyJob { tenant: "chaos".into(), cfg })
            .expect("mid-chaos submit accepted");
        run_lines(&addrs[0], &[JobLine::PeerRemove(addrs[1].clone())], false)
            .expect("admin leave accepted");
        let report = svc(2).wait_job(job).expect("chaos job tracked");
        assert!(report.ok(), "seed {seed}: mid-chaos job: {:?}", report.error);
        assert_eq!(&report.y, solo_y, "seed {seed}: membership chaos never changes results");

        // the leave relayed everywhere: A and C dropped B, and B — told
        // of its own departure — collapsed to a solo ring but kept
        // serving its local work
        assert_eq!(ring_size(svc(0)), 2, "seed {seed}: A dropped the departed peer");
        assert_eq!(ring_size(svc(2)), 2, "seed {seed}: C dropped the departed peer");
        assert_eq!(ring_size(svc(1)), 1, "seed {seed}: the departed node runs solo");

        // the scripted refusals fired and opened a per-address breaker;
        // degraded lookups went to replica peeks, not a wedge
        assert!(
            plan_c.fired().peer_faults >= 3,
            "seed {seed}: the refusal streak fired ({} faults)",
            plan_c.fired().peer_faults
        );
        let breaker_opens = svc(2).remote_tier().expect("cluster node").stats().breaker_opens;
        assert!(breaker_opens >= 1, "seed {seed}: the refusal streak opened a breaker");

        // rejoin: the members re-admit B over the wire (`peers add=`),
        // and B itself is re-pointed at its peers — the in-process
        // equivalent of restarting it with `peers=` or feeding it its
        // own `peers add=` lines
        run_lines(&addrs[0], &[JobLine::PeerAdd(addrs[1].clone())], false)
            .expect("admin rejoin accepted");
        svc(1).peer_join(&addrs[0], false).expect("rejoiner re-adds A");
        svc(1).peer_join(&addrs[2], false).expect("rejoiner re-adds C");
        for i in 0..3 {
            assert_eq!(ring_size(svc(i)), 3, "seed {seed}: node {i} converged after rejoin");
        }

        // the rejoined node still serves and computes correctly
        let spec = JobSpec { tenant: "after".into(), args: study_args(), tune: false };
        let out = run_jobs(&addrs[1], &[spec], false).expect("post-rejoin job completes");
        assert!(out.jobs[0].ok(), "seed {seed}: post-rejoin job: {:?}", out.jobs[0].error);
        assert_eq!(&out.jobs[0].y, solo_y, "seed {seed}: post-rejoin result matches solo");

        // no scripted or membership fault may wedge drain; ledgers exact
        let mut bills = Vec::new();
        for i in (0..3).rev() {
            let bill =
                run_jobs(&addrs[i], &[], true).expect("drain node").bill.expect("bill");
            bills.push((i, bill));
        }
        for ((_, handle), _) in nodes {
            handle.join().expect("node joins");
        }
        for (i, bill) in &bills {
            assert_scoped_sums_match(bill, &format!("member node {i}"));
        }
        for d in &dirs {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}
