//! Frontier-batched execution: batch width must never change results —
//! metrics bit-identical, cache contents identical — and the batched
//! engine call must publish exactly its miss keys.

use std::sync::Arc;

use rtf_reuse::cache::{Key, ReuseCache};
use rtf_reuse::config::{SaMethod, SamplerKind, StudyConfig};
use rtf_reuse::data::{synth_tile, SplitMix64, SynthConfig};
use rtf_reuse::driver::{prepare, run_pjrt_with_cache};
use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};
use rtf_reuse::runtime::PjrtEngine;

fn artifacts_dir() -> String {
    format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
}

fn fan_out_cfg(width: usize) -> StudyConfig {
    StudyConfig {
        method: SaMethod::Moat { r: 1 }, // 16 evaluations
        // one bucket per merge group: the widest frontiers the study has
        algorithm: FineAlgorithm::Trtma(TrtmaOptions::new(1)),
        workers: 2,
        batch_width: width,
        artifacts_dir: artifacts_dir(),
        ..StudyConfig::default()
    }
}

#[test]
fn batch_width_never_changes_results_or_cache_contents() {
    let mut runs: Vec<(rtf_reuse::coordinator::StudyOutcome, Arc<ReuseCache>)> = Vec::new();
    for width in [1usize, 4, 16] {
        let cfg = fan_out_cfg(width);
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        let cache = Arc::new(ReuseCache::with_capacity(512 * 1024 * 1024));
        let outcome = run_pjrt_with_cache(&cfg, &prepared, &plan, Some(cache.clone()))
            .expect("run `make artifacts` first");
        runs.push((outcome, cache));
    }
    let (base, base_cache) = &runs[0];
    for (o, c) in &runs[1..] {
        // [f32; 3] equality is exact: bit-identical metrics
        assert_eq!(base.metrics, o.metrics, "metrics drift across batch widths");
        assert_eq!(
            base_cache.resident_keys(),
            c.resident_keys(),
            "state cache contents drift across batch widths"
        );
        assert_eq!(
            base_cache.metric_keys(),
            c.metric_keys(),
            "metric cache contents drift across batch widths"
        );
    }
}

#[test]
fn randomized_studies_are_width_invariant() {
    let mut rng = SplitMix64::new(0xBA7C4);
    for _ in 0..2 {
        let sampler = match rng.uniform_usize(0, 3) {
            0 => SamplerKind::Qmc,
            1 => SamplerKind::Mc,
            _ => SamplerKind::Lhs,
        };
        let algorithm = match rng.uniform_usize(0, 3) {
            0 => FineAlgorithm::Rtma(rng.uniform_usize(2, 9)),
            1 => FineAlgorithm::Trtma(TrtmaOptions::new(rng.uniform_usize(1, 5))),
            _ => FineAlgorithm::Naive(rng.uniform_usize(2, 7)),
        };
        let seed = rng.next_u64() % 1000;
        let mut outcomes = Vec::new();
        for width in [1usize, 8] {
            let cfg = StudyConfig {
                sampler,
                algorithm,
                seed,
                ..fan_out_cfg(width)
            };
            let prepared = prepare(&cfg);
            let plan = prepared.plan(&cfg);
            let outcome =
                run_pjrt_with_cache(&cfg, &prepared, &plan, None).expect("study executes");
            outcomes.push(outcome);
        }
        assert_eq!(
            outcomes[0].metrics, outcomes[1].metrics,
            "randomized study (sampler {}, algo {}, seed {seed}) drifted with batching",
            sampler.name(),
            algorithm.name()
        );
    }
}

#[test]
fn batch_partition_publishes_exactly_the_miss_keys() {
    let mut engine = PjrtEngine::load(artifacts_dir()).expect("run `make artifacts` first");
    let cache = Arc::new(ReuseCache::with_capacity(64 * 1024 * 1024));
    engine.set_cache(cache.clone());
    let (h, w) = engine.tile_shape();
    let tile = synth_tile(&SynthConfig::new(h, w, 7));
    let state = engine.lit_state(&[tile.r.clone(), tile.g.clone(), tile.b.clone()]).unwrap();
    let id = engine.task_id("t1").expect("t1 artifact present");
    let params: Vec<Vec<f32>> = vec![
        vec![220.0, 220.0, 220.0, 4.0, 4.0],
        vec![200.0, 210.0, 215.0, 3.0, 5.0],
        vec![230.0, 205.0, 225.0, 4.0, 3.5],
    ];
    let (k0, k1, k2) = (Key::from(101u64), Key::from(202u64), Key::from(303u64));

    // pre-populate lane 0's key
    let _ = engine.execute_task_lit_keyed_id(id, Some(k0), &state, &params[0]).unwrap();
    assert!(cache.contains_state(k0));
    let inserts_before = cache.stats().inserts;

    let keys = [Some(k0), Some(k1), Some(k2)];
    let states = [&state, &state, &state];
    let p_refs: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    let res = engine.execute_task_batch_keyed(id, &keys, &states, &p_refs).unwrap();
    assert_eq!(res.len(), 3);
    assert!(res[0].1, "lane 0 must be served from the cache");
    assert!(!res[1].1 && !res[2].1, "lanes 1, 2 are misses");
    assert!(cache.contains_state(k1) && cache.contains_state(k2));
    assert_eq!(
        cache.stats().inserts - inserts_before,
        2,
        "exactly the miss keys are published"
    );

    // miss lanes must match the scalar execution bit-for-bit
    let direct = engine.execute_task_lit("t1", &state, &params[1]).unwrap();
    for (a, b) in direct.iter().zip(&res[1].0) {
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
    }
}

#[test]
fn duplicate_keys_within_a_batch_dedupe_like_the_sequential_path() {
    // Two miss lanes sharing one (e.g. quantized) chain key: the
    // sequential path executes the first and serves the second from the
    // just-published state. The batched partition must match — one
    // execution, one insert, identical states on both lanes.
    let mut engine = PjrtEngine::load(artifacts_dir()).unwrap();
    let cache = Arc::new(ReuseCache::with_capacity(64 * 1024 * 1024));
    engine.set_cache(cache.clone());
    let (h, w) = engine.tile_shape();
    let tile = synth_tile(&SynthConfig::new(h, w, 9));
    let state = engine.lit_state(&[tile.r.clone(), tile.g.clone(), tile.b.clone()]).unwrap();
    let id = engine.task_id("t1").unwrap();
    let p0: &[f32] = &[220.0, 220.0, 220.0, 4.0, 4.0];
    let p1: &[f32] = &[220.4, 220.0, 220.0, 4.0, 4.0]; // same quantized cell, say
    let shared = Key::from(0xdeadu64);
    let before = cache.stats();
    let res = engine
        .execute_task_batch_keyed(id, &[Some(shared), Some(shared)], &[&state, &state], &[p0, p1])
        .unwrap();
    assert!(!res[0].1, "first lane executes");
    assert!(res[1].1, "second lane is served the first's result");
    let after = cache.stats();
    assert_eq!(after.inserts - before.inserts, 1, "one publication for the shared key");
    // counter parity with the sequential path: one miss (first lane's
    // lookup), one hit (second lane served after publication)
    assert_eq!(after.misses - before.misses, 1);
    assert_eq!(after.hits - before.hits, 1);
    for (a, b) in res[0].0.iter().zip(&res[1].0) {
        assert_eq!(a.to_vec::<f32>().unwrap(), b.to_vec::<f32>().unwrap());
    }
}

#[test]
fn spill_dirs_are_cleaned_up_and_never_collide() {
    use rtf_reuse::coordinator::{execute_study, ExecuteOptions};
    use rtf_reuse::driver::{make_tiles, reference_masks};
    use rtf_reuse::sampling::default_space;

    let cfg = fan_out_cfg(8);
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let mut engine = PjrtEngine::load(&cfg.artifacts_dir).unwrap();
    let (h, w) = engine.tile_shape();
    let tiles = make_tiles(&cfg, h, w);
    let refs =
        reference_masks(&mut engine, &default_space(), &prepared.workflow, &tiles).unwrap();
    drop(engine);

    let opts = ExecuteOptions::new(2, &cfg.artifacts_dir).with_state_limit(64 * 1024);
    execute_study(&opts, &plan, &prepared.graph, &prepared.instances, &tiles, &refs,
        prepared.n_evals())
    .unwrap();

    // every spill dir of this process must be gone after execution
    let prefix = format!("rtf-reuse-spill-{}-", std::process::id());
    let leftovers: Vec<String> = std::fs::read_dir(std::env::temp_dir())
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with(&prefix))
        .collect();
    assert!(leftovers.is_empty(), "spill dirs leaked: {leftovers:?}");
}
