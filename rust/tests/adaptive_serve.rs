//! The serve-side half of the run-time adaptivity story: adaptive
//! studies bill their pruned evaluations at every level, speculative
//! pre-execution is billed globally under the `~speculative`
//! pseudo-tenant (never as a tenant's misses), drain never wedges on
//! in-flight speculation, and the per-tenant scoped ledgers still
//! partition the globals with speculation on. The standalone safety
//! properties (surviving results bit-identical, `threshold=0` exact)
//! live in `tests/prop_adaptive.rs`; this file proves the same
//! machinery behaves under the multi-tenant service.

use std::thread;
use std::time::{Duration, Instant};

use rtf_reuse::cache::CacheConfig;
use rtf_reuse::config::{StudyConfig, TuneConfig};
use rtf_reuse::sampling::default_space;
use rtf_reuse::serve::{ServeOptions, ServiceReport, StudyJob, StudyService, SPECULATIVE_TENANT};

fn opts(service_workers: usize) -> ServeOptions {
    ServeOptions {
        service_workers,
        tenant_inflight_cap: 1,
        study_workers: 2,
        cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
        ..ServeOptions::default()
    }
}

fn study_cfg(extra: &[&str]) -> StudyConfig {
    let mut args: Vec<String> = vec!["method=moat".into(), "r=2".into()];
    args.extend(extra.iter().map(|s| s.to_string()));
    StudyConfig::from_args(&args).expect("test study args parse")
}

/// A GA tune whose budget spans three generations, so the tuner offers
/// non-empty speculative predictions after the first and second.
fn ga_tune(extra: &[&str]) -> TuneConfig {
    let mut args: Vec<String> = ["tuner=ga", "budget=9", "population=3", "k-active=1", "r=1"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    args.extend(extra.iter().map(|s| s.to_string()));
    TuneConfig::from_args(&args).expect("test tune args parse")
}

fn wait_until(what: &str, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(120);
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        thread::sleep(Duration::from_millis(5));
    }
}

/// Per-tenant scoped counters — including the `~speculative`
/// pseudo-scope — must sum exactly to the shared cache's globals.
fn assert_scoped_sums_match(report: &ServiceReport) {
    let sums = report.scoped_totals();
    assert_eq!(sums.hits, report.cache.hits, "scoped hits partition the globals");
    assert_eq!(sums.disk_hits, report.cache.disk_hits, "scoped disk hits partition the globals");
    assert_eq!(sums.misses, report.cache.misses, "scoped misses partition the globals");
    assert_eq!(sums.inserts, report.cache.inserts, "scoped inserts partition the globals");
}

#[test]
fn speculative_spend_bills_the_pseudo_tenant_and_ledgers_stay_exact() {
    // one service worker: the tune job runs first, then the worker goes
    // idle and works through the speculation backlog — every offered
    // prediction executes through the scoped cache path before drain
    let svc = StudyService::start(opts(1)).expect("service starts");
    let tc = ga_tune(&["speculate=on"]);
    let id = svc.submit_tune("dora", tc.study, tc.options).expect("submit tune");
    let report = svc.wait_job(id).expect("job known");
    assert!(report.ok(), "tune job failed: {:?}", report.error);

    wait_until("the speculation backlog to drain", || svc.speculative_pending() == 0);
    let report = svc.drain();
    assert_eq!(report.jobs.len(), 1);
    assert!(report.jobs[0].ok());

    // a three-generation GA offers at least one non-empty prediction,
    // so the pseudo-tenant scope exists — with no jobs of its own, and
    // with real cache traffic from the pre-executions
    let spec = report.tenant(SPECULATIVE_TENANT).expect("speculative pseudo-tenant billed");
    assert_eq!(spec.jobs, 0, "the pseudo-tenant owns no jobs");
    assert!(
        spec.cache.hits + spec.cache.misses > 0,
        "speculative pre-execution went through the scoped cache path"
    );
    // the job-level count is a lower bound on the global speculative
    // spend (it reads whatever had executed by reporting time)
    assert!(report.jobs[0].speculative <= report.speculative_launches);
    // the launch ledger partitions: shared input builds + speculation +
    // per-job work, with speculation never inside a tenant's row
    assert_eq!(
        report.total_launches(),
        report.input_launches + report.speculative_launches + report.jobs[0].launches
    );
    assert_scoped_sums_match(&report);
}

#[test]
fn drain_during_inflight_speculation_never_wedges() {
    // two service workers and the service-level speculate flag: worker
    // two pre-executes predictions while worker one still runs the
    // tune. Draining mid-flight must complete the real job, discard or
    // finish the speculation, and join — the drain return IS the
    // no-wedge assertion
    let mut o = opts(2);
    o.speculate = true;
    let svc = StudyService::start(o).expect("service starts");
    let tc = ga_tune(&[]);
    let id = svc.submit_tune("erin", tc.study, tc.options).expect("submit tune");

    // drain as soon as speculation is observably queued, executing, or
    // the job finished first — any interleaving must drain cleanly
    wait_until("speculation or job completion", || {
        svc.speculative_pending() > 0 || svc.speculative_launches() > 0 || svc.completed() > 0
    });
    let report = svc.drain();

    assert_eq!(report.jobs.len(), 1, "the real job completed through the drain");
    assert!(report.jobs[0].ok(), "job failed: {:?}", report.jobs[0].error);
    assert_eq!(svc.speculative_pending(), 0, "drain leaves no speculation queued");
    assert_eq!(report.jobs[0].job, id);
    assert_eq!(report.jobs[0].tenant, "erin");
    assert_scoped_sums_match(&report);
}

#[test]
fn adaptive_studies_prune_and_bill_under_the_service() {
    let k = default_space().dim();
    let svc = StudyService::start(opts(1)).expect("service starts");
    // three tenants, same MOAT r=2 design: the exhaustive baseline, an
    // adaptive run at threshold=0 (must be exact), and an adaptive run
    // whose absurd threshold prunes every parameter after the first
    // trajectory (min-samples=1), dropping the entire second trajectory
    let full = study_cfg(&[]);
    let tiles = full.tiles;
    svc.submit(StudyJob { tenant: "full".into(), cfg: full }).unwrap();
    svc.submit(StudyJob {
        tenant: "exact".into(),
        cfg: study_cfg(&["adaptive=on", "threshold=0", "min-samples=1"]),
    })
    .unwrap();
    svc.submit(StudyJob {
        tenant: "pruned".into(),
        cfg: study_cfg(&["adaptive=on", "threshold=1e18", "min-samples=1"]),
    })
    .unwrap();
    let report = svc.drain();
    assert_eq!(report.jobs.len(), 3);
    assert!(report.jobs.iter().all(|j| j.ok()), "jobs: {:?}", report.jobs);
    let (full, exact, pruned) = (&report.jobs[0], &report.jobs[1], &report.jobs[2]);

    // threshold=0 never prunes: the adaptive run is the full run
    assert_eq!(exact.pruned, 0);
    assert_eq!(exact.y, full.y, "adaptive at threshold=0 is bit-identical to exhaustive");

    // the absurd threshold prunes all k parameters after trajectory 1:
    // its k+1 evaluations survive bit-identically, the second
    // trajectory's k+1 evaluations are pruned 0.0 sentinels
    let unit = (k + 1) * tiles;
    assert_eq!(pruned.pruned, unit as u64, "exactly one trajectory was pruned");
    assert_eq!(pruned.y[..unit], full.y[..unit], "surviving evaluations are bit-identical");
    assert!(pruned.y[unit..].iter().all(|&v| v == 0.0), "pruned slots hold the sentinel");

    // pruning is billed on the tenant rows, and only where it happened
    assert_eq!(report.tenant("full").unwrap().pruned, 0);
    assert_eq!(report.tenant("exact").unwrap().pruned, 0);
    assert_eq!(report.tenant("pruned").unwrap().pruned, unit as u64);
    assert_eq!(report.speculative_launches, 0, "studies never speculate");
    assert_scoped_sums_match(&report);
}
