//! Cross-study reuse-cache integration: correctness under quantization,
//! byte-bounded LRU behavior, concurrent access from scoped workers,
//! disk-tier persistence, and the two-study end-to-end guarantee — the
//! warm study executes fewer tasks yet produces identical results.

use std::path::PathBuf;
use std::sync::Arc;

use rtf_reuse::cache::{CacheConfig, CacheCtx, Key, ReuseCache};
use rtf_reuse::config::{SaMethod, StudyConfig};
use rtf_reuse::data::Plane;
use rtf_reuse::driver::{prepare, prune_plan_with_cache, run_pjrt_with_cache};
use rtf_reuse::merging::FineAlgorithm;

fn state(v: f32) -> [Plane; 3] {
    [Plane::filled(v, 8, 8), Plane::filled(v, 8, 8), Plane::filled(v, 8, 8)]
}

/// Bytes of one `state(v)`: 3 planes x 64 px x 4 B.
const SB: usize = 3 * 64 * 4;

/// Unscoped accounting context (global counters only).
fn ux() -> CacheCtx {
    CacheCtx::unscoped()
}

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rtf-cache-it-{tag}-{}", std::process::id()))
}

fn base_cfg() -> StudyConfig {
    StudyConfig {
        method: SaMethod::Moat { r: 1 }, // 16 evaluations
        algorithm: FineAlgorithm::Rtma(7),
        workers: 2,
        ..StudyConfig::default()
    }
}

fn executed_tasks(outcome: &rtf_reuse::coordinator::StudyOutcome) -> u64 {
    outcome
        .timer
        .summary()
        .iter()
        .filter(|(name, _, _)| !name.ends_with("#cached"))
        .map(|(_, _, n)| n)
        .sum()
}

fn cached_tasks(outcome: &rtf_reuse::coordinator::StudyOutcome) -> u64 {
    outcome
        .timer
        .summary()
        .iter()
        .filter(|(name, _, _)| name.ends_with("#cached"))
        .map(|(_, _, n)| n)
        .sum()
}

#[test]
fn lru_eviction_holds_the_byte_bound() {
    let c = ReuseCache::new(CacheConfig {
        capacity_bytes: 4 * SB,
        shards: 1,
        ..CacheConfig::default()
    });
    for k in 0..16u64 {
        c.put_state(Key::from(k), state(k as f32), &ux());
        assert!(
            c.resident_bytes() <= 4 * SB,
            "bound violated at insert {k}: {}",
            c.resident_bytes()
        );
    }
    let st = c.stats();
    assert_eq!(st.inserts, 16);
    assert_eq!(st.evictions, 12, "4 resident, 12 evicted");
    // the most recent entries survive, the oldest do not
    assert!(c.get_state(Key::from(15u64), &ux()).is_some());
    assert!(c.get_state(Key::from(0u64), &ux()).is_none());
}

#[test]
fn concurrent_scoped_workers_share_one_cache() {
    let cache = Arc::new(ReuseCache::new(CacheConfig {
        capacity_bytes: 1 << 20,
        shards: 4,
        ..CacheConfig::default()
    }));
    let workers = 8usize;
    let per = 32u64;
    std::thread::scope(|scope| {
        for w in 0..workers as u64 {
            let cache = &cache;
            scope.spawn(move || {
                for i in 0..per {
                    // half the keys are shared across all workers, half private
                    let shared = i % 2 == 0;
                    let raw = if shared { i } else { ((w + 1) << 32) | i };
                    let key = Key::from(raw);
                    if cache.get_state(key, &ux()).is_none() {
                        cache.put_state(key, state(raw as f32), &ux());
                    }
                    let got = cache.get_state(key, &ux()).expect("just inserted or present");
                    assert_eq!(got[0].get(0, 0), raw as f32, "no cross-key corruption");
                }
            });
        }
    });
    let st = cache.stats();
    let lookups = st.hits + st.disk_hits + st.misses;
    assert_eq!(lookups, workers as u64 * per * 2, "every lookup is counted");
    assert!(st.hits > 0 && st.misses > 0);
}

#[test]
fn disk_tier_persists_across_cache_instances() {
    let dir = tmp_dir("persist");
    let _ = std::fs::remove_dir_all(&dir);
    {
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: 1 << 20,
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        c.put_state(Key::from(0xfeedu64), state(7.5), &ux());
    } // first "process" ends
    let c2 = ReuseCache::new(CacheConfig {
        capacity_bytes: 1 << 20,
        spill_dir: Some(dir.clone()),
        ..CacheConfig::default()
    });
    assert!(
        c2.contains_state(Key::from(0xfeedu64)),
        "persistent tier visible to a fresh cache"
    );
    let got = c2.get_state(Key::from(0xfeedu64), &ux()).expect("served from disk");
    assert_eq!(got[2].get(7, 7), 7.5);
    assert_eq!(c2.stats().disk_hits, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn quantized_studies_share_cache_entries() {
    // two studies over the same tile whose parameters differ by less than
    // the quantization step must produce key collisions (approximate
    // reuse); with exact keys they must not.
    use rtf_reuse::cache::task_cache_sig;
    use rtf_reuse::workflow::{instantiate_study, paper_workflow, Evaluation};

    let wf = paper_workflow();
    let space = rtf_reuse::sampling::default_space();
    let mut p2 = space.defaults();
    p2[5] += 0.4; // G1 nudged off-grid by less than half a grid step
    let evals = vec![
        Evaluation { id: 0, tile: 0, params: space.defaults() },
        Evaluation { id: 1, tile: 0, params: p2 },
    ];
    let insts = instantiate_study(&wf, &evals);
    // t2 consumes G1: instances 1 and 4 are the segmentation stages
    let a = &insts[1].tasks[1];
    let b = &insts[4].tasks[1];
    assert_ne!(task_cache_sig(a, 0.0), task_cache_sig(b, 0.0), "exact keys differ");
    assert_eq!(task_cache_sig(a, 5.0), task_cache_sig(b, 5.0), "quantized keys match");
}

#[test]
fn two_study_end_to_end_executes_fewer_tasks_with_identical_results() {
    let cfg = base_cfg();
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);

    // ground truth without any cache
    let baseline = run_pjrt_with_cache(&cfg, &prepared, &plan, None).unwrap();

    let cache = Arc::new(ReuseCache::with_capacity(512 * 1024 * 1024));
    let first = run_pjrt_with_cache(&cfg, &prepared, &plan, Some(cache.clone())).unwrap();
    for (a, b) in baseline.y.iter().zip(&first.y) {
        assert!((a - b).abs() < 1e-9, "cold cached run must match baseline");
    }
    // the cold run may already reuse across buckets of one merge group
    // (different buckets share task prefixes the planner split apart), so
    // it executes at most the planned tasks
    let exec1 = executed_tasks(&first);
    assert!(exec1 as usize <= plan.tasks_to_execute(), "cold run never exceeds the plan");
    assert!(exec1 > 0);

    // second study: identical design, warm cache
    let prepared2 = prepare(&cfg);
    let mut plan2 = prepared2.plan(&cfg);
    let predicted = prune_plan_with_cache(&cfg, &prepared2, &mut plan2, &cache).unwrap();
    assert!(predicted > 0, "planning must see the warm cache");
    assert_eq!(plan2.cached_tasks, predicted);
    assert!(
        plan2.tasks_to_execute() < plan.tasks_to_execute(),
        "pruned plan predicts less work"
    );

    let second = run_pjrt_with_cache(&cfg, &prepared2, &plan2, Some(cache.clone())).unwrap();
    for (a, b) in baseline.y.iter().zip(&second.y) {
        assert!((a - b).abs() < 1e-9, "warm run must match baseline: {a} vs {b}");
    }
    let exec2 = executed_tasks(&second);
    assert!(
        exec2 < exec1,
        "warm study must execute fewer tasks ({exec2} vs {exec1})"
    );
    assert!(cached_tasks(&second) > 0, "per-task #cached rows are reported");
    let stats = second.cache.expect("stats present");
    assert!(stats.hits + stats.disk_hits > 0);
    assert!(stats.metric_hits > 0, "comparison metrics are memoized too");
}

#[test]
fn cache_survives_worker_count_changes() {
    // the cache is keyed by content, not by scheduling: a warm cache must
    // serve a study executed with a different worker count unchanged
    let cfg = base_cfg();
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    let cache = Arc::new(ReuseCache::with_capacity(512 * 1024 * 1024));
    let y1 = run_pjrt_with_cache(&cfg, &prepared, &plan, Some(cache.clone())).unwrap().y;

    let mut cfg4 = base_cfg();
    cfg4.workers = 4;
    let prepared4 = prepare(&cfg4);
    let plan4 = prepared4.plan(&cfg4);
    let out4 = run_pjrt_with_cache(&cfg4, &prepared4, &plan4, Some(cache.clone())).unwrap();
    assert_eq!(y1.len(), out4.y.len());
    for (a, b) in y1.iter().zip(&out4.y) {
        assert!((a - b).abs() < 1e-9);
    }
    assert!(executed_tasks(&out4) < plan4.tasks_to_execute() as u64);
}
