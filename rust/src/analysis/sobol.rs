//! Sobol indices via the Saltelli design (paper §2.2, Table 2 right).

use crate::sampling::VbdSample;

/// First-order (main) and total-order Sobol indices per active parameter.
#[derive(Clone, Debug)]
pub struct SobolIndices {
    /// S_i — variance attributable to parameter i alone ("Main").
    pub first: Vec<f64>,
    /// ST_i — variance including all interactions of i ("Total").
    pub total: Vec<f64>,
    /// Total output variance over the A∪B sample.
    pub variance: f64,
}

impl SobolIndices {
    /// Higher-order effect proxy per parameter: ST_i − S_i.
    pub fn interaction(&self, i: usize) -> f64 {
        self.total[i] - self.first[i]
    }
}

/// Estimate Sobol indices from the evaluations of a Saltelli design.
/// `y[i]` is the output of `sample.sets[i]`.
///
/// Estimators (Saltelli 2010 / Jansen 1999):
///   S_i  =  mean( f_B · (f_ABi − f_A) ) / V
///   ST_i =  mean( (f_A − f_ABi)² ) / (2 V)
pub fn sobol_indices(sample: &VbdSample, y: &[f64]) -> SobolIndices {
    assert_eq!(y.len(), sample.sample_size(), "one output per evaluation");
    let n = sample.n;
    let k = sample.k;

    let fa: Vec<f64> = (0..n).map(|j| y[sample.idx_a(j)]).collect();
    let fb: Vec<f64> = (0..n).map(|j| y[sample.idx_b(j)]).collect();

    // total variance over A ∪ B
    let all: Vec<f64> = fa.iter().chain(&fb).copied().collect();
    let mean = all.iter().sum::<f64>() / all.len() as f64;
    let variance = all.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / all.len() as f64;

    let mut first = vec![0.0; k];
    let mut total = vec![0.0; k];
    if variance > 1e-300 {
        for i in 0..k {
            let mut s = 0.0;
            let mut t = 0.0;
            for j in 0..n {
                let fab = y[sample.idx_ab(i, j)];
                s += fb[j] * (fab - fa[j]);
                t += (fa[j] - fab) * (fa[j] - fab);
            }
            first[i] = s / (n as f64 * variance);
            total[i] = t / (2.0 * n as f64 * variance);
        }
    }
    SobolIndices { first, total, variance }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{default_space, LatinHypercube, VbdDesign};

    /// Ishigami-like additive model on normalized levels: strong x0,
    /// moderate x1, inert x2.
    fn run(n: usize) -> SobolIndices {
        let space = default_space();
        let active = vec![5usize, 6, 7]; // G1, G2, minSize
        let sample = VbdDesign::new(n).generate(&space, &active, &mut LatinHypercube::new(17));
        let norm = |p: usize, v: f64| {
            let d = &space.params[p];
            (v - d.grid[0]) / (d.grid.last().unwrap() - d.grid[0])
        };
        let y: Vec<f64> = sample
            .sets
            .iter()
            .map(|s| 4.0 * norm(5, s[5]) + 1.0 * norm(6, s[6]))
            .collect();
        sobol_indices(&sample, &y)
    }

    #[test]
    fn additive_model_indices() {
        let idx = run(4000);
        // analytic: Var = 16/12·σ0² + 1/12... with uniform levels the
        // first-order shares are 16:1:0
        assert!(idx.first[0] > 0.85, "S_G1 {}", idx.first[0]);
        assert!(idx.first[1] > 0.02 && idx.first[1] < 0.15, "S_G2 {}", idx.first[1]);
        assert!(idx.first[2].abs() < 0.05, "S_minSize {}", idx.first[2]);
        // additive model: total ≈ first
        for i in 0..3 {
            assert!(
                (idx.total[i] - idx.first[i]).abs() < 0.08,
                "param {i}: S {} vs ST {}",
                idx.first[i],
                idx.total[i]
            );
        }
    }

    #[test]
    fn interaction_detected() {
        let space = default_space();
        let active = vec![5usize, 6];
        let sample = VbdDesign::new(4000).generate(&space, &active, &mut LatinHypercube::new(3));
        let norm = |p: usize, v: f64| {
            let d = &space.params[p];
            (v - d.grid[0]) / (d.grid.last().unwrap() - d.grid[0])
        };
        // pure interaction: y = x0·x1 (centered)
        let y: Vec<f64> = sample
            .sets
            .iter()
            .map(|s| (norm(5, s[5]) - 0.5) * (norm(6, s[6]) - 0.5))
            .collect();
        let idx = sobol_indices(&sample, &y);
        assert!(idx.first[0].abs() < 0.1, "no main effect: {}", idx.first[0]);
        assert!(idx.total[0] > 0.5, "interaction in total: {}", idx.total[0]);
        assert!(idx.interaction(0) > 0.4);
    }

    #[test]
    fn constant_output_yields_zero_indices() {
        let space = default_space();
        let sample =
            VbdDesign::new(50).generate(&space, &[5, 6], &mut LatinHypercube::new(9));
        let y = vec![3.25; sample.sample_size()];
        let idx = sobol_indices(&sample, &y);
        assert_eq!(idx.variance, 0.0);
        assert!(idx.first.iter().all(|&v| v == 0.0));
        assert!(idx.total.iter().all(|&v| v == 0.0));
    }
}
