//! Morris elementary effects (paper §2.2, Table 2 left column).

use crate::sampling::MoatSample;

/// Per-parameter MOAT statistics.
#[derive(Clone, Debug)]
pub struct MoatIndices {
    /// Signed mean elementary effect (the paper's "First-order Effect";
    /// sign conveys direction, magnitude conveys influence).
    pub mean: Vec<f64>,
    /// Mean absolute elementary effect μ* (Campolongo's screening
    /// statistic — robust to non-monotone effects).
    pub mu_star: Vec<f64>,
    /// Standard deviation of the effects (interaction/nonlinearity).
    pub sigma: Vec<f64>,
    /// Elementary-effect count per parameter (r when every trajectory
    /// perturbs every parameter once).
    pub count: Vec<usize>,
}

impl MoatIndices {
    /// Parameter indices sorted by decreasing μ*.
    pub fn ranking(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.mu_star.len()).collect();
        order.sort_by(|&a, &b| {
            self.mu_star[b].partial_cmp(&self.mu_star[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        order
    }
}

/// Compute the elementary effects of a MOAT experiment. `y[i]` is the
/// workflow output (here: 1 − dice vs. the reference mask) of
/// evaluation `i` of `sample.sets`; `k` is the parameter-space dimension.
///
/// Each trajectory step perturbing parameter `p` by normalized Δ yields
/// `EE_p = (y_after − y_before) / Δ`.
pub fn moat_effects(sample: &MoatSample, y: &[f64], k: usize) -> MoatIndices {
    assert_eq!(y.len(), sample.sets.len(), "one output per evaluation");
    let mut sums = vec![0.0f64; k];
    let mut abs_sums = vec![0.0f64; k];
    let mut sq_sums = vec![0.0f64; k];
    let mut count = vec![0usize; k];

    for t in &sample.trajectories {
        for (i, step) in t.steps.iter().enumerate() {
            let before = y[t.first_eval + i];
            let after = y[t.first_eval + i + 1];
            let ee = (after - before) / step.delta_norm;
            sums[step.param] += ee;
            abs_sums[step.param] += ee.abs();
            sq_sums[step.param] += ee * ee;
            count[step.param] += 1;
        }
    }

    let mut mean = vec![0.0; k];
    let mut mu_star = vec![0.0; k];
    let mut sigma = vec![0.0; k];
    for p in 0..k {
        let n = count[p] as f64;
        if count[p] == 0 {
            continue;
        }
        mean[p] = sums[p] / n;
        mu_star[p] = abs_sums[p] / n;
        let var = (sq_sums[p] / n - mean[p] * mean[p]).max(0.0);
        sigma[p] = var.sqrt();
    }
    MoatIndices { mean, mu_star, sigma, count }
}

/// The two-phase SA screen: the `k` parameters with the largest μ*
/// (paper: MOAT over all 15, VBD over the top 8), returned in canonical
/// (ascending index) order.
pub fn screen_top_k(indices: &MoatIndices, k: usize) -> Vec<usize> {
    let mut top: Vec<usize> = indices.ranking().into_iter().take(k).collect();
    top.sort_unstable();
    top
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{default_space, HaltonSampler, MoatDesign};

    /// Synthetic model with known sensitivities: y = 3·x5 + 1·x6 + noiseless
    /// rest (x in level fractions).
    fn synth_outputs(sample: &crate::sampling::MoatSample) -> Vec<f64> {
        let space = default_space();
        sample
            .sets
            .iter()
            .map(|set| {
                let f = |p: usize| {
                    let d = &space.params[p];
                    let lo = d.grid[0];
                    let hi = *d.grid.last().unwrap();
                    (set[p] - lo) / (hi - lo)
                };
                3.0 * f(5) + 1.0 * f(6)
            })
            .collect()
    }

    #[test]
    fn recovers_known_influence_ordering() {
        let space = default_space();
        let sample = MoatDesign::new(20).generate(&space, &mut HaltonSampler::new(0), 7);
        let y = synth_outputs(&sample);
        let idx = moat_effects(&sample, &y, space.dim());
        let rank = idx.ranking();
        assert_eq!(rank[0], 5, "G1 dominates: {:?}", idx.mu_star);
        assert_eq!(rank[1], 6, "G2 second");
        // linear noiseless model: sigma ~ 0 for influential params
        assert!(idx.sigma[5] < 1e-9, "sigma {}", idx.sigma[5]);
        // non-influential params have zero effect
        for p in [0usize, 1, 2, 10, 11] {
            assert!(idx.mu_star[p] < 1e-12, "param {p}: {}", idx.mu_star[p]);
        }
    }

    #[test]
    fn signed_mean_tracks_direction() {
        let space = default_space();
        let sample = MoatDesign::new(15).generate(&space, &mut HaltonSampler::new(1), 3);
        // y decreases with G1
        let y: Vec<f64> = sample.sets.iter().map(|s| -s[5]).collect();
        let idx = moat_effects(&sample, &y, space.dim());
        assert!(idx.mean[5] < 0.0);
        assert!(idx.mu_star[5] > 0.0);
    }

    #[test]
    fn every_param_measured_r_times() {
        let space = default_space();
        let r = 9;
        let sample = MoatDesign::new(r).generate(&space, &mut HaltonSampler::new(2), 5);
        let y = vec![0.0; sample.sets.len()];
        let idx = moat_effects(&sample, &y, space.dim());
        assert!(idx.count.iter().all(|&c| c == r), "{:?}", idx.count);
    }

    #[test]
    fn screen_top_k_returns_sorted_subset() {
        let space = default_space();
        let sample = MoatDesign::new(12).generate(&space, &mut HaltonSampler::new(3), 11);
        let y = synth_outputs(&sample);
        let idx = moat_effects(&sample, &y, space.dim());
        let top = screen_top_k(&idx, 8);
        assert_eq!(top.len(), 8);
        assert!(top.windows(2).all(|w| w[0] < w[1]));
        assert!(top.contains(&5) && top.contains(&6));
    }
}
