//! Mask-comparison metrics — Rust reference implementation of the `cmp`
//! artifact's numbers (used for cross-checking and for the simulator).

use crate::data::Plane;

fn confusion(a: &Plane, b: &Plane, thr: f32) -> (f64, f64, f64) {
    assert_eq!(a.height(), b.height());
    assert_eq!(a.width(), b.width());
    let mut inter = 0u64;
    let mut na = 0u64;
    let mut nb = 0u64;
    for (x, y) in a.data().iter().zip(b.data()) {
        let pa = *x > thr;
        let pb = *y > thr;
        na += pa as u64;
        nb += pb as u64;
        inter += (pa && pb) as u64;
    }
    (inter as f64, na as f64, nb as f64)
}

/// Dice coefficient 2|A∩B| / (|A|+|B|) over thresholded masks. Two empty
/// masks are perfectly similar (1.0).
pub fn dice(a: &Plane, b: &Plane, thr: f32) -> f64 {
    let (inter, na, nb) = confusion(a, b, thr);
    if na + nb == 0.0 {
        1.0
    } else {
        2.0 * inter / (na + nb)
    }
}

/// Jaccard index |A∩B| / |A∪B| over thresholded masks.
pub fn jaccard(a: &Plane, b: &Plane, thr: f32) -> f64 {
    let (inter, na, nb) = confusion(a, b, thr);
    let union = na + nb - inter;
    if union == 0.0 {
        1.0
    } else {
        inter / union
    }
}

/// Mean absolute difference between two planes (the `cmp` artifact's
/// third metric).
pub fn mask_diff(a: &Plane, b: &Plane) -> f64 {
    let n = a.data().len().max(1);
    a.data().iter().zip(b.data()).map(|(x, y)| (x - y).abs() as f64).sum::<f64>() / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane(vals: &[f32], w: usize) -> Plane {
        Plane::new(vals.to_vec(), vals.len() / w, w).unwrap()
    }

    #[test]
    fn identical_masks_score_one() {
        let a = plane(&[1.0, 0.0, 1.0, 1.0], 2);
        assert_eq!(dice(&a, &a, 0.5), 1.0);
        assert_eq!(jaccard(&a, &a, 0.5), 1.0);
        assert_eq!(mask_diff(&a, &a), 0.0);
    }

    #[test]
    fn disjoint_masks_score_zero() {
        let a = plane(&[1.0, 1.0, 0.0, 0.0], 2);
        let b = plane(&[0.0, 0.0, 1.0, 1.0], 2);
        assert_eq!(dice(&a, &b, 0.5), 0.0);
        assert_eq!(jaccard(&a, &b, 0.5), 0.0);
        assert_eq!(mask_diff(&a, &b), 1.0);
    }

    #[test]
    fn half_overlap() {
        let a = plane(&[1.0, 1.0, 0.0, 0.0], 2);
        let b = plane(&[1.0, 0.0, 1.0, 0.0], 2);
        assert!((dice(&a, &b, 0.5) - 0.5).abs() < 1e-12);
        assert!((jaccard(&a, &b, 0.5) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_masks_are_similar() {
        let a = plane(&[0.0; 4], 2);
        assert_eq!(dice(&a, &a, 0.5), 1.0);
        assert_eq!(jaccard(&a, &a, 0.5), 1.0);
    }

    #[test]
    fn dice_jaccard_relation() {
        // d = 2j/(1+j) always
        let a = plane(&[1.0, 1.0, 1.0, 0.0, 0.0, 0.0], 3);
        let b = plane(&[1.0, 1.0, 0.0, 1.0, 0.0, 0.0], 3);
        let d = dice(&a, &b, 0.5);
        let j = jaccard(&a, &b, 0.5);
        assert!((d - 2.0 * j / (1.0 + j)).abs() < 1e-12);
    }
}
