//! Sensitivity-analysis statistics — the numbers of paper Table 2.
//!
//! * [`moat_effects`] — Morris elementary effects: per-parameter signed
//!   mean effect, μ* (mean absolute effect) and σ (effect spread).
//! * [`sobol_indices`] — Saltelli/Jansen estimators of first-order and
//!   total-order Sobol indices over a [`VbdSample`](crate::sampling::VbdSample).
//! * [`dice`] / [`jaccard`] — mask-comparison metrics (Rust reference for
//!   the `cmp` artifact; the coordinator uses the artifact's numbers).
//! * [`screen_top_k`] — the paper's two-phase flow: pick the k most
//!   influential parameters from a MOAT screen to feed the VBD study.

mod effects;
mod metrics;
mod sobol;

pub use effects::{moat_effects, screen_top_k, MoatIndices};
pub use metrics::{dice, jaccard, mask_diff};
pub use sobol::{sobol_indices, SobolIndices};
