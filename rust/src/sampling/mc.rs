//! Plain Monte-Carlo base sampler.

use crate::data::synth::SplitMix64;

use super::Sampler;

/// Uniform pseudo-random sampler (SplitMix64, deterministic per seed).
pub struct MonteCarlo {
    rng: SplitMix64,
}

impl MonteCarlo {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed) }
    }
}

impl Sampler for MonteCarlo {
    fn draw(&mut self, n: usize, dim: usize) -> Vec<Vec<f64>> {
        (0..n).map(|_| (0..dim).map(|_| self.rng.next_f64()).collect()).collect()
    }

    fn name(&self) -> &'static str {
        "MC"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_range() {
        let pts = MonteCarlo::new(1).draw(100, 15);
        assert_eq!(pts.len(), 100);
        assert!(pts.iter().all(|p| p.len() == 15));
        assert!(pts.iter().flatten().all(|&v| (0.0..1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(MonteCarlo::new(7).draw(5, 3), MonteCarlo::new(7).draw(5, 3));
        assert_ne!(MonteCarlo::new(7).draw(5, 3), MonteCarlo::new(8).draw(5, 3));
    }

    #[test]
    fn roughly_uniform_mean() {
        let pts = MonteCarlo::new(3).draw(4000, 2);
        let mean: f64 = pts.iter().map(|p| p[0]).sum::<f64>() / 4000.0;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}");
    }
}
