//! Quasi-Monte-Carlo base sampler (Halton sequence — the paper generated
//! its MOAT experiments "with a quasi-Monte Carlo sampling using a Halton
//! sequence").

use super::Sampler;

const PRIMES: [u64; 24] = [
    2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37, 41, 43, 47, 53, 59, 61, 67, 71, 73, 79, 83, 89,
];

/// The `i`-th element (1-based internally) of the van-der-Corput sequence
/// in the given base.
pub fn halton(index: u64, base: u64) -> f64 {
    let mut f = 1.0;
    let mut r = 0.0;
    let mut i = index;
    while i > 0 {
        f /= base as f64;
        r += f * (i % base) as f64;
        i /= base;
    }
    r
}

/// Multi-dimensional Halton sampler with a leap-free, offset start (skip
/// the first points to avoid the degenerate origin cluster).
pub struct HaltonSampler {
    next_index: u64,
}

impl HaltonSampler {
    pub fn new(seed: u64) -> Self {
        // seed offsets the stream so different studies decorrelate
        Self { next_index: 20 + (seed % 1000) }
    }
}

impl Sampler for HaltonSampler {
    fn draw(&mut self, n: usize, dim: usize) -> Vec<Vec<f64>> {
        assert!(dim <= PRIMES.len(), "Halton supports up to {} dims", PRIMES.len());
        let mut pts = Vec::with_capacity(n);
        for _ in 0..n {
            let i = self.next_index;
            self.next_index += 1;
            pts.push((0..dim).map(|d| halton(i, PRIMES[d])).collect());
        }
        pts
    }

    fn name(&self) -> &'static str {
        "QMC"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn van_der_corput_base2_prefix() {
        let want = [0.5, 0.25, 0.75, 0.125, 0.625, 0.375, 0.875];
        for (i, w) in want.iter().enumerate() {
            assert!((halton(i as u64 + 1, 2) - w).abs() < 1e-12);
        }
    }

    #[test]
    fn low_discrepancy_coverage() {
        // Halton fills the unit interval evenly: each of 10 bins gets
        // close to n/10 of the first n points.
        let mut s = HaltonSampler::new(0);
        let pts = s.draw(1000, 1);
        let mut bins = [0usize; 10];
        for p in &pts {
            bins[(p[0] * 10.0) as usize] += 1;
        }
        for b in bins {
            assert!((90..=110).contains(&b), "bin count {b}");
        }
    }

    #[test]
    fn sequential_draws_continue_sequence() {
        let mut a = HaltonSampler::new(3);
        let first = a.draw(5, 2);
        let second = a.draw(5, 2);
        let mut b = HaltonSampler::new(3);
        let all = b.draw(10, 2);
        assert_eq!(first[..], all[..5]);
        assert_eq!(second[..], all[5..]);
    }

    #[test]
    fn values_in_unit_interval() {
        let pts = HaltonSampler::new(1).draw(200, 15);
        assert!(pts.iter().flatten().all(|&v| (0.0..1.0).contains(&v)));
    }
}
