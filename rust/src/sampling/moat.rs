//! MOAT — Morris One-At-a-Time screening design (paper §2.2).
//!
//! `r` trajectories of `k+1` points each: a random grid base point, then
//! one elementary perturbation per parameter in random order. The jump is
//! Δ = p/(2(p−1)) in normalized units (the paper's choice, [33]), i.e.
//! ⌊p/2⌋ grid levels. Consecutive trajectory points differ in exactly one
//! parameter — this is precisely the structure the reuse-tree merging
//! exploits.

use crate::data::SplitMix64;

use super::{ParamSet, ParamSpace, Sampler};

/// One elementary-effect step within a trajectory.
#[derive(Clone, Debug)]
pub struct MoatStep {
    /// Which parameter was perturbed.
    pub param: usize,
    /// Signed normalized jump (Δ in units of the full parameter range).
    pub delta_norm: f64,
}

/// One trajectory: `k+1` consecutive evaluation indices into the sample's
/// `sets`, plus the step descriptors between them.
#[derive(Clone, Debug)]
pub struct Trajectory {
    /// Index of the trajectory's first evaluation in `MoatSample::sets`.
    pub first_eval: usize,
    pub steps: Vec<MoatStep>,
}

/// A generated MOAT experiment.
#[derive(Clone, Debug)]
pub struct MoatSample {
    pub sets: Vec<ParamSet>,
    pub trajectories: Vec<Trajectory>,
}

impl MoatSample {
    /// Total number of workflow evaluations (the paper's "sample size").
    pub fn sample_size(&self) -> usize {
        self.sets.len()
    }
}

/// MOAT design parameters.
#[derive(Clone, Copy, Debug)]
pub struct MoatDesign {
    /// Number of trajectories (paper: 5–15 typical; sample = r(k+1)).
    pub r: usize,
}

impl MoatDesign {
    pub fn new(r: usize) -> Self {
        Self { r }
    }

    /// The `r` needed for a requested sample size (rounded down, ≥ 1).
    pub fn for_sample_size(sample: usize, k: usize) -> Self {
        Self { r: (sample / (k + 1)).max(1) }
    }

    /// Generate the experiment. The base points come from `sampler`
    /// (paper: Halton QMC "known to provide a good coverage"); step order
    /// and directions come from a deterministic PRNG seeded by `seed`.
    pub fn generate(&self, space: &ParamSpace, sampler: &mut dyn Sampler, seed: u64) -> MoatSample {
        let k = space.dim();
        let mut rng = SplitMix64::new(seed ^ 0x4d4f4154); // "MOAT"
        let bases = sampler.draw(self.r, k);
        let mut sets = Vec::with_capacity(self.r * (k + 1));
        let mut trajectories = Vec::with_capacity(self.r);

        for base_fracs in bases {
            // base point as level indices
            let mut levels: Vec<usize> = space
                .params
                .iter()
                .zip(&base_fracs)
                .map(|(p, &f)| p.level_of_fraction(f))
                .collect();

            // random parameter visit order (Fisher–Yates)
            let mut order: Vec<usize> = (0..k).collect();
            for i in (1..k).rev() {
                let j = rng.uniform_usize(0, i + 1);
                order.swap(i, j);
            }

            let first_eval = sets.len();
            sets.push(levels_to_set(space, &levels));
            let mut steps = Vec::with_capacity(k);
            for &param in &order {
                let p = &space.params[param];
                let pl = p.levels();
                let jump = (pl / 2).max(1);
                // choose a feasible direction (prefer the random one)
                let up = rng.next_f64() < 0.5;
                let (new_level, dir) = if up && levels[param] + jump < pl {
                    (levels[param] + jump, 1.0)
                } else if levels[param] >= jump {
                    (levels[param] - jump, -1.0)
                } else {
                    (levels[param] + jump.min(pl - 1 - levels[param]), 1.0)
                };
                let delta_levels = (new_level as f64 - levels[param] as f64).abs() * dir;
                levels[param] = new_level;
                sets.push(levels_to_set(space, &levels));
                // normalized Δ: fraction of the parameter's level range
                let delta_norm = delta_levels / (pl.saturating_sub(1).max(1) as f64);
                steps.push(MoatStep { param, delta_norm });
            }
            trajectories.push(Trajectory { first_eval, steps });
        }
        MoatSample { sets, trajectories }
    }
}

fn levels_to_set(space: &ParamSpace, levels: &[usize]) -> ParamSet {
    space.params.iter().zip(levels).map(|(p, &l)| p.value_at(l)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{default_space, HaltonSampler};

    fn sample(r: usize) -> (MoatSample, ParamSpace) {
        let space = default_space();
        let mut sampler = HaltonSampler::new(0);
        (MoatDesign::new(r).generate(&space, &mut sampler, 42), space)
    }

    #[test]
    fn sample_size_is_r_times_k_plus_1() {
        let (s, space) = sample(10);
        assert_eq!(s.sample_size(), 10 * (space.dim() + 1));
        assert_eq!(s.trajectories.len(), 10);
    }

    #[test]
    fn consecutive_points_differ_in_exactly_one_param() {
        let (s, space) = sample(8);
        for t in &s.trajectories {
            for (i, step) in t.steps.iter().enumerate() {
                let a = &s.sets[t.first_eval + i];
                let b = &s.sets[t.first_eval + i + 1];
                let diffs: Vec<usize> =
                    (0..space.dim()).filter(|&d| (a[d] - b[d]).abs() > 1e-12).collect();
                assert_eq!(diffs, vec![step.param], "trajectory step {i}");
            }
        }
    }

    #[test]
    fn each_param_perturbed_once_per_trajectory() {
        let (s, space) = sample(5);
        for t in &s.trajectories {
            let mut seen: Vec<usize> = t.steps.iter().map(|st| st.param).collect();
            seen.sort();
            assert_eq!(seen, (0..space.dim()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn all_points_on_grid() {
        let (s, space) = sample(6);
        for set in &s.sets {
            space.validate(set).unwrap();
        }
    }

    #[test]
    fn deltas_are_nonzero_and_sane() {
        let (s, _) = sample(6);
        for t in &s.trajectories {
            for st in &t.steps {
                assert!(st.delta_norm.abs() > 1e-9);
                assert!(st.delta_norm.abs() <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn for_sample_size_rounds_down() {
        assert_eq!(MoatDesign::for_sample_size(160, 15).r, 10);
        assert_eq!(MoatDesign::for_sample_size(640, 15).r, 40);
        assert_eq!(MoatDesign::for_sample_size(3, 15).r, 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let space = default_space();
        let a = MoatDesign::new(3).generate(&space, &mut HaltonSampler::new(1), 9);
        let b = MoatDesign::new(3).generate(&space, &mut HaltonSampler::new(1), 9);
        assert_eq!(a.sets, b.sets);
    }
}
