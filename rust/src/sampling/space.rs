//! The 15-parameter space of the segmentation workflow (paper Table 1).

use crate::{Error, Result};

/// A parameter set: one concrete value per parameter, in canonical order.
pub type ParamSet = Vec<f64>;

/// One workflow parameter with its discrete value grid.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamDef {
    pub name: String,
    /// The discrete levels the SA methods sample from (ascending).
    pub grid: Vec<f64>,
}

impl ParamDef {
    pub fn new(name: &str, grid: Vec<f64>) -> Self {
        Self { name: name.into(), grid }
    }

    /// Evenly spaced grid `lo, lo+step, ..., hi`.
    pub fn range(name: &str, lo: f64, hi: f64, step: f64) -> Self {
        let mut grid = Vec::new();
        let mut v = lo;
        while v <= hi + 1e-9 {
            grid.push((v * 1e6).round() / 1e6);
            v += step;
        }
        Self::new(name, grid)
    }

    pub fn levels(&self) -> usize {
        self.grid.len()
    }

    /// Snap a fraction in [0,1) to a grid level index.
    pub fn level_of_fraction(&self, f: f64) -> usize {
        ((f.clamp(0.0, 1.0 - 1e-12)) * self.levels() as f64) as usize
    }

    /// Value at a level index (clamped).
    pub fn value_at(&self, level: usize) -> f64 {
        self.grid[level.min(self.levels() - 1)]
    }
}

/// The full parameter space.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpace {
    pub params: Vec<ParamDef>,
}

impl ParamSpace {
    pub fn new(params: Vec<ParamDef>) -> Self {
        Self { params }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Number of points in the discrete space (paper: ~21 trillion).
    pub fn cardinality(&self) -> f64 {
        self.params.iter().map(|p| p.levels() as f64).product()
    }

    /// Index of a parameter by name.
    pub fn index_of(&self, name: &str) -> Result<usize> {
        self.params
            .iter()
            .position(|p| p.name == name)
            .ok_or_else(|| Error::Config(format!("unknown parameter `{name}`")))
    }

    /// Map per-parameter fractions to grid values.
    pub fn snap(&self, fractions: &[f64]) -> ParamSet {
        self.params
            .iter()
            .zip(fractions)
            .map(|(p, &f)| p.value_at(p.level_of_fraction(f)))
            .collect()
    }

    /// The paper's default parameter values (application defaults used to
    /// build the reference mask).
    pub fn defaults(&self) -> ParamSet {
        self.params
            .iter()
            .map(|p| p.grid[p.levels() / 2]) // mid-grid
            .collect()
    }

    /// Validate that a parameter set lies on the grids.
    pub fn validate(&self, set: &ParamSet) -> Result<()> {
        if set.len() != self.dim() {
            return Err(Error::Config(format!(
                "param set has {} values, space has {}",
                set.len(),
                self.dim()
            )));
        }
        for (p, v) in self.params.iter().zip(set) {
            if !p.grid.iter().any(|g| (g - v).abs() < 1e-9) {
                return Err(Error::Config(format!("value {v} not on grid of `{}`", p.name)));
            }
        }
        Ok(())
    }
}

/// Canonical parameter order used across the crate: indices into every
/// [`ParamSet`].
pub mod idx {
    pub const B: usize = 0;
    pub const G: usize = 1;
    pub const R: usize = 2;
    pub const T1: usize = 3;
    pub const T2: usize = 4;
    pub const G1: usize = 5;
    pub const G2: usize = 6;
    pub const MIN_SIZE: usize = 7;
    pub const MAX_SIZE: usize = 8;
    pub const MIN_SIZE_PL: usize = 9;
    pub const MIN_SIZE_SEG: usize = 10;
    pub const MAX_SIZE_SEG: usize = 11;
    pub const FILL_HOLES: usize = 12;
    pub const RECON: usize = 13;
    pub const WATERSHED: usize = 14;
}

/// The paper's Table-2 MOAT screen outcome: the 8 most influential
/// parameters (T2, G1, G2, minS, maxS, minSPL, RC, WConn) in canonical
/// index order. VBD refinement restricts its design to these; the tuning
/// subsystem ([`crate::tune`]) searches over a prefix of this list by
/// default.
pub const CANONICAL_ACTIVE: [usize; 8] = [
    idx::T2,
    idx::G1,
    idx::G2,
    idx::MIN_SIZE,
    idx::MAX_SIZE,
    idx::MIN_SIZE_PL,
    idx::RECON,
    idx::WATERSHED,
];

/// Build the Table-1 space: B/G/R ∈ {210..240 step 10}, T1/T2 ∈
/// {2.5..7.5 step 0.5}, G1/minSPL ∈ {5..80 step 5}, G2/minS/minSS ∈
/// {2..40 step 2}, maxS/maxSS ∈ {900..1500 step 50}, and the three
/// 4-/8-connectivity switches — ≈ 2.1·10¹³ combinations.
pub fn default_space() -> ParamSpace {
    ParamSpace::new(vec![
        ParamDef::range("B", 210.0, 240.0, 10.0),
        ParamDef::range("G", 210.0, 240.0, 10.0),
        ParamDef::range("R", 210.0, 240.0, 10.0),
        ParamDef::range("T1", 2.5, 7.5, 0.5),
        ParamDef::range("T2", 2.5, 7.5, 0.5),
        ParamDef::range("G1", 5.0, 80.0, 5.0),
        ParamDef::range("G2", 2.0, 40.0, 2.0),
        ParamDef::range("minSize", 2.0, 40.0, 2.0),
        ParamDef::range("maxSize", 900.0, 1500.0, 50.0),
        ParamDef::range("minSizePl", 5.0, 80.0, 5.0),
        ParamDef::range("minSizeSeg", 2.0, 40.0, 2.0),
        ParamDef::range("maxSizeSeg", 900.0, 1500.0, 50.0),
        ParamDef::new("fillHolesConn", vec![4.0, 8.0]),
        ParamDef::new("reconConn", vec![4.0, 8.0]),
        ParamDef::new("watershedConn", vec![4.0, 8.0]),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_cardinality_is_about_21_trillion() {
        let s = default_space();
        assert_eq!(s.dim(), 15);
        let c = s.cardinality();
        assert!(
            (2.0e13..2.5e13).contains(&c),
            "paper says ~21 trillion, got {c:.3e}"
        );
    }

    #[test]
    fn grids_match_table1() {
        let s = default_space();
        assert_eq!(s.params[idx::B].grid, vec![210.0, 220.0, 230.0, 240.0]);
        assert_eq!(s.params[idx::T1].levels(), 11);
        assert_eq!(s.params[idx::G1].levels(), 16);
        assert_eq!(s.params[idx::G2].levels(), 20);
        assert_eq!(s.params[idx::MAX_SIZE].levels(), 13);
        assert_eq!(s.params[idx::FILL_HOLES].grid, vec![4.0, 8.0]);
    }

    #[test]
    fn snap_hits_grid() {
        let s = default_space();
        let set = s.snap(&vec![0.999; 15]);
        s.validate(&set).unwrap();
        assert_eq!(set[idx::B], 240.0);
        assert_eq!(set[idx::WATERSHED], 8.0);
        let set0 = s.snap(&vec![0.0; 15]);
        assert_eq!(set0[idx::B], 210.0);
        assert_eq!(set0[idx::G2], 2.0);
    }

    #[test]
    fn canonical_active_matches_table2() {
        assert_eq!(CANONICAL_ACTIVE, [4, 5, 6, 7, 8, 9, 13, 14]);
        let s = default_space();
        assert!(CANONICAL_ACTIVE.iter().all(|&p| p < s.dim()));
    }

    #[test]
    fn defaults_validate() {
        let s = default_space();
        s.validate(&s.defaults()).unwrap();
    }

    #[test]
    fn level_of_fraction_uniform() {
        let p = ParamDef::new("x", vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.level_of_fraction(0.0), 0);
        assert_eq!(p.level_of_fraction(0.24), 0);
        assert_eq!(p.level_of_fraction(0.25), 1);
        assert_eq!(p.level_of_fraction(0.99), 3);
        assert_eq!(p.level_of_fraction(1.0), 3); // clamped
    }

    #[test]
    fn validate_rejects_off_grid() {
        let s = default_space();
        let mut set = s.defaults();
        set[idx::B] = 215.0;
        assert!(s.validate(&set).is_err());
        assert!(s.validate(&set[..3].to_vec()).is_err());
    }
}
