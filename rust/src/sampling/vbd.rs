//! VBD — variance-based decomposition with the Saltelli design
//! (paper §2.2: n(k+2) evaluations for k parameters and n samples,
//! yielding first-order *and* total-order Sobol indices).

use super::{ParamSet, ParamSpace, Sampler};

/// A generated VBD experiment: the A matrix, the B matrix, and the k
/// "A-with-column-i-from-B" matrices, flattened into `sets`.
#[derive(Clone, Debug)]
pub struct VbdSample {
    pub sets: Vec<ParamSet>,
    pub n: usize,
    pub k: usize,
}

impl VbdSample {
    /// Evaluation index of A-matrix row `j`.
    pub fn idx_a(&self, j: usize) -> usize {
        j
    }

    /// Evaluation index of B-matrix row `j`.
    pub fn idx_b(&self, j: usize) -> usize {
        self.n + j
    }

    /// Evaluation index of row `j` of A with column `i` replaced from B.
    pub fn idx_ab(&self, i: usize, j: usize) -> usize {
        2 * self.n + i * self.n + j
    }

    /// Total evaluations = n(k+2).
    pub fn sample_size(&self) -> usize {
        self.sets.len()
    }
}

/// VBD design parameters.
#[derive(Clone, Copy, Debug)]
pub struct VbdDesign {
    /// Base sample count n (paper: order of thousands).
    pub n: usize,
}

impl VbdDesign {
    pub fn new(n: usize) -> Self {
        Self { n }
    }

    /// The n needed for a requested total sample size.
    pub fn for_sample_size(sample: usize, k: usize) -> Self {
        Self { n: (sample / (k + 2)).max(1) }
    }

    /// Generate the Saltelli design on `space`, optionally restricted to
    /// `active` parameter indices (the paper screens down to the 8 most
    /// influential parameters with MOAT first; inactive parameters stay
    /// at their defaults).
    pub fn generate(
        &self,
        space: &ParamSpace,
        active: &[usize],
        sampler: &mut dyn Sampler,
    ) -> VbdSample {
        let k = active.len();
        let defaults = space.defaults();
        // draw A and B as one 2k-dimensional sample (standard Saltelli)
        let pts = sampler.draw(self.n, 2 * k);
        let row = |fracs: &[f64]| -> ParamSet {
            let mut set = defaults.clone();
            for (ai, &p) in active.iter().enumerate() {
                let pd = &space.params[p];
                set[p] = pd.value_at(pd.level_of_fraction(fracs[ai]));
            }
            set
        };
        let a_rows: Vec<ParamSet> = pts.iter().map(|p| row(&p[..k])).collect();
        let b_rows: Vec<ParamSet> = pts.iter().map(|p| row(&p[k..])).collect();

        let mut sets = Vec::with_capacity(self.n * (k + 2));
        sets.extend(a_rows.iter().cloned());
        sets.extend(b_rows.iter().cloned());
        for &p in active.iter() {
            for j in 0..self.n {
                let mut s = a_rows[j].clone();
                s[p] = b_rows[j][p];
                sets.push(s);
            }
        }
        VbdSample { sets, n: self.n, k }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::{default_space, LatinHypercube};

    fn sample(n: usize, k: usize) -> (VbdSample, Vec<usize>) {
        let space = default_space();
        let active: Vec<usize> = (0..k).collect();
        let mut s = LatinHypercube::new(11);
        (VbdDesign::new(n).generate(&space, &active, &mut s), active)
    }

    #[test]
    fn size_is_n_times_k_plus_2() {
        let (s, _) = sample(50, 8);
        assert_eq!(s.sample_size(), 50 * 10);
        assert_eq!(s.n, 50);
        assert_eq!(s.k, 8);
    }

    #[test]
    fn layout_indices_partition_the_sets() {
        let (s, _) = sample(10, 4);
        let mut seen = vec![false; s.sample_size()];
        for j in 0..s.n {
            seen[s.idx_a(j)] = true;
            seen[s.idx_b(j)] = true;
            for i in 0..s.k {
                seen[s.idx_ab(i, j)] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn ab_rows_differ_from_a_only_in_param_i() {
        let (s, active) = sample(12, 5);
        for i in 0..s.k {
            for j in 0..s.n {
                let a = &s.sets[s.idx_a(j)];
                let ab = &s.sets[s.idx_ab(i, j)];
                for (d, (x, y)) in a.iter().zip(ab).enumerate() {
                    if d == active[i] {
                        // comes from B: usually differs (grids can collide)
                        let b = &s.sets[s.idx_b(j)];
                        assert_eq!(*y, b[d]);
                    } else {
                        assert_eq!(x, y, "param {d} must match A");
                    }
                }
            }
        }
    }

    #[test]
    fn inactive_params_stay_default() {
        let space = default_space();
        let active = vec![5usize, 6]; // G1, G2
        let mut smp = LatinHypercube::new(3);
        let s = VbdDesign::new(20).generate(&space, &active, &mut smp);
        let defaults = space.defaults();
        for set in &s.sets {
            for d in 0..space.dim() {
                if !active.contains(&d) {
                    assert_eq!(set[d], defaults[d]);
                }
            }
        }
    }

    #[test]
    fn all_points_on_grid() {
        let (s, _) = sample(15, 8);
        let space = default_space();
        for set in &s.sets {
            space.validate(set).unwrap();
        }
    }

    #[test]
    fn for_sample_size() {
        assert_eq!(VbdDesign::for_sample_size(2000, 8).n, 200);
        assert_eq!(VbdDesign::for_sample_size(10000, 8).n, 1000);
    }
}
