//! Latin-Hypercube base sampler (the generator the paper used for its
//! VBD experiments).

use crate::data::SplitMix64;

use super::Sampler;

/// Stratified LHS: each dimension's n draws occupy the n strata of [0,1)
/// exactly once, in a random permutation, jittered within the stratum.
pub struct LatinHypercube {
    rng: SplitMix64,
}

impl LatinHypercube {
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed) }
    }

    fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut perm: Vec<usize> = (0..n).collect();
        // Fisher–Yates
        for i in (1..n).rev() {
            let j = self.rng.uniform_usize(0, i + 1);
            perm.swap(i, j);
        }
        perm
    }
}

impl Sampler for LatinHypercube {
    fn draw(&mut self, n: usize, dim: usize) -> Vec<Vec<f64>> {
        if n == 0 {
            return Vec::new();
        }
        let mut pts = vec![vec![0.0; dim]; n];
        for d in 0..dim {
            let perm = self.permutation(n);
            for (i, &stratum) in perm.iter().enumerate() {
                let jitter = self.rng.next_f64();
                pts[i][d] = (stratum as f64 + jitter) / n as f64;
            }
        }
        pts
    }

    fn name(&self) -> &'static str {
        "LHS"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strata_covered_exactly_once() {
        let n = 50;
        let pts = LatinHypercube::new(5).draw(n, 4);
        for d in 0..4 {
            let mut seen = vec![false; n];
            for p in &pts {
                let s = (p[d] * n as f64) as usize;
                assert!(!seen[s], "stratum {s} hit twice in dim {d}");
                seen[s] = true;
            }
            assert!(seen.iter().all(|&b| b));
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(LatinHypercube::new(2).draw(8, 3), LatinHypercube::new(2).draw(8, 3));
    }

    #[test]
    fn empty_draw() {
        assert!(LatinHypercube::new(1).draw(0, 3).is_empty());
    }
}
