//! Sensitivity-analysis experiment generation.
//!
//! The paper evaluates two SA methods — MOAT (Morris One-At-a-Time,
//! screening) and VBD (variance-based decomposition, Saltelli design) —
//! driven by three base samplers (Monte-Carlo, Latin-Hypercube,
//! quasi-Monte-Carlo/Halton; Table 4 compares their reuse potential).
//! This module generates the parameter-set lists ("experiments") that the
//! merging algorithms compact and the coordinator executes.
//!
//! All sampling happens on the *discrete grids* of Table 1 — the paper's
//! parameter space has about 21·10¹² points (asserted by a unit test).

mod lhs;
mod mc;
mod moat;
mod qmc;
pub mod space;
mod vbd;

pub use lhs::LatinHypercube;
pub use mc::MonteCarlo;
pub use moat::{MoatDesign, MoatSample, MoatStep, Trajectory};
pub use qmc::{halton, HaltonSampler};
pub use space::{default_space, ParamDef, ParamSpace, ParamSet, CANONICAL_ACTIVE};
pub use vbd::{VbdDesign, VbdSample};

/// A base sampler draws points (as per-parameter *level fractions* in
/// [0,1)) that the designs then snap onto the discrete grids.
pub trait Sampler {
    /// Draw `n` points of dimension `dim`; element (i, j) in [0, 1).
    fn draw(&mut self, n: usize, dim: usize) -> Vec<Vec<f64>>;
    /// Human-readable name (used in Table 4 reports).
    fn name(&self) -> &'static str;
}
