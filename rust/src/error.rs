//! Crate-wide error type.

use std::fmt;

/// Errors surfaced by the rtf-reuse library.
#[derive(Debug)]
pub enum Error {
    /// PJRT / XLA runtime failure (compile, transfer, execute).
    Xla(String),
    /// Artifact directory / manifest problems.
    Artifact(String),
    /// Workflow descriptor or instantiation problems.
    Workflow(String),
    /// Invalid study / sampler configuration.
    Config(String),
    /// Coordinator / scheduling failure.
    Coordinator(String),
    /// I/O error with context.
    Io(std::io::Error),
    /// JSON (de)serialization error.
    Json(String),
    /// Wire-protocol violation (bad frame, unexpected message, version
    /// mismatch) on the serve TCP protocol.
    Protocol(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(m) => write!(f, "xla/pjrt error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Workflow(m) => write!(f, "workflow error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(e) => write!(f, "json error: {e}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
        }
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::jsonx::ParseError> for Error {
    fn from(e: crate::jsonx::ParseError) -> Self {
        Error::Json(e.to_string())
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
