//! The PJRT engine: compile once, execute many.
//!
//! One engine per worker thread owns the compiled executables and runs
//! tasks against them. Invariants the rest of the stack builds on:
//!
//! * **Task interning.** Task names resolve once to a [`TaskId`]
//!   (manifest order); the hot execution path is an array index plus an
//!   allocation-free [`TaskTimer::record`], never a string hash.
//! * **Literal residency.** Chained tasks feed each other's output
//!   literals directly (`execute_task_lit*`); the host round-trip
//!   (literal → `Plane` → literal) happens only at unit boundaries and
//!   at cache insertion. Cache *hits* are literal-resident end to end
//!   too: a served state's plane → literal conversion is memoized per
//!   key, so repeat hits — batched or sequential, local or remote —
//!   skip the conversion entirely.
//! * **Hit/miss partition.** The keyed paths split work into cache hits
//!   — served as refcount bumps on the stored `Arc` states (zero-copy;
//!   see [`crate::cache::CachedState`]) and recorded as zero-cost
//!   `<task>#cached` timer rows — and misses, which execute and publish
//!   exactly their own keys. Batched misses run as ONE backend call with
//!   the per-pixel loops vectorized across lanes (lane-interleaved
//!   layout in the backend; see `rust/xla/src/kernels.rs`).
//! * **Single-flight misses.** Every keyed miss is claimed through
//!   [`crate::cache::ReuseCache::lookup_or_claim`] before executing, so
//!   concurrent engines — other workers of this study, or other tenants
//!   of a shared service — never duplicate a launch for the same key.
//!   The engine publishes all of its own claims before it ever waits on
//!   a foreign flight, which rules out claim/wait deadlock cycles, and
//!   releases claims on error paths via
//!   [`crate::cache::FlightClaims`].
//! * **Scoped accounting.** With [`PjrtEngine::set_cache_scope`], the
//!   engine's [`CacheCtx`] names a per-tenant
//!   [`crate::cache::ScopedCounters`] that every counted cache
//!   operation is mirrored into — the multi-tenant service's per-tenant
//!   ledger.
//! * **Bounded waits.** A wait on a foreign in-flight key is sliced
//!   ([`FLIGHT_WAIT_SLICE`]) and re-resolved rather than parked
//!   indefinitely: if the claimant died wedged (e.g. a remote node that
//!   vanished mid-claim), the claim eventually expires or is released
//!   and this engine re-claims — duplicate work in the worst case,
//!   never a deadlock.
//! * **Fault injection.** [`PjrtEngine::set_fault_hook`] installs a
//!   [`crate::faults::FaultHook`] consulted before every backend
//!   launch; a scripted [`crate::faults::FaultHook::on_launch`] fault
//!   panics the worker thread exactly as a real backend crash would,
//!   exercising the retry/claim-release paths above. Disabled (the
//!   default), the check is one `Option` test.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::{
    CacheCtx, FlightClaims, Key, MetricsClaim, ReuseCache, ScopedCounters, StateClaim,
};
use crate::data::Plane;
use crate::faults::Faults;
use crate::obs::{span, HistId, Obs, SpanCtx};
use crate::{Error, Result};

use super::manifest::ArtifactManifest;

/// Interned task identity: an index into the engine's executable table
/// (manifest order). Resolve once with [`PjrtEngine::task_id`] and use
/// the `_id` execution methods on the hot path — no string hashing or
/// allocation per call.
pub type TaskId = usize;

/// Per-task wall-clock accounting (feeds the Table-6 cost model).
///
/// Executions are recorded under interned [`TaskId`]s plus a `cached`
/// flag — the hot path touches two array slots and never allocates; the
/// display names (`task`, `task#cached`) materialize only in
/// [`TaskTimer::summary`]. Rows absorbed from other workers' summaries
/// stay string-keyed (off the per-execution path).
#[derive(Clone, Debug, Default)]
pub struct TaskTimer {
    /// Interned task names; slot `2*id` accumulates live executions of
    /// `names[id]`, slot `2*id + 1` cache-served ones.
    names: Vec<String>,
    slots: Vec<(Duration, u64)>,
    /// String-keyed rows merged in via [`TaskTimer::absorb`].
    extra: HashMap<String, (Duration, u64)>,
    /// Telemetry handle: live (non-cached) recordings mirror into the
    /// [`HistId::Launch`] histogram, attributed to `tenant`. The
    /// interned table stays — the cost model and summaries need
    /// per-task means, which the fixed-bucket registry cannot provide.
    obs: Obs,
    tenant: Option<Arc<str>>,
}

impl TaskTimer {
    /// A timer with interned slots for `names` (the engine passes its
    /// manifest's task names).
    pub fn with_tasks(names: Vec<String>) -> Self {
        let slots = vec![(Duration::ZERO, 0); names.len() * 2];
        Self { names, slots, ..Self::default() }
    }

    /// Attach the telemetry handle; every subsequent live recording
    /// feeds the launch-latency histogram under `tenant`.
    pub fn set_obs(&mut self, obs: Obs, tenant: Option<Arc<str>>) {
        self.obs = obs;
        self.tenant = tenant;
    }

    /// Record one execution of interned task `id`; `cached` executions
    /// accumulate under the `<task>#cached` summary row.
    pub fn record(&mut self, id: TaskId, cached: bool, elapsed: Duration) {
        let e = &mut self.slots[id * 2 + usize::from(cached)];
        e.0 += elapsed;
        e.1 += 1;
        if !cached {
            // no-op when telemetry is off; cached rows are zero-cost
            // serves, not launches
            self.obs.observe(HistId::Launch, self.tenant.as_deref(), elapsed);
        }
    }

    /// Mean seconds per execution for `task` (a plain task name, or
    /// `<task>#cached` for the cache-served row), if any were recorded.
    pub fn mean_secs(&self, task: &str) -> Option<f64> {
        let (base, cached) = match task.strip_suffix("#cached") {
            Some(b) => (b, true),
            None => (task, false),
        };
        let mut d = Duration::ZERO;
        let mut n = 0u64;
        if let Some(id) = self.names.iter().position(|x| x == base) {
            let (sd, sn) = self.slots[id * 2 + usize::from(cached)];
            d += sd;
            n += sn;
        }
        if let Some((ed, en)) = self.extra.get(task) {
            d += *ed;
            n += *en;
        }
        if n == 0 {
            None
        } else {
            Some(d.as_secs_f64() / n as f64)
        }
    }

    /// Merge another timer's rows into this one (the coordinator folds
    /// every worker engine's timer into a study-wide one).
    pub fn absorb(&mut self, rows: &[(String, f64, u64)]) {
        for (name, mean, n) in rows {
            let e = self.extra.entry(name.clone()).or_insert((Duration::ZERO, 0));
            e.0 += Duration::from_secs_f64(mean * *n as f64);
            e.1 += n;
        }
    }

    /// Total backend launches recorded — every non-cached execution of
    /// every task, comparison included. The launch-count acceptance
    /// metrics (multi-tenant bill, warm-start and tuning benches) are
    /// all built from this.
    pub fn launches(&self) -> u64 {
        let live: u64 = (0..self.names.len()).map(|id| self.slots[id * 2].1).sum();
        let extra: u64 = self
            .extra
            .iter()
            .filter(|(name, _)| !name.ends_with("#cached"))
            .map(|(_, (_, n))| *n)
            .sum();
        live + extra
    }

    /// Executions served from the reuse cache (`<task>#cached` rows).
    pub fn cached_served(&self) -> u64 {
        let live: u64 = (0..self.names.len()).map(|id| self.slots[id * 2 + 1].1).sum();
        let extra: u64 = self
            .extra
            .iter()
            .filter(|(name, _)| name.ends_with("#cached"))
            .map(|(_, (_, n))| *n)
            .sum();
        live + extra
    }

    /// (task, mean seconds, count) for all tasks, sorted by task name.
    /// Cache-served executions report as `<task>#cached` rows.
    pub fn summary(&self) -> Vec<(String, f64, u64)> {
        let mut acc: HashMap<String, (Duration, u64)> = self.extra.clone();
        for (id, name) in self.names.iter().enumerate() {
            for cached in [false, true] {
                let (d, n) = self.slots[id * 2 + usize::from(cached)];
                if n > 0 {
                    let key = if cached { format!("{name}#cached") } else { name.clone() };
                    let e = acc.entry(key).or_insert((Duration::ZERO, 0));
                    e.0 += d;
                    e.1 += n;
                }
            }
        }
        let mut rows: Vec<_> = acc
            .into_iter()
            .map(|(k, (d, n))| (k, d.as_secs_f64() / (n as f64).max(1.0), n))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }
}

/// One batched backend call. With the in-tree native backend this is
/// the vectorized `execute_batch` extension; under the `xla-upstream`
/// cargo feature — for builds against the published `xla` binding,
/// whose API has no batched entry point — it degrades to a loop over
/// `execute` with bit-identical per-lane results (the batching
/// *speedup* is lost, the semantics are not; `tests/batch_exec.rs`
/// width-invariance holds under either path).
#[cfg(not(feature = "xla-upstream"))]
fn backend_execute_batch(
    exe: &xla::PjRtLoadedExecutable,
    states: &[&[xla::Literal; 3]],
    params: &[&[f32]],
) -> Result<Vec<[xla::Literal; 3]>> {
    Ok(exe.execute_batch(states, params)?)
}

/// The `execute_batch` shim for the published `xla` binding: loop over
/// the standard `execute` entry point (see the non-feature twin above).
#[cfg(feature = "xla-upstream")]
fn backend_execute_batch(
    exe: &xla::PjRtLoadedExecutable,
    states: &[&[xla::Literal; 3]],
    params: &[&[f32]],
) -> Result<Vec<[xla::Literal; 3]>> {
    let mut out = Vec::with_capacity(states.len());
    for (state, p) in states.iter().zip(params) {
        let pl = xla::Literal::vec1(p);
        let inputs: [&xla::Literal; 4] = [&state[0], &state[1], &state[2], &pl];
        let result = exe.execute(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let lane: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| Error::Xla("batched task did not return 3 outputs".into()))?;
        out.push(lane);
    }
    Ok(out)
}

/// Loads every artifact, compiles it once on a PJRT CPU client, and
/// executes tasks with concrete planes. One engine per worker thread
/// (PJRT handles are not `Send`).
pub struct PjrtEngine {
    manifest: ArtifactManifest,
    /// Owns the PJRT CPU client; never read directly but must outlive
    /// the loaded executables.
    _client: xla::PjRtClient,
    /// Compiled executables, indexed by interned [`TaskId`] (manifest
    /// order) — the hot path is an array index, not a map lookup.
    execs: Vec<xla::PjRtLoadedExecutable>,
    /// Task name → interned id (resolved once per call site, off the
    /// per-execution path).
    ids: HashMap<String, TaskId>,
    compare_id: TaskId,
    timer: TaskTimer,
    /// Cross-study reuse cache, shared between worker engines. When set,
    /// the keyed execution paths consult/populate it at task granularity.
    cache: Option<Arc<ReuseCache>>,
    /// Accounting context for every cache call: unscoped (global
    /// counters only) by default, or naming the tenant scope set via
    /// [`PjrtEngine::set_cache_scope`].
    ctx: CacheCtx,
    /// Per-key memo of cache-served states already converted to backend
    /// literals: repeat hits on a key are refcount bumps, not
    /// conversions. Bounded by [`LIT_MEMO_CAP`].
    lit_memo: HashMap<Key, [xla::Literal; 3]>,
    /// Fault-injection hook consulted before every backend launch
    /// (inactive by default; see the module docs).
    faults: Faults,
    /// Telemetry handle (off by default): backend calls emit `launch`
    /// spans and feed the launch histogram; threaded into the cache
    /// context so lookups are timed per tier.
    obs: Obs,
    /// The job span this engine's spans parent under, if tracing.
    obs_span: Option<SpanCtx>,
}

/// Capacity of the per-engine hit-conversion memo. Crossing it clears
/// the map wholesale (keys recur heavily within a study, so it refills
/// hot); entries are `Literal` handles, so the footprint is tile-sized
/// per key.
const LIT_MEMO_CAP: usize = 256;

/// How long the engine parks on a foreign in-flight key before
/// re-resolving it. Long enough that the periodic re-poll is free under
/// healthy operation (publications wake waiters immediately through the
/// condvar); short enough that a wedged claimant — a crashed peer whose
/// remote claim must age out — stalls a waiter by seconds, not forever.
const FLIGHT_WAIT_SLICE: Duration = Duration::from_secs(5);

impl PjrtEngine {
    /// Load + compile all artifacts in `dir`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    /// Load + compile from an already-parsed manifest.
    pub fn from_manifest(manifest: ArtifactManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let mut execs = Vec::with_capacity(manifest.tasks.len());
        let mut ids = HashMap::new();
        for (id, t) in manifest.tasks.iter().enumerate() {
            let path = manifest.dir.join(&t.file);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            execs.push(client.compile(&comp)?);
            ids.insert(t.name.clone(), id);
        }
        let compare_id = *ids
            .get(&manifest.compare_task)
            .ok_or_else(|| Error::Artifact("manifest lacks the compare task".into()))?;
        let timer = TaskTimer::with_tasks(manifest.tasks.iter().map(|t| t.name.clone()).collect());
        Ok(Self {
            manifest,
            _client: client,
            execs,
            ids,
            compare_id,
            timer,
            cache: None,
            ctx: CacheCtx::default(),
            lit_memo: HashMap::new(),
            faults: Faults::none(),
            obs: Obs::none(),
            obs_span: None,
        })
    }

    /// Attach a (shared) cross-study reuse cache; keyed executions will
    /// consult it before running and publish what they compute.
    pub fn set_cache(&mut self, cache: Arc<ReuseCache>) {
        self.cache = Some(cache);
    }

    /// Account this engine's cache traffic under a per-tenant scope
    /// (see [`ScopedCounters`]); only meaningful with a cache attached.
    /// Preserves an installed telemetry handle.
    pub fn set_cache_scope(&mut self, scope: Arc<ScopedCounters>) {
        self.ctx = CacheCtx::scoped(scope);
        self.ctx.set_obs(self.obs.clone(), self.obs_span.clone());
    }

    /// Attach the telemetry handle and the job span this engine's
    /// launches and cache lookups should report under; threads both
    /// into the cache context and the task timer. Off
    /// ([`Obs::none`], the default) every instrumented site is one
    /// never-taken branch — and on, only recording happens: telemetry
    /// never changes a result.
    pub fn set_obs(&mut self, obs: Obs, span: Option<SpanCtx>) {
        self.ctx.set_obs(obs.clone(), span.clone());
        self.timer.set_obs(obs.clone(), span.as_ref().map(|s| Arc::clone(&s.tenant)));
        self.obs = obs;
        self.obs_span = span;
    }

    /// The installed telemetry handle and the span the engine currently
    /// parents under — for callers that emit their own spans around
    /// engine calls (the frontier executor's per-level spans).
    pub fn obs_ctx(&self) -> (&Obs, Option<&SpanCtx>) {
        (&self.obs, self.obs_span.as_ref())
    }

    /// Swap the span the engine parents its launch and lookup spans
    /// under (telemetry handle and tenant attribution unchanged),
    /// returning the previous one. The frontier executor brackets each
    /// tree level with this so launches nest under the level's span.
    pub fn swap_obs_span(&mut self, span: Option<SpanCtx>) -> Option<SpanCtx> {
        let prev = self.obs_span.take();
        self.ctx.set_obs(self.obs.clone(), span.clone());
        self.obs_span = span;
        prev
    }

    /// Emit a `launch` span under the engine's job span (no-op with
    /// telemetry off or untraced).
    fn emit_launch(&self, started: Instant, dur: Duration, detail: String) {
        if let (Some(o), Some(sc)) = (self.obs.get(), self.obs_span.as_ref()) {
            let span_id = o.next_span();
            o.emit_timed(sc, span::LAUNCH, span_id, started, dur, detail);
        }
    }

    /// Install a fault-injection hook consulted before every backend
    /// launch (see the module docs). Inactive hooks cost one `Option`
    /// test per launch.
    pub fn set_fault_hook(&mut self, faults: Faults) {
        self.faults = faults;
    }

    /// Consult the fault hook before a backend launch; a scripted fault
    /// panics this worker thread exactly like a real backend crash.
    fn check_launch_fault(&self) {
        if let Some(msg) = self.faults.get().and_then(|h| h.on_launch()) {
            panic!("{msg}");
        }
    }

    /// The attached reuse cache, if any.
    pub fn cache(&self) -> Option<&Arc<ReuseCache>> {
        self.cache.as_ref()
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Interned id of a task, stable for this engine (manifest order).
    pub fn task_id(&self, name: &str) -> Option<TaskId> {
        self.ids.get(name).copied()
    }

    /// Tile height/width the artifacts were compiled for.
    pub fn tile_shape(&self) -> (usize, usize) {
        (self.manifest.height, self.manifest.width)
    }

    pub fn timer(&self) -> &TaskTimer {
        &self.timer
    }

    fn plane_literal(&self, p: &Plane) -> Result<xla::Literal> {
        if (p.height(), p.width()) != self.tile_shape() {
            return Err(Error::Xla(format!(
                "plane {}x{} does not match artifact tile {}x{}",
                p.height(),
                p.width(),
                self.manifest.height,
                self.manifest.width
            )));
        }
        Ok(xla::Literal::vec1(p.data()).reshape(&[p.height() as i64, p.width() as i64])?)
    }

    fn literal_plane(&self, lit: &xla::Literal) -> Result<Plane> {
        let (h, w) = self.tile_shape();
        let data = lit.to_vec::<f32>()?;
        Plane::new(data, h, w)
    }

    /// Serve a cache-hit state as literals through the per-key memo:
    /// the first hit on a key pays the plane → literal conversion, every
    /// repeat hit — batched warm runs revisit keys constantly — is a
    /// handle clone.
    fn lit_state_memo(&mut self, key: Key, state: &[Plane; 3]) -> Result<[xla::Literal; 3]> {
        if let Some(lits) = self.lit_memo.get(&key) {
            return Ok(lits.clone());
        }
        let lits = self.lit_state(state)?;
        if self.lit_memo.len() >= LIT_MEMO_CAP {
            self.lit_memo.clear();
        }
        self.lit_memo.insert(key, lits.clone());
        Ok(lits)
    }

    /// Convert a 3-plane state to literals (unit-boundary transfer).
    pub fn lit_state(&self, state: &[Plane; 3]) -> Result<[xla::Literal; 3]> {
        Ok([
            self.plane_literal(&state[0])?,
            self.plane_literal(&state[1])?,
            self.plane_literal(&state[2])?,
        ])
    }

    /// Convert a 3-literal state back to planes.
    pub fn plane_state(&self, lits: &[xla::Literal; 3]) -> Result<[Plane; 3]> {
        Ok([
            self.literal_plane(&lits[0])?,
            self.literal_plane(&lits[1])?,
            self.literal_plane(&lits[2])?,
        ])
    }

    /// Resolve a task name, erroring on unknown tasks.
    pub fn require_id(&self, name: &str) -> Result<TaskId> {
        self.task_id(name).ok_or_else(|| Error::Artifact(format!("unknown task `{name}`")))
    }

    /// Validate that `id` names a 3-plane chain task.
    fn require_chain(&self, id: TaskId) -> Result<()> {
        let t = &self.manifest.tasks[id];
        if t.image_inputs != 3 || t.outputs != 3 {
            return Err(Error::Artifact(format!(
                "task `{}` is not a 3-plane chain task (use execute_compare)",
                t.name
            )));
        }
        Ok(())
    }

    /// Execute a chain task with literal-resident state — the hot path:
    /// chained tasks feed each other's output literals directly, so the
    /// host round-trip (literal → Plane → literal, ~23% of per-task
    /// wall time at 128×128; EXPERIMENTS.md §Perf change 3) happens only
    /// at unit boundaries.
    pub fn execute_task_lit(
        &mut self,
        name: &str,
        state: &[xla::Literal; 3],
        params: &[f32],
    ) -> Result<[xla::Literal; 3]> {
        let id = self.require_id(name)?;
        self.execute_task_lit_id(id, state, params)
    }

    /// [`PjrtEngine::execute_task_lit`] over an interned [`TaskId`].
    pub fn execute_task_lit_id(
        &mut self,
        id: TaskId,
        state: &[xla::Literal; 3],
        params: &[f32],
    ) -> Result<[xla::Literal; 3]> {
        self.require_chain(id)?;
        self.check_launch_fault();
        let start = Instant::now();
        let pl = self.param_literal(params)?;
        let inputs: [&xla::Literal; 4] = [&state[0], &state[1], &state[2], &pl];
        let exe = &self.execs[id];
        let result = exe.execute(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let out: [xla::Literal; 3] = parts.try_into().map_err(|_| {
            Error::Xla(format!("task `{}` did not return 3 outputs", self.manifest.tasks[id].name))
        })?;
        let dur = start.elapsed();
        self.timer.record(id, false, dur);
        if self.obs.is_active() {
            self.emit_launch(start, dur, self.manifest.tasks[id].name.clone());
        }
        Ok(out)
    }

    /// Cache-aware chain-task execution: when a cache is attached and a
    /// content key is supplied, a cached state short-circuits the PJRT
    /// execution entirely (recorded as a zero-cost `<task>#cached` timer
    /// row so study summaries report reuse per task); a miss *claims* the
    /// key (single-flight), executes, and publishes the result — a
    /// concurrent engine missing the same key waits for the publication
    /// instead of duplicating the launch. Returns the output state and
    /// whether it was served from the cache.
    pub fn execute_task_lit_keyed(
        &mut self,
        name: &str,
        key: Option<Key>,
        state: &[xla::Literal; 3],
        params: &[f32],
    ) -> Result<([xla::Literal; 3], bool)> {
        let id = self.require_id(name)?;
        self.execute_task_lit_keyed_id(id, key, state, params)
    }

    /// [`PjrtEngine::execute_task_lit_keyed`] over an interned
    /// [`TaskId`].
    pub fn execute_task_lit_keyed_id(
        &mut self,
        id: TaskId,
        key: Option<Key>,
        state: &[xla::Literal; 3],
        params: &[f32],
    ) -> Result<([xla::Literal; 3], bool)> {
        if let (Some(cache), Some(k)) = (self.cache.clone(), key) {
            let ctx = self.ctx.clone();
            loop {
                match cache.lookup_or_claim(k, &ctx) {
                    StateClaim::Ready(planes) => {
                        let lits = self.lit_state_memo(k, &planes)?;
                        self.timer.record(id, true, Duration::ZERO);
                        return Ok((lits, true));
                    }
                    StateClaim::Claimed => {
                        // release the claim if execution errors, so
                        // waiters re-claim instead of blocking forever
                        let mut claims = FlightClaims::new(cache.clone());
                        claims.add(k);
                        let out = self.execute_task_lit_id(id, state, params)?;
                        let planes = self.plane_state(&out)?;
                        cache.put_state(k, planes, &ctx);
                        claims.settle(k);
                        return Ok((out, false));
                    }
                    // holding no claim of our own: safe to block — but
                    // bounded, so a wedged claimant is re-resolved, not
                    // waited on forever
                    StateClaim::InFlight => {
                        cache.wait_for_flight_for(k, FLIGHT_WAIT_SLICE);
                    }
                }
            }
        }
        Ok((self.execute_task_lit_id(id, state, params)?, false))
    }

    /// Cache-aware **batched** chain-task execution: partitions the
    /// batch into cache hits and misses, serves every hit from the cache
    /// (a refcount bump on the stored state), executes the misses it
    /// *claims* (single-flight) in one backend call per round with the
    /// per-pixel loops vectorized across the batch, publishes exactly
    /// the claimed results, and returns per-lane
    /// `(state, served_from_cache)` in input order. Lanes whose key is
    /// in flight on another engine wait for that publication — after
    /// this call has published every claim of its own, so claim/wait
    /// cycles cannot form — and are then served as hits. Lanes without a
    /// key (or with no cache attached) always execute.
    pub fn execute_task_batch_keyed(
        &mut self,
        id: TaskId,
        keys: &[Option<Key>],
        states: &[&[xla::Literal; 3]],
        params: &[&[f32]],
    ) -> Result<Vec<([xla::Literal; 3], bool)>> {
        let n = states.len();
        if keys.len() != n || params.len() != n {
            return Err(Error::Xla(format!(
                "batch arity mismatch: {n} states, {} keys, {} params",
                keys.len(),
                params.len()
            )));
        }
        self.require_chain(id)?;
        let cache = self.cache.clone();
        let ctx = self.ctx.clone();
        let mut out: Vec<Option<([xla::Literal; 3], bool)>> = (0..n).map(|_| None).collect();
        // intra-batch dedup: a later lane whose (quantized) key equals a
        // key this call already claimed is served the claimant's result —
        // exactly what the sequential path does, where the earlier node
        // publishes before the later one looks up. Without this, width >
        // 1 could diverge from width 1 under quantized keys (and a lane
        // would deadlock waiting on its own sibling's claim).
        let mut dup_of: Vec<(usize, usize)> = Vec::new();
        let mut claimed_by: HashMap<Key, usize> = HashMap::new();
        // claims this call owns; released on publication, or on drop if
        // execution errors, so waiters re-claim instead of blocking
        let mut claims = cache.as_ref().map(|c| FlightClaims::new(c.clone()));

        let mut pending: Vec<usize> = (0..n).collect();
        loop {
            let mut exec: Vec<usize> = Vec::new();
            let mut waiting: Vec<usize> = Vec::new();
            for &i in &pending {
                match (&cache, keys[i]) {
                    (Some(c), Some(k)) => {
                        if let Some(&src) = claimed_by.get(&k) {
                            dup_of.push((i, src));
                            continue;
                        }
                        match c.lookup_or_claim(k, &ctx) {
                            StateClaim::Ready(planes) => {
                                let lits = self.lit_state_memo(k, &planes)?;
                                self.timer.record(id, true, Duration::ZERO);
                                out[i] = Some((lits, true));
                            }
                            StateClaim::Claimed => {
                                claimed_by.insert(k, i);
                                if let Some(cl) = claims.as_mut() {
                                    cl.add(k);
                                }
                                exec.push(i);
                            }
                            StateClaim::InFlight => waiting.push(i),
                        }
                    }
                    _ => exec.push(i),
                }
            }
            if !exec.is_empty() {
                self.check_launch_fault();
                let start = Instant::now();
                let mut padded: Vec<Vec<f32>> = Vec::with_capacity(exec.len());
                for &i in &exec {
                    padded.push(self.padded_params(params[i])?);
                }
                let p_refs: Vec<&[f32]> = padded.iter().map(|p| p.as_slice()).collect();
                let s_refs: Vec<&[xla::Literal; 3]> = exec.iter().map(|&i| states[i]).collect();
                let results = backend_execute_batch(&self.execs[id], &s_refs, &p_refs)?;
                let elapsed = start.elapsed();
                if results.len() != exec.len() {
                    return Err(Error::Xla(format!(
                        "batch returned {} states for {} lanes",
                        results.len(),
                        exec.len()
                    )));
                }
                // one batched call = one backend launch = one span
                if self.obs.is_active() {
                    let name = &self.manifest.tasks[id].name;
                    self.emit_launch(start, elapsed, format!("{name} x{}", exec.len()));
                }
                // per-task accounting: the launch cost amortizes over lanes
                let per_lane = elapsed / exec.len() as u32;
                for (&i, lits) in exec.iter().zip(results) {
                    if let (Some(c), Some(k)) = (&cache, keys[i]) {
                        c.put_state(k, self.plane_state(&lits)?, &ctx);
                        if let Some(cl) = claims.as_mut() {
                            cl.settle(k);
                        }
                    }
                    self.timer.record(id, false, per_lane);
                    out[i] = Some((lits, false));
                }
            }
            if waiting.is_empty() {
                break;
            }
            // every claim of this call is published: safe to block on a
            // foreign flight (bounded — a wedged claimant is re-resolved
            // next round), then re-resolve the still-pending lanes
            if let (Some(c), Some(k)) = (&cache, keys[waiting[0]]) {
                c.wait_for_flight_for(k, FLIGHT_WAIT_SLICE);
            }
            pending = waiting;
        }
        for (i, src) in dup_of {
            let lits = out[src].as_ref().expect("dedup source resolved").0.clone();
            if let Some(c) = &cache {
                // the sequential path would hit the just-published key
                c.note_state_hit(&ctx);
            }
            self.timer.record(id, true, Duration::ZERO);
            out[i] = Some((lits, true));
        }
        Ok(out.into_iter().map(|o| o.expect("every lane resolved")).collect())
    }

    /// Cache-aware comparison execution (metrics are memoized under the
    /// full chain key folded with the reference-mask fingerprint —
    /// [`crate::cache::metrics_key`]), single-flight like the state
    /// paths.
    pub fn execute_compare_keyed(
        &mut self,
        key: Option<Key>,
        state: &[Plane; 3],
        reference: &Plane,
    ) -> Result<([f32; 3], bool)> {
        if let (Some(cache), Some(k)) = (self.cache.clone(), key) {
            let ctx = self.ctx.clone();
            loop {
                match cache.lookup_or_claim_metrics(k, &ctx) {
                    MetricsClaim::Ready(m) => {
                        self.timer.record(self.compare_id, true, Duration::ZERO);
                        return Ok((m, true));
                    }
                    MetricsClaim::Claimed => {
                        let mut claims = FlightClaims::new(cache.clone());
                        claims.add(k);
                        let m = self.execute_compare(state, reference)?;
                        cache.put_metrics(k, m);
                        claims.settle(k);
                        return Ok((m, false));
                    }
                    MetricsClaim::InFlight => {
                        cache.wait_for_flight_for(k, FLIGHT_WAIT_SLICE);
                    }
                }
            }
        }
        Ok((self.execute_compare(state, reference)?, false))
    }

    /// Execute a chain task (`norm`, `t1`..`t7`): 3 planes + padded param
    /// vector in, 3 planes out. Convenience wrapper over
    /// [`PjrtEngine::execute_task_lit`].
    pub fn execute_task(
        &mut self,
        name: &str,
        state: &[Plane; 3],
        params: &[f32],
    ) -> Result<[Plane; 3]> {
        let lits = self.lit_state(state)?;
        let out = self.execute_task_lit(name, &lits, params)?;
        self.plane_state(&out)
    }

    /// Execute the comparison task: final state + reference mask in,
    /// `(dice, jaccard, mean |diff|)` out.
    pub fn execute_compare(
        &mut self,
        state: &[Plane; 3],
        reference: &Plane,
    ) -> Result<[f32; 3]> {
        let id = self.compare_id;
        self.check_launch_fault();
        let start = Instant::now();
        let inputs = vec![
            self.plane_literal(&state[0])?,
            self.plane_literal(&state[1])?,
            self.plane_literal(&state[2])?,
            self.plane_literal(reference)?,
            self.param_literal(&[])?,
        ];
        let exe = &self.execs[id];
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let metrics = result.to_tuple1()?;
        let v = metrics.to_vec::<f32>()?;
        if v.len() != 3 {
            return Err(Error::Xla(format!("compare returned {} metrics", v.len())));
        }
        let dur = start.elapsed();
        self.timer.record(id, false, dur);
        if self.obs.is_active() {
            self.emit_launch(start, dur, self.manifest.compare_task.clone());
        }
        Ok([v[0], v[1], v[2]])
    }

    /// Zero-pad a parameter vector to the artifact capacity.
    fn padded_params(&self, params: &[f32]) -> Result<Vec<f32>> {
        let mut padded = vec![0.0f32; self.manifest.n_params];
        if params.len() > self.manifest.n_params {
            return Err(Error::Config(format!(
                "{} params exceed artifact capacity {}",
                params.len(),
                self.manifest.n_params
            )));
        }
        padded[..params.len()].copy_from_slice(params);
        Ok(padded)
    }

    fn param_literal(&self, params: &[f32]) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&self.padded_params(params)?))
    }

    /// Run the full chain (norm → t7) on one tile with per-task params,
    /// returning the final 3-plane state.
    pub fn run_chain(
        &mut self,
        tile: &crate::data::TileSet,
        task_params: &HashMap<String, Vec<f32>>,
    ) -> Result<[Plane; 3]> {
        let planes = [tile.r.clone(), tile.g.clone(), tile.b.clone()];
        let mut state = self.lit_state(&planes)?;
        let order = self.manifest.task_order.clone();
        for name in &order {
            let empty = Vec::new();
            let p = task_params.get(name).unwrap_or(&empty);
            state = self.execute_task_lit(name, &state, p)?;
        }
        self.plane_state(&state)
    }
}
