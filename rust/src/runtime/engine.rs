//! The PJRT engine: compile once, execute many.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cache::ReuseCache;
use crate::data::Plane;
use crate::{Error, Result};

use super::manifest::ArtifactManifest;

/// Per-task wall-clock accounting (feeds the Table-6 cost model).
#[derive(Clone, Debug, Default)]
pub struct TaskTimer {
    totals: HashMap<String, (Duration, u64)>,
}

impl TaskTimer {
    pub fn record(&mut self, task: &str, elapsed: Duration) {
        let e = self.totals.entry(task.to_string()).or_default();
        e.0 += elapsed;
        e.1 += 1;
    }

    /// Mean seconds per execution for `task`, if any were recorded.
    pub fn mean_secs(&self, task: &str) -> Option<f64> {
        self.totals.get(task).map(|(d, n)| d.as_secs_f64() / (*n as f64).max(1.0))
    }

    /// Merge another timer's rows into this one (the coordinator folds
    /// every worker engine's timer into a study-wide one).
    pub fn absorb(&mut self, rows: &[(String, f64, u64)]) {
        for (name, mean, n) in rows {
            let e = self.totals.entry(name.clone()).or_default();
            e.0 += Duration::from_secs_f64(mean * *n as f64);
            e.1 += n;
        }
    }

    /// (task, mean seconds, count) for all tasks, sorted by task name.
    pub fn summary(&self) -> Vec<(String, f64, u64)> {
        let mut rows: Vec<_> = self
            .totals
            .iter()
            .map(|(k, (d, n))| (k.clone(), d.as_secs_f64() / (*n as f64).max(1.0), *n))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }
}

/// Loads every artifact, compiles it once on a PJRT CPU client, and
/// executes tasks with concrete planes. One engine per worker thread
/// (PJRT handles are not `Send`).
pub struct PjrtEngine {
    manifest: ArtifactManifest,
    /// Owns the PJRT CPU client; never read directly but must outlive
    /// the loaded executables.
    _client: xla::PjRtClient,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
    timer: TaskTimer,
    /// Cross-study reuse cache, shared between worker engines. When set,
    /// the keyed execution paths consult/populate it at task granularity.
    cache: Option<Arc<ReuseCache>>,
}

impl PjrtEngine {
    /// Load + compile all artifacts in `dir`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        Self::from_manifest(manifest)
    }

    /// Load + compile from an already-parsed manifest.
    pub fn from_manifest(manifest: ArtifactManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let mut execs = HashMap::new();
        for t in &manifest.tasks {
            let path = manifest.dir.join(&t.file);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            execs.insert(t.name.clone(), exe);
        }
        Ok(Self { manifest, _client: client, execs, timer: TaskTimer::default(), cache: None })
    }

    /// Attach a (shared) cross-study reuse cache; keyed executions will
    /// consult it before running and publish what they compute.
    pub fn set_cache(&mut self, cache: Arc<ReuseCache>) {
        self.cache = Some(cache);
    }

    /// The attached reuse cache, if any.
    pub fn cache(&self) -> Option<&Arc<ReuseCache>> {
        self.cache.as_ref()
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Tile height/width the artifacts were compiled for.
    pub fn tile_shape(&self) -> (usize, usize) {
        (self.manifest.height, self.manifest.width)
    }

    pub fn timer(&self) -> &TaskTimer {
        &self.timer
    }

    fn plane_literal(&self, p: &Plane) -> Result<xla::Literal> {
        if (p.height(), p.width()) != self.tile_shape() {
            return Err(Error::Xla(format!(
                "plane {}x{} does not match artifact tile {}x{}",
                p.height(),
                p.width(),
                self.manifest.height,
                self.manifest.width
            )));
        }
        Ok(xla::Literal::vec1(p.data()).reshape(&[p.height() as i64, p.width() as i64])?)
    }

    fn literal_plane(&self, lit: &xla::Literal) -> Result<Plane> {
        let (h, w) = self.tile_shape();
        let data = lit.to_vec::<f32>()?;
        Plane::new(data, h, w)
    }

    /// Convert a 3-plane state to literals (unit-boundary transfer).
    pub fn lit_state(&self, state: &[Plane; 3]) -> Result<[xla::Literal; 3]> {
        Ok([
            self.plane_literal(&state[0])?,
            self.plane_literal(&state[1])?,
            self.plane_literal(&state[2])?,
        ])
    }

    /// Convert a 3-literal state back to planes.
    pub fn plane_state(&self, lits: &[xla::Literal; 3]) -> Result<[Plane; 3]> {
        Ok([
            self.literal_plane(&lits[0])?,
            self.literal_plane(&lits[1])?,
            self.literal_plane(&lits[2])?,
        ])
    }

    /// Execute a chain task with literal-resident state — the hot path:
    /// chained tasks feed each other's output literals directly, so the
    /// host round-trip (literal → Plane → literal, ~23% of per-task
    /// wall time at 128×128; EXPERIMENTS.md §Perf change 3) happens only
    /// at unit boundaries.
    pub fn execute_task_lit(
        &mut self,
        name: &str,
        state: &[xla::Literal; 3],
        params: &[f32],
    ) -> Result<[xla::Literal; 3]> {
        let t = self
            .manifest
            .task(name)
            .ok_or_else(|| Error::Artifact(format!("unknown task `{name}`")))?;
        if t.image_inputs != 3 || t.outputs != 3 {
            return Err(Error::Artifact(format!(
                "task `{name}` is not a 3-plane chain task (use execute_compare)"
            )));
        }
        let start = Instant::now();
        let pl = self.param_literal(params)?;
        let inputs: [&xla::Literal; 4] = [&state[0], &state[1], &state[2], &pl];
        let exe = &self.execs[name];
        let result = exe.execute(&inputs)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        let out: [xla::Literal; 3] = parts
            .try_into()
            .map_err(|_| Error::Xla(format!("task `{name}` did not return 3 outputs")))?;
        self.timer.record(name, start.elapsed());
        Ok(out)
    }

    /// Cache-aware chain-task execution: when a cache is attached and a
    /// content key is supplied, a cached state short-circuits the PJRT
    /// execution entirely (recorded as a zero-cost `<task>#cached` timer
    /// row so study summaries report reuse per task); a miss executes and
    /// publishes the result. Returns the output state and whether it was
    /// served from the cache.
    pub fn execute_task_lit_keyed(
        &mut self,
        name: &str,
        key: Option<u64>,
        state: &[xla::Literal; 3],
        params: &[f32],
    ) -> Result<([xla::Literal; 3], bool)> {
        if let (Some(cache), Some(k)) = (self.cache.clone(), key) {
            if let Some(planes) = cache.get_state(k) {
                let lits = self.lit_state(&planes)?;
                self.timer.record(&format!("{name}#cached"), Duration::ZERO);
                return Ok((lits, true));
            }
            let out = self.execute_task_lit(name, state, params)?;
            let planes = self.plane_state(&out)?;
            cache.put_state(k, planes);
            return Ok((out, false));
        }
        Ok((self.execute_task_lit(name, state, params)?, false))
    }

    /// Cache-aware comparison execution (metrics are memoized under the
    /// full chain key folded with the reference-mask fingerprint).
    pub fn execute_compare_keyed(
        &mut self,
        key: Option<u64>,
        state: &[Plane; 3],
        reference: &Plane,
    ) -> Result<([f32; 3], bool)> {
        if let (Some(cache), Some(k)) = (self.cache.clone(), key) {
            if let Some(m) = cache.get_metrics(k) {
                let name = self.manifest.compare_task.clone();
                self.timer.record(&format!("{name}#cached"), Duration::ZERO);
                return Ok((m, true));
            }
            let m = self.execute_compare(state, reference)?;
            cache.put_metrics(k, m);
            return Ok((m, false));
        }
        Ok((self.execute_compare(state, reference)?, false))
    }

    /// Execute a chain task (`norm`, `t1`..`t7`): 3 planes + padded param
    /// vector in, 3 planes out. Convenience wrapper over
    /// [`PjrtEngine::execute_task_lit`].
    pub fn execute_task(
        &mut self,
        name: &str,
        state: &[Plane; 3],
        params: &[f32],
    ) -> Result<[Plane; 3]> {
        let lits = self.lit_state(state)?;
        let out = self.execute_task_lit(name, &lits, params)?;
        self.plane_state(&out)
    }

    /// Execute the comparison task: final state + reference mask in,
    /// `(dice, jaccard, mean |diff|)` out.
    pub fn execute_compare(
        &mut self,
        state: &[Plane; 3],
        reference: &Plane,
    ) -> Result<[f32; 3]> {
        let name = self.manifest.compare_task.clone();
        let start = Instant::now();
        let inputs = vec![
            self.plane_literal(&state[0])?,
            self.plane_literal(&state[1])?,
            self.plane_literal(&state[2])?,
            self.plane_literal(reference)?,
            self.param_literal(&[])?,
        ];
        let exe = &self.execs[&name];
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let metrics = result.to_tuple1()?;
        let v = metrics.to_vec::<f32>()?;
        if v.len() != 3 {
            return Err(Error::Xla(format!("compare returned {} metrics", v.len())));
        }
        self.timer.record(&name, start.elapsed());
        Ok([v[0], v[1], v[2]])
    }

    fn param_literal(&self, params: &[f32]) -> Result<xla::Literal> {
        let mut padded = vec![0.0f32; self.manifest.n_params];
        if params.len() > self.manifest.n_params {
            return Err(Error::Config(format!(
                "{} params exceed artifact capacity {}",
                params.len(),
                self.manifest.n_params
            )));
        }
        padded[..params.len()].copy_from_slice(params);
        Ok(xla::Literal::vec1(&padded))
    }

    /// Run the full chain (norm → t7) on one tile with per-task params,
    /// returning the final 3-plane state.
    pub fn run_chain(
        &mut self,
        tile: &crate::data::TileSet,
        task_params: &HashMap<String, Vec<f32>>,
    ) -> Result<[Plane; 3]> {
        let planes = [tile.r.clone(), tile.g.clone(), tile.b.clone()];
        let mut state = self.lit_state(&planes)?;
        let order = self.manifest.task_order.clone();
        for name in &order {
            let empty = Vec::new();
            let p = task_params.get(name).unwrap_or(&empty);
            state = self.execute_task_lit(name, &state, p)?;
        }
        self.plane_state(&state)
    }
}
