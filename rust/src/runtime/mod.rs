//! PJRT execution of the AOT-compiled task artifacts.
//!
//! `make artifacts` lowers every workflow task (L2 JAX calling the L1
//! Pallas kernels) to HLO *text* under `artifacts/`; this module loads
//! them through `HloModuleProto::from_text_file`, compiles each once per
//! engine with the PJRT CPU client, and executes them from the L3 hot
//! path. Text is the interchange format because jax ≥ 0.5 emits protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects.
//!
//! PJRT handles are not `Send`; the coordinator therefore gives each
//! worker node its own [`PjrtEngine`] on a dedicated OS thread — which is
//! also the faithful topology: every RTF worker node is its own process
//! with its own runtime.

mod engine;
mod manifest;

pub use engine::{PjrtEngine, TaskId, TaskTimer};
pub use manifest::{ArtifactManifest, TaskArtifact};
