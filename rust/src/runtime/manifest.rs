//! `artifacts/manifest.json` — the contract between `compile/aot.py`
//! and the Rust loader.

use std::path::{Path, PathBuf};

use crate::jsonx::Json;
use crate::workflow::{sig_hash, str_bits};
use crate::{Error, Result};

/// One task artifact entry.
#[derive(Clone, Debug)]
pub struct TaskArtifact {
    pub name: String,
    pub file: String,
    pub image_inputs: usize,
    pub param_inputs: usize,
    pub outputs: usize,
    pub output_kind: String,
    pub sha256_16: String,
}

/// Parsed manifest plus its directory.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub height: usize,
    pub width: usize,
    pub n_params: usize,
    pub depth_levels: usize,
    pub task_order: Vec<String>,
    pub compare_task: String,
    pub tasks: Vec<TaskArtifact>,
    pub dir: PathBuf,
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Artifact(format!("manifest: missing/invalid `{key}`")))
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Artifact(format!("manifest: missing/invalid `{key}`")))?
        .to_string())
}

impl ArtifactManifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let task_order = v
            .get("task_order")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest: missing `task_order`".into()))?
            .iter()
            .map(|j| j.as_str().unwrap_or_default().to_string())
            .collect();
        let mut tasks = Vec::new();
        for tj in v
            .get("tasks")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Artifact("manifest: missing `tasks`".into()))?
        {
            tasks.push(TaskArtifact {
                name: req_str(tj, "name")?,
                file: req_str(tj, "file")?,
                image_inputs: req_usize(tj, "image_inputs")?,
                param_inputs: req_usize(tj, "param_inputs")?,
                outputs: req_usize(tj, "outputs")?,
                output_kind: req_str(tj, "output_kind")?,
                sha256_16: req_str(tj, "sha256_16").unwrap_or_default(),
            });
        }
        let m = ArtifactManifest {
            height: req_usize(&v, "height")?,
            width: req_usize(&v, "width")?,
            n_params: req_usize(&v, "n_params")?,
            depth_levels: req_usize(&v, "depth_levels")?,
            task_order,
            compare_task: req_str(&v, "compare_task")?,
            tasks,
            dir: dir.to_path_buf(),
        };
        m.validate()?;
        Ok(m)
    }

    fn validate(&self) -> Result<()> {
        for name in self.task_order.iter().chain([&self.compare_task]) {
            let t = self
                .task(name)
                .ok_or_else(|| Error::Artifact(format!("manifest missing task `{name}`")))?;
            let p = self.dir.join(&t.file);
            if !p.exists() {
                return Err(Error::Artifact(format!("artifact file missing: {}", p.display())));
            }
        }
        if self.n_params == 0 || self.height == 0 || self.width == 0 {
            return Err(Error::Artifact("degenerate manifest dimensions".into()));
        }
        Ok(())
    }

    /// Stable fingerprint of the artifact set: tile shape, parameter
    /// capacity, and every task's identity + content hash. The
    /// cross-study cache folds this into its key roots so states
    /// computed by different kernels/artifacts never alias — regenerated
    /// artifacts (new `sha256_16` tags) invalidate old cache entries by
    /// construction.
    pub fn fingerprint(&self) -> u64 {
        let mut parts = vec![
            self.height as u64,
            self.width as u64,
            self.n_params as u64,
            self.depth_levels as u64,
        ];
        for t in &self.tasks {
            parts.push(str_bits(&t.name));
            parts.push(str_bits(&t.file));
            parts.push(str_bits(&t.sha256_16));
            parts.push(t.image_inputs as u64);
            parts.push(t.outputs as u64);
        }
        sig_hash(&parts)
    }

    /// Find a task entry by name.
    pub fn task(&self, name: &str) -> Option<&TaskArtifact> {
        self.tasks.iter().find(|t| t.name == name)
    }

    /// Absolute path of a task's HLO file.
    pub fn hlo_path(&self, name: &str) -> Option<PathBuf> {
        self.task(name).map(|t| self.dir.join(&t.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn load_real_manifest() {
        let m = ArtifactManifest::load(artifacts_dir()).expect("run `make artifacts` first");
        assert_eq!(m.n_params, 5);
        assert_eq!(m.task_order.len(), 8);
        assert_eq!(m.task_order[0], "norm");
        assert_eq!(m.compare_task, "cmp");
        let cmp = m.task("cmp").unwrap();
        assert_eq!(cmp.image_inputs, 4);
        assert_eq!(cmp.output_kind, "metrics3");
        assert!(m.hlo_path("t3").unwrap().exists());
    }

    #[test]
    fn missing_dir_is_artifact_error() {
        let err = ArtifactManifest::load("/nonexistent/path").unwrap_err();
        assert!(matches!(err, Error::Artifact(_)));
    }
}
