//! Process-wide telemetry: structured job tracing, named counters and
//! fixed-bucket latency histograms, behind a zero-cost-when-off handle.
//!
//! The design mirrors [`crate::faults::Faults`]: an [`Obs`] is an
//! `Option<Arc<ObsInner>>` — [`Obs::none`] (the default) makes every
//! recording call a single never-taken branch on the hot path, and an
//! active handle is an `Arc` shared by every layer of one process
//! (service, engine, cache stack, remote tier, wire server). The
//! telemetry invariant the rest of the stack builds on: **telemetry off
//! is zero-cost; telemetry on never changes a result** — observers only
//! read clocks and bump atomics, they never touch the data path.
//!
//! # Tracing
//!
//! Every job gets a 128-bit trace id at admission. Spans cover
//! admit → queue wait → schedule → per-level frontier execution →
//! lower-tier cache lookups → kernel launches → retries → drain, each
//! emitted as one [`SpanEvent`] into a bounded ring buffer
//! ([`RING_CAP`]; overflow drops the oldest and counts
//! [`ObsSnapshot::ring_dropped`]) and, with a `trace=FILE` sink, as one
//! JSONL line ([`event_json`] / [`parse_event`]). Trace id and parent
//! span id propagate on `route` / `cache-get` / `cache-put` frames
//! (protocol v7, optional fields), so a routed job's spans — and the
//! owner-side `serve-get` / `serve-put` spans its cache traffic causes
//! on peer nodes — stitch into one cross-node tree: one root per trace,
//! every parent link resolvable. Span ids are node-unique (an atomic
//! counter salted per process), timestamps are per-node monotonic
//! offsets ([`std::time::Instant`], never wall-clock arithmetic), and
//! the tree structure never depends on clock agreement between nodes.
//!
//! # Metrics
//!
//! A fixed registry: [`CounterId`] counters and [`HistId`] latency
//! histograms over the fixed [`BUCKET_BOUNDS_US`] bucket boundaries
//! (job wall, queue wait, per-tier lookup, kernel launch, peer RTT,
//! retry backoff). Recording with a tenant label bumps the global
//! registry *and* the tenant's — the same discipline as
//! [`crate::cache::ScopedCounters`], so per-tenant counters sum exactly
//! to the globals on every field that is recorded with a tenant.
//! Unattributed traffic (peer RTT, speculative work) is global-only.
//!
//! # Exposure
//!
//! [`ObsInner::snapshot`] is the point-in-time [`ObsSnapshot`] behind
//! the `stats` wire message, the `stats` admin job line's
//! Prometheus-style client dump, and the `stats=on` periodic server
//! digest. See `docs/OBSERVABILITY.md` for the event schema, metric
//! names and operator cookbook.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::jsonx::{obj, Json};
use crate::{Error, Result};

/// Span-kind names as they appear on the wire and in trace files.
pub mod span {
    /// Root span of one job, admission to completion.
    pub const JOB: &str = "job";
    /// Admission processing inside `submit` (queue insertion).
    pub const ADMIT: &str = "admit";
    /// Queue wait: admission to a worker popping the job.
    pub const QUEUE: &str = "queue";
    /// Worker dispatch: pop to execution start.
    pub const SCHEDULE: &str = "schedule";
    /// One frontier level of one unit's reuse-tree walk.
    pub const LEVEL: &str = "level";
    /// One lower-tier cache lookup (detail names the tier).
    pub const LOOKUP: &str = "lookup";
    /// One backend kernel launch (batched: one span per call).
    pub const LAUNCH: &str = "launch";
    /// One retried attempt (duration = the backoff slept).
    pub const RETRY: &str = "retry";
    /// Service drain: admission stop to last job completion.
    pub const DRAIN: &str = "drain";
    /// Front-door routing of a submit to the owning peer.
    pub const ROUTE: &str = "route";
    /// Owner-side service of a peer's `cache-get`.
    pub const SERVE_GET: &str = "serve-get";
    /// Owner-side service of a peer's `cache-put`.
    pub const SERVE_PUT: &str = "serve-put";
}

/// Bounded span ring capacity; overflow drops the oldest event and is
/// counted, never silently.
pub const RING_CAP: usize = 8192;

/// Fixed histogram bucket upper bounds, microseconds. Chosen to resolve
/// both a sub-millisecond memory-tier lookup and a multi-second job
/// wall on one scale; the implicit final bucket is +Inf.
pub const BUCKET_BOUNDS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 50_000, 100_000, 1_000_000, 10_000_000];

/// Named counters of the metrics registry (wire/dump names via
/// [`CounterId::name`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CounterId {
    /// Jobs admitted into the queue.
    JobsAdmitted,
    /// Jobs completed (success or failure).
    JobsCompleted,
    /// Jobs whose final attempt failed.
    JobsFailed,
    /// Retried attempts across all jobs.
    Retries,
    /// Backend kernel launches.
    Launches,
    /// Task executions served from the reuse cache.
    CachedTasks,
    /// Submits forwarded to a peer by the front door.
    JobsRouted,
}

impl CounterId {
    /// Every counter, in wire order.
    pub const ALL: [CounterId; 7] = [
        CounterId::JobsAdmitted,
        CounterId::JobsCompleted,
        CounterId::JobsFailed,
        CounterId::Retries,
        CounterId::Launches,
        CounterId::CachedTasks,
        CounterId::JobsRouted,
    ];

    /// The counter's registry/wire name.
    pub fn name(self) -> &'static str {
        match self {
            CounterId::JobsAdmitted => "jobs_admitted",
            CounterId::JobsCompleted => "jobs_completed",
            CounterId::JobsFailed => "jobs_failed",
            CounterId::Retries => "retries",
            CounterId::Launches => "launches",
            CounterId::CachedTasks => "cached_tasks",
            CounterId::JobsRouted => "jobs_routed",
        }
    }
}

/// Named latency histograms of the metrics registry (wire/dump names
/// via [`HistId::name`]; all record microseconds over
/// [`BUCKET_BOUNDS_US`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HistId {
    /// Job execution wall time (per attempt set, admission excluded).
    JobWall,
    /// Admission-to-pop queue wait.
    QueueWait,
    /// Memory-tier lookup latency.
    LookupMemory,
    /// Disk-tier lookup latency.
    LookupDisk,
    /// Remote-tier lookup latency (owner call + any replica peek).
    LookupRemote,
    /// One backend kernel launch (a batched call is one observation).
    Launch,
    /// One peer round trip (dial + exchange) on the cluster fabric.
    PeerRtt,
    /// Backoff slept before a retried attempt.
    RetryBackoff,
}

impl HistId {
    /// Every histogram, in wire order.
    pub const ALL: [HistId; 8] = [
        HistId::JobWall,
        HistId::QueueWait,
        HistId::LookupMemory,
        HistId::LookupDisk,
        HistId::LookupRemote,
        HistId::Launch,
        HistId::PeerRtt,
        HistId::RetryBackoff,
    ];

    /// The histogram's registry/wire name (`_us` marks the unit).
    pub fn name(self) -> &'static str {
        match self {
            HistId::JobWall => "job_wall_us",
            HistId::QueueWait => "queue_wait_us",
            HistId::LookupMemory => "lookup_memory_us",
            HistId::LookupDisk => "lookup_disk_us",
            HistId::LookupRemote => "lookup_remote_us",
            HistId::Launch => "launch_us",
            HistId::PeerRtt => "peer_rtt_us",
            HistId::RetryBackoff => "retry_backoff_us",
        }
    }

    /// The lookup histogram for a cache tier name
    /// ([`crate::cache::CacheTier::name`]); unknown tiers record as
    /// remote (every non-disk lower tier bills as remote today).
    pub fn lookup_for_tier(tier: &str) -> HistId {
        match tier {
            "memory" => HistId::LookupMemory,
            "disk" => HistId::LookupDisk,
            _ => HistId::LookupRemote,
        }
    }

    fn index(self) -> usize {
        HistId::ALL.iter().position(|h| *h == self).expect("every histogram is registered")
    }
}

/// The trace context one job carries through the stack: which trace its
/// spans belong to, which span new child spans parent to, and the
/// tenant/job labels spans and scoped metrics are stamped with. Cheap
/// to clone (one `Arc` bump); the service builds one per job attempt
/// and the engine/cache layers thread it via
/// [`crate::cache::CacheCtx`].
#[derive(Clone, Debug, PartialEq)]
pub struct SpanCtx {
    /// 128-bit trace id (nonzero for real traces).
    pub trace: u128,
    /// Span id new children parent to.
    pub parent: u64,
    /// Tenant label for span events and scoped metrics.
    pub tenant: Arc<str>,
    /// Job id as billed (the executing node's local id).
    pub job: u64,
}

impl SpanCtx {
    /// A child context: same trace/tenant/job, parenting to `span`.
    pub fn child(&self, span: u64) -> SpanCtx {
        SpanCtx { parent: span, ..self.clone() }
    }
}

/// One span, as buffered in the ring and written to the trace sink.
/// `start_us` is a monotonic offset from the emitting node's epoch —
/// meaningful for ordering *within* a node, never compared across
/// nodes (the tree structure carries the cross-node relation).
#[derive(Clone, Debug, PartialEq)]
pub struct SpanEvent {
    pub trace: u128,
    pub span: u64,
    /// Parent span id; `None` marks a trace root. A parent emitted by
    /// another node is fine — stitching is by (trace, span id).
    pub parent: Option<u64>,
    /// One of the [`span`] kind names.
    pub kind: &'static str,
    pub job: u64,
    pub tenant: String,
    /// Monotonic start offset from the node's epoch, microseconds.
    pub start_us: u64,
    pub dur_us: u64,
    /// Kind-specific detail (tier name, task name, level index, ...).
    pub detail: String,
}

/// Serialize one event as its JSONL trace line (no trailing newline).
pub fn event_json(ev: &SpanEvent, node: &str) -> String {
    let mut fields = vec![
        ("trace", Json::Str(format!("{:032x}", ev.trace))),
        ("span", Json::Str(format!("{:016x}", ev.span))),
        ("kind", Json::Str(ev.kind.to_string())),
        ("job", Json::Num(ev.job as f64)),
        ("tenant", Json::Str(ev.tenant.clone())),
        ("node", Json::Str(node.to_string())),
        ("start_us", Json::Num(ev.start_us as f64)),
        ("dur_us", Json::Num(ev.dur_us as f64)),
        ("detail", Json::Str(ev.detail.clone())),
    ];
    if let Some(p) = ev.parent {
        fields.push(("parent", Json::Str(format!("{p:016x}"))));
    }
    obj(fields).to_string_compact()
}

/// One parsed trace line: the event plus the node that emitted it.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceLine {
    pub event: SpanEvent,
    pub node: String,
}

/// Parse one JSONL trace line (the inverse of [`event_json`]).
pub fn parse_event(line: &str) -> Result<TraceLine> {
    let bad = |what: &str| Error::Json(format!("trace line: {what}"));
    let json = Json::parse(line).map_err(|e| Error::Json(format!("trace line: {e}")))?;
    let hexfield = |key: &str| -> Result<u128> {
        let s = json
            .get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| bad(&format!("missing hex field `{key}`")))?;
        u128::from_str_radix(s, 16).map_err(|_| bad(&format!("field `{key}` is not hex")))
    };
    let num = |key: &str| -> Result<u64> {
        json.get(key)
            .and_then(Json::as_f64)
            .filter(|n| *n >= 0.0)
            .map(|n| n as u64)
            .ok_or_else(|| bad(&format!("missing numeric field `{key}`")))
    };
    let text = |key: &str| -> Result<String> {
        json.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| bad(&format!("missing string field `{key}`")))
    };
    let parent = match json.get("parent") {
        None | Some(Json::Null) => None,
        Some(_) => Some(hexfield("parent")? as u64),
    };
    let kind_name = text("kind")?;
    let kind = [
        span::JOB,
        span::ADMIT,
        span::QUEUE,
        span::SCHEDULE,
        span::LEVEL,
        span::LOOKUP,
        span::LAUNCH,
        span::RETRY,
        span::DRAIN,
        span::ROUTE,
        span::SERVE_GET,
        span::SERVE_PUT,
    ]
    .into_iter()
    .find(|k| *k == kind_name)
    .ok_or_else(|| bad(&format!("unknown span kind `{kind_name}`")))?;
    Ok(TraceLine {
        node: text("node")?,
        event: SpanEvent {
            trace: hexfield("trace")?,
            span: hexfield("span")? as u64,
            parent,
            kind,
            job: num("job")?,
            tenant: text("tenant")?,
            start_us: num("start_us")?,
            dur_us: num("dur_us")?,
            detail: text("detail")?,
        },
    })
}

/// One fixed-bucket latency histogram: atomic bucket counts over
/// [`BUCKET_BOUNDS_US`] plus an overflow bucket, with running sum and
/// count (all `Relaxed` — a snapshot is a statistical read, not a
/// synchronization point).
#[derive(Debug)]
struct Hist {
    counts: [AtomicU64; BUCKET_BOUNDS_US.len() + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Hist {
    fn new() -> Self {
        Self {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn observe(&self, us: u64) {
        let b = BUCKET_BOUNDS_US.iter().position(|&lim| us <= lim).unwrap_or(BUCKET_BOUNDS_US.len());
        self.counts[b].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self, id: HistId) -> HistSnapshot {
        HistSnapshot {
            name: id.name().to_string(),
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// One registry (the global one, or one tenant's mirror).
#[derive(Debug)]
struct Metrics {
    counters: [AtomicU64; CounterId::ALL.len()],
    hists: [Hist; HistId::ALL.len()],
}

impl Metrics {
    fn new() -> Self {
        Self {
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            hists: std::array::from_fn(|_| Hist::new()),
        }
    }

    fn add(&self, c: CounterId, n: u64) {
        let i = CounterId::ALL.iter().position(|x| *x == c).expect("registered counter");
        self.counters[i].fetch_add(n, Ordering::Relaxed);
    }

    fn observe(&self, h: HistId, us: u64) {
        self.hists[h.index()].observe(us);
    }

    fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            counters: CounterId::ALL
                .iter()
                .zip(&self.counters)
                .map(|(id, c)| (id.name().to_string(), c.load(Ordering::Relaxed)))
                .collect(),
            hists: HistId::ALL.iter().map(|id| self.hists[id.index()].snapshot(*id)).collect(),
        }
    }
}

/// Point-in-time copy of one histogram (snapshot/wire form).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    pub name: String,
    /// Bucket counts: one per [`BUCKET_BOUNDS_US`] bound, plus the
    /// final +Inf overflow bucket.
    pub counts: Vec<u64>,
    pub sum_us: u64,
    pub count: u64,
}

impl HistSnapshot {
    /// Approximate quantile (0..=1) from the bucket counts: the upper
    /// bound of the bucket holding the q-th observation (the overflow
    /// bucket reports the largest finite bound). `None` when empty.
    pub fn quantile_us(&self, q: f64) -> Option<u64> {
        if self.count == 0 {
            return None;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, n) in self.counts.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return Some(*BUCKET_BOUNDS_US.get(i).unwrap_or(BUCKET_BOUNDS_US.last().unwrap()));
            }
        }
        Some(*BUCKET_BOUNDS_US.last().unwrap())
    }
}

/// Point-in-time copy of one registry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// `(name, value)` in [`CounterId::ALL`] order.
    pub counters: Vec<(String, u64)>,
    /// One row per [`HistId::ALL`] entry.
    pub hists: Vec<HistSnapshot>,
}

impl MetricsSnapshot {
    /// A counter by name (0 when absent — snapshots from older peers
    /// may carry fewer counters).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    /// A histogram row by name.
    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }
}

/// The full point-in-time telemetry snapshot (metrics + ring state);
/// the payload of the `stats` wire message, per-tier cache stats ride
/// beside it.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ObsSnapshot {
    /// The emitting node's label (cluster address, or `local`).
    pub node: String,
    pub global: MetricsSnapshot,
    /// Per-tenant mirrors, sorted by tenant name. Each field sums to
    /// the global across tenants for tenant-attributed recordings.
    pub tenants: Vec<(String, MetricsSnapshot)>,
    pub ring_len: u64,
    pub ring_cap: u64,
    /// Events dropped by ring overflow (the trace sink, when
    /// configured, still received them).
    pub ring_dropped: u64,
}

struct Ring {
    buf: VecDeque<SpanEvent>,
    dropped: u64,
}

/// The active telemetry state behind an [`Obs`] handle.
pub struct ObsInner {
    node: String,
    epoch: Instant,
    seed: u64,
    span_ids: AtomicU64,
    trace_ids: AtomicU64,
    ring: Mutex<Ring>,
    sink: Option<Mutex<BufWriter<File>>>,
    global: Metrics,
    tenants: Mutex<BTreeMap<String, Arc<Metrics>>>,
}

fn mix64(mut x: u64) -> u64 {
    // splitmix64 finalizer: full-period bijection, good avalanche
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

impl ObsInner {
    fn new(node: &str, sink: Option<BufWriter<File>>) -> Self {
        // trace-id entropy: process + node + boot wall clock. This is
        // identity material, not a latency measurement — the monotonic
        // epoch below is what every duration is measured against.
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::SystemTime::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let mut seed = mix64(nanos ^ u64::from(std::process::id()));
        for b in node.as_bytes() {
            seed = mix64(seed ^ u64::from(*b));
        }
        Self {
            node: node.to_string(),
            epoch: Instant::now(),
            seed,
            span_ids: AtomicU64::new(0),
            trace_ids: AtomicU64::new(0),
            ring: Mutex::new(Ring { buf: VecDeque::new(), dropped: 0 }),
            sink: sink.map(Mutex::new),
            global: Metrics::new(),
            tenants: Mutex::new(BTreeMap::new()),
        }
    }

    /// This node's label, stamped on every emitted trace line.
    pub fn node(&self) -> &str {
        &self.node
    }

    /// Allocate a node-unique span id (salted, so two nodes' ids do not
    /// collide within a trace except with negligible probability).
    pub fn next_span(&self) -> u64 {
        let n = self.span_ids.fetch_add(1, Ordering::Relaxed) + 1;
        mix64(self.seed ^ n) | 1
    }

    /// Allocate a fresh 128-bit trace id (never zero).
    pub fn new_trace(&self) -> u128 {
        let n = self.trace_ids.fetch_add(1, Ordering::Relaxed) + 1;
        let hi = mix64(self.seed.rotate_left(17) ^ n);
        let lo = mix64(hi ^ n.rotate_left(32));
        (u128::from(hi) << 64) | u128::from(lo) | 1
    }

    /// Microseconds since this node's telemetry epoch (monotonic).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Buffer one span event and append it to the trace sink.
    pub fn emit(&self, ev: SpanEvent) {
        if let Some(sink) = &self.sink {
            let line = event_json(&ev, &self.node);
            let mut w = sink.lock().unwrap();
            // a full disk must never fail a job: drop the line
            let _ = writeln!(w, "{line}");
        }
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() >= RING_CAP {
            ring.buf.pop_front();
            ring.dropped += 1;
        }
        ring.buf.push_back(ev);
    }

    /// Emit a span timed against the node epoch: `started` is the
    /// span's start instant, `dur` its duration.
    pub fn emit_timed(
        &self,
        ctx: &SpanCtx,
        kind: &'static str,
        span_id: u64,
        started: Instant,
        dur: Duration,
        detail: String,
    ) {
        let start_us = self
            .now_us()
            .saturating_sub(started.elapsed().as_micros() as u64);
        self.emit(SpanEvent {
            trace: ctx.trace,
            span: span_id,
            parent: (ctx.parent != 0).then_some(ctx.parent),
            kind,
            job: ctx.job,
            tenant: ctx.tenant.to_string(),
            start_us,
            dur_us: dur.as_micros() as u64,
            detail,
        });
    }

    fn tenant_metrics(&self, tenant: &str) -> Arc<Metrics> {
        let mut map = self.tenants.lock().unwrap();
        Arc::clone(map.entry(tenant.to_string()).or_insert_with(|| Arc::new(Metrics::new())))
    }

    /// Bump a counter, globally and (when labeled) for the tenant.
    pub fn add(&self, c: CounterId, tenant: Option<&str>, n: u64) {
        self.global.add(c, n);
        if let Some(t) = tenant {
            self.tenant_metrics(t).add(c, n);
        }
    }

    /// Record a latency observation, globally and (when labeled) for
    /// the tenant.
    pub fn observe(&self, h: HistId, tenant: Option<&str>, d: Duration) {
        let us = d.as_micros() as u64;
        self.global.observe(h, us);
        if let Some(t) = tenant {
            self.tenant_metrics(t).observe(h, us);
        }
    }

    /// Flush the trace sink (drain path; also called on drop).
    pub fn flush(&self) {
        if let Some(sink) = &self.sink {
            let _ = sink.lock().unwrap().flush();
        }
    }

    /// Copy of the buffered ring events, oldest first.
    pub fn ring_events(&self) -> Vec<SpanEvent> {
        self.ring.lock().unwrap().buf.iter().cloned().collect()
    }

    /// The point-in-time snapshot behind every stats surface.
    pub fn snapshot(&self) -> ObsSnapshot {
        let ring = self.ring.lock().unwrap();
        let tenants = self.tenants.lock().unwrap();
        ObsSnapshot {
            node: self.node.clone(),
            global: self.global.snapshot(),
            tenants: tenants.iter().map(|(t, m)| (t.clone(), m.snapshot())).collect(),
            ring_len: ring.buf.len() as u64,
            ring_cap: RING_CAP as u64,
            ring_dropped: ring.dropped,
        }
    }
}

impl Drop for ObsInner {
    fn drop(&mut self) {
        if let Some(sink) = &self.sink {
            let _ = sink.lock().unwrap().flush();
        }
    }
}

impl fmt::Debug for ObsInner {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ObsInner")
            .field("node", &self.node)
            .field("sink", &self.sink.is_some())
            .finish_non_exhaustive()
    }
}

/// The telemetry handle every layer carries (engine, cache stack,
/// remote tier, service, server). Mirrors [`crate::faults::Faults`]:
/// `Obs::none()` — the default — is a `None` and costs one never-taken
/// branch per recording site; an active handle shares one
/// [`ObsInner`] process-wide.
#[derive(Clone, Default)]
pub struct Obs(Option<Arc<ObsInner>>);

impl Obs {
    /// Telemetry off (the default): every recording call is one
    /// `Option` test.
    pub fn none() -> Self {
        Obs(None)
    }

    /// Telemetry on, ring buffer + metrics only (no trace sink).
    /// `node` labels emitted events (the cluster address, or `local`).
    pub fn active(node: &str) -> Self {
        Obs(Some(Arc::new(ObsInner::new(node, None))))
    }

    /// Telemetry on with a JSONL trace sink appended to `path`
    /// (the `trace=FILE` serve flag).
    pub fn to_file(node: &str, path: impl AsRef<Path>) -> Result<Self> {
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path.as_ref())
            .map_err(Error::Io)?;
        Ok(Obs(Some(Arc::new(ObsInner::new(node, Some(BufWriter::new(file)))))))
    }

    /// The active state, if telemetry is on. Callers needing more than
    /// a counter/histogram bump guard on this — exactly the
    /// [`crate::faults::Faults::get`] idiom — so the off path never
    /// allocates span details.
    pub fn get(&self) -> Option<&Arc<ObsInner>> {
        self.0.as_ref()
    }

    /// Is telemetry on?
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }

    /// Bump a counter (no-op when off).
    pub fn add(&self, c: CounterId, tenant: Option<&str>, n: u64) {
        if let Some(inner) = &self.0 {
            inner.add(c, tenant, n);
        }
    }

    /// Record a latency observation (no-op when off).
    pub fn observe(&self, h: HistId, tenant: Option<&str>, d: Duration) {
        if let Some(inner) = &self.0 {
            inner.observe(h, tenant, d);
        }
    }
}

/// Handles compare by activeness (the inner state is shared mutable
/// telemetry, not a value) — the same convention as
/// [`crate::faults::Faults`], and what lets every config struct
/// carrying an `Obs` stay `PartialEq`.
impl PartialEq for Obs {
    fn eq(&self, other: &Self) -> bool {
        self.is_active() == other.is_active()
    }
}

impl fmt::Debug for Obs {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Obs({})", if self.is_active() { "on" } else { "off" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(inner: &ObsInner) -> SpanCtx {
        SpanCtx { trace: inner.new_trace(), parent: 0, tenant: Arc::from("alice"), job: 7 }
    }

    #[test]
    fn an_inactive_handle_records_nothing_and_compares_by_activeness() {
        let off = Obs::none();
        assert!(!off.is_active());
        assert!(off.get().is_none());
        off.add(CounterId::Launches, Some("alice"), 3);
        off.observe(HistId::Launch, None, Duration::from_millis(1));
        assert_eq!(off, Obs::default());
        assert_ne!(off, Obs::active("local"));
        assert_eq!(format!("{off:?}"), "Obs(off)");
        assert_eq!(format!("{:?}", Obs::active("local")), "Obs(on)");
    }

    #[test]
    fn tenant_scoped_metrics_sum_exactly_to_the_globals() {
        let obs = Obs::active("local");
        obs.add(CounterId::Launches, Some("alice"), 5);
        obs.add(CounterId::Launches, Some("bob"), 7);
        obs.observe(HistId::JobWall, Some("alice"), Duration::from_millis(3));
        obs.observe(HistId::JobWall, Some("bob"), Duration::from_micros(80));
        let snap = obs.get().unwrap().snapshot();
        assert_eq!(snap.global.counter("launches"), 12);
        let by_tenant: u64 =
            snap.tenants.iter().map(|(_, m)| m.counter("launches")).sum();
        assert_eq!(by_tenant, snap.global.counter("launches"));
        let g = snap.global.hist("job_wall_us").unwrap();
        assert_eq!(g.count, 2);
        assert_eq!(g.sum_us, 3000 + 80);
        let tenant_counts: u64 = snap
            .tenants
            .iter()
            .filter_map(|(_, m)| m.hist("job_wall_us"))
            .map(|h| h.count)
            .sum();
        assert_eq!(tenant_counts, g.count, "histogram counts partition by tenant");
        for (i, &n) in g.counts.iter().enumerate() {
            let t: u64 = snap
                .tenants
                .iter()
                .filter_map(|(_, m)| m.hist("job_wall_us"))
                .map(|h| h.counts[i])
                .sum();
            assert_eq!(t, n, "bucket {i} partitions by tenant");
        }
    }

    #[test]
    fn histogram_buckets_and_quantiles_follow_the_fixed_bounds() {
        let obs = Obs::active("local");
        // 40us -> bucket 0 (<=50), 80us -> bucket 1 (<=100),
        // 20s -> overflow bucket
        for us in [40u64, 80, 20_000_000] {
            obs.observe(HistId::PeerRtt, None, Duration::from_micros(us));
        }
        let snap = obs.get().unwrap().snapshot();
        let h = snap.global.hist("peer_rtt_us").unwrap();
        assert_eq!(h.counts.len(), BUCKET_BOUNDS_US.len() + 1);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[1], 1);
        assert_eq!(*h.counts.last().unwrap(), 1, "20s lands in the overflow bucket");
        assert_eq!(h.quantile_us(0.0), Some(50));
        assert_eq!(h.quantile_us(0.5), Some(100));
        assert_eq!(h.quantile_us(1.0), Some(*BUCKET_BOUNDS_US.last().unwrap()));
        assert_eq!(HistSnapshot::default().quantile_us(0.5), None);
    }

    #[test]
    fn span_events_roundtrip_through_the_jsonl_codec() {
        let ev = SpanEvent {
            trace: 0xdead_beef_0000_0000_0000_0000_0000_0001,
            span: 0x1234,
            parent: Some(0x99),
            kind: span::LAUNCH,
            job: 42,
            tenant: "alice".into(),
            start_us: 1_000,
            dur_us: 250,
            detail: "t3 x4".into(),
        };
        let line = event_json(&ev, "127.0.0.1:4101");
        let back = parse_event(&line).expect("line parses");
        assert_eq!(back.event, ev);
        assert_eq!(back.node, "127.0.0.1:4101");

        let root = SpanEvent { parent: None, kind: span::JOB, ..ev };
        let back = parse_event(&event_json(&root, "n")).expect("root parses");
        assert_eq!(back.event.parent, None, "absent parent reads as a root");

        assert!(parse_event("not json").is_err());
        assert!(
            parse_event("{\"trace\":\"1\",\"span\":\"1\",\"kind\":\"gossip\"}").is_err(),
            "unknown span kinds are rejected"
        );
    }

    #[test]
    fn the_ring_is_bounded_and_counts_drops() {
        let obs = Obs::active("local");
        let inner = obs.get().unwrap();
        let c = ctx(inner);
        for i in 0..(RING_CAP as u64 + 10) {
            inner.emit(SpanEvent {
                trace: c.trace,
                span: i + 1,
                parent: None,
                kind: span::LAUNCH,
                job: 7,
                tenant: "alice".into(),
                start_us: i,
                dur_us: 1,
                detail: String::new(),
            });
        }
        let snap = inner.snapshot();
        assert_eq!(snap.ring_len, RING_CAP as u64);
        assert_eq!(snap.ring_dropped, 10);
        let events = inner.ring_events();
        assert_eq!(events.len(), RING_CAP);
        assert_eq!(events[0].span, 11, "the oldest events were dropped");
    }

    #[test]
    fn trace_and_span_ids_are_unique_and_nonzero() {
        let obs = Obs::active("local");
        let inner = obs.get().unwrap();
        let mut traces = std::collections::HashSet::new();
        let mut spans = std::collections::HashSet::new();
        for _ in 0..1000 {
            let t = inner.new_trace();
            let s = inner.next_span();
            assert_ne!(t, 0);
            assert_ne!(s, 0);
            assert!(traces.insert(t), "trace ids must not repeat");
            assert!(spans.insert(s), "span ids must not repeat");
        }
    }

    #[test]
    fn the_file_sink_writes_parsable_jsonl() {
        let dir = std::env::temp_dir().join(format!("obs_sink_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let _ = std::fs::remove_file(&path);
        let obs = Obs::to_file("127.0.0.1:4101", &path).expect("sink opens");
        let inner = obs.get().unwrap();
        let c = ctx(inner);
        let root = inner.next_span();
        inner.emit_timed(&c, span::JOB, root, Instant::now(), Duration::from_millis(2), String::new());
        let child = c.child(root);
        inner.emit_timed(
            &child,
            span::LAUNCH,
            inner.next_span(),
            Instant::now(),
            Duration::from_micros(300),
            "t1".into(),
        );
        inner.flush();
        let text = std::fs::read_to_string(&path).expect("trace file exists");
        let lines: Vec<TraceLine> =
            text.lines().map(|l| parse_event(l).expect("line parses")).collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0].event.trace, c.trace);
        assert_eq!(lines[0].event.parent, None, "ctx parent 0 emits a root");
        assert_eq!(lines[1].event.parent, Some(root), "child links to the root");
        assert_eq!(lines[1].event.kind, span::LAUNCH);
        assert!(lines.iter().all(|l| l.node == "127.0.0.1:4101"));
        let _ = std::fs::remove_file(&path);
    }
}
