//! Deterministic fault injection for the engine, the cache tiers, and
//! the wire server.
//!
//! Failure is the common case at scale: workers panic mid-unit, disk
//! writes tear when a process dies, peers flap, frames corrupt in
//! transit. This module makes each of those a *scripted, reproducible
//! input* instead of an accident, so the self-healing paths (job
//! retries, the per-peer circuit breaker, disk quarantine, bounded
//! flight waits) are exercised by ordinary deterministic tests —
//! `tests/chaos.rs` is the capstone consumer.
//!
//! # Design
//!
//! The injection points implement one trait, [`FaultHook`], whose
//! methods all default to "no fault". Production code holds a
//! [`Faults`] handle (a cloneable `Option<Arc<dyn FaultHook>>`
//! newtype); the disabled handle is the default everywhere, and every
//! injection site guards on it with a single `Option` check — no
//! allocation, no locking, no syscall — so a fault-free build pays
//! nothing measurable (the frontier-batching bench is the acceptance
//! gate for that).
//!
//! [`FaultPlan`] is the scripted implementation: each injection *site*
//! (backend launch, disk store, peer call, outbound cache-state frame)
//! carries an atomic ordinal counter, and the plan maps 1-based
//! ordinals to events. Ordinals — not wall-clock, not randomness —
//! make a plan deterministic under any thread interleaving *of the
//! site itself*: the Nth disk store fails no matter which worker
//! performs it. Plans are built with the builder methods, then frozen
//! behind an `Arc`; only the atomics mutate afterwards.
//!
//! ```
//! use rtf_reuse::faults::{DiskFault, FaultHook, FaultPlan, Faults};
//! use std::sync::Arc;
//!
//! let plan = Arc::new(FaultPlan::new().panic_on_launch(2).disk_fault(1, DiskFault::IoError));
//! let faults = Faults::hooked(plan.clone());
//! let hook = faults.get().unwrap();
//! assert!(hook.on_launch().is_none(), "launch #1 passes");
//! assert!(hook.on_launch().is_some(), "launch #2 panics");
//! assert_eq!(hook.on_disk_store(), Some(DiskFault::IoError));
//! assert_eq!(plan.fired().launch_panics, 1);
//! ```

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// What a scripted disk-store fault does to the write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiskFault {
    /// The store fails outright with an I/O error (disk full, EIO).
    /// The tier reports the store as not-performed; nothing persists.
    IoError,
    /// The write tears: a truncated payload reaches the final file
    /// name, as if the process died between write and a (skipped)
    /// fsync. The tier's checksum must catch this on the next lookup.
    ShortWrite,
}

/// What a scripted peer-call fault does to the connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerFault {
    /// The dial is refused / the pooled connection is dead. The call
    /// fails before any bytes move.
    Refuse,
    /// The connection drops mid-exchange (after the request is sent,
    /// before the reply arrives).
    Drop,
    /// Added network latency before the exchange proceeds normally.
    Delay(Duration),
}

/// The injection-point trait. Every method defaults to "no fault", so
/// an implementation only overrides the sites it scripts. Injection
/// sites call these *only when a hook is installed* — see [`Faults`].
pub trait FaultHook: Send + Sync {
    /// Consulted once per backend launch (before the kernels run).
    /// `Some(msg)` makes the engine panic with that message — the
    /// worker-panic failure mode.
    fn on_launch(&self) -> Option<String> {
        None
    }

    /// Consulted once per disk-tier store attempt.
    fn on_disk_store(&self) -> Option<DiskFault> {
        None
    }

    /// Consulted once per remote-tier call; `peer` is the target
    /// address (informational — ordinals script the schedule).
    fn on_peer_call(&self, peer: &str) -> Option<PeerFault> {
        let _ = peer;
        None
    }

    /// Consulted once per outbound `cache-state` reply frame on the
    /// wire server; `true` corrupts that frame's body.
    fn on_frame_out(&self) -> bool {
        false
    }
}

/// A cloneable, comparable handle to an optional [`FaultHook`] — the
/// form fault injection takes in configuration structs. The default
/// ([`Faults::none`]) is inert; every injection site reduces to one
/// `Option` check.
///
/// Equality compares *activeness* only (hooked vs not), because
/// configs that derive `PartialEq` cannot compare trait objects — and
/// two configs differing only in which plan they carry are, for
/// config-equality purposes, both "a faulted config".
#[derive(Clone, Default)]
pub struct Faults(Option<Arc<dyn FaultHook>>);

impl Faults {
    /// The inert handle: no hook, no faults, no overhead.
    pub fn none() -> Self {
        Faults(None)
    }

    /// A handle carrying the given hook.
    pub fn hooked(hook: Arc<dyn FaultHook>) -> Self {
        Faults(Some(hook))
    }

    /// The installed hook, if any — the single guard every injection
    /// site branches on.
    pub fn get(&self) -> Option<&Arc<dyn FaultHook>> {
        self.0.as_ref()
    }

    /// Whether a hook is installed.
    pub fn is_active(&self) -> bool {
        self.0.is_some()
    }
}

impl fmt::Debug for Faults {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Faults({})", if self.is_active() { "on" } else { "off" })
    }
}

impl PartialEq for Faults {
    fn eq(&self, other: &Self) -> bool {
        self.is_active() == other.is_active()
    }
}

/// How many scripted events each site has actually fired — the test
/// assertion that a chaos plan *exercised* what it scripted, not just
/// scheduled it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FiredCounts {
    /// Launch panics delivered by [`FaultHook::on_launch`].
    pub launch_panics: u64,
    /// Disk faults delivered by [`FaultHook::on_disk_store`].
    pub disk_faults: u64,
    /// Peer faults delivered by [`FaultHook::on_peer_call`].
    pub peer_faults: u64,
    /// Frames corrupted by [`FaultHook::on_frame_out`].
    pub frames_corrupted: u64,
}

/// A deterministic scripted fault plan: per-site atomic ordinal
/// counters plus maps from 1-based ordinals to events. Build with the
/// consuming builder methods, freeze behind an `Arc`, install via
/// [`Faults::hooked`]. See the module docs for the determinism
/// argument.
#[derive(Debug, Default)]
pub struct FaultPlan {
    panics: BTreeSet<u64>,
    disk: BTreeMap<u64, DiskFault>,
    peer: BTreeMap<u64, PeerFault>,
    frames: BTreeSet<u64>,
    launch_seen: AtomicU64,
    disk_seen: AtomicU64,
    peer_seen: AtomicU64,
    frame_seen: AtomicU64,
    launch_fired: AtomicU64,
    disk_fired: AtomicU64,
    peer_fired: AtomicU64,
    frame_fired: AtomicU64,
}

impl FaultPlan {
    /// An empty plan (injects nothing until scripted).
    pub fn new() -> Self {
        Self::default()
    }

    /// Script a panic on the `n`th backend launch (1-based).
    pub fn panic_on_launch(mut self, n: u64) -> Self {
        self.panics.insert(n);
        self
    }

    /// Script a fault on the `n`th disk store attempt (1-based).
    pub fn disk_fault(mut self, n: u64, fault: DiskFault) -> Self {
        self.disk.insert(n, fault);
        self
    }

    /// Script a fault on the `n`th remote-peer call (1-based).
    pub fn peer_fault(mut self, n: u64, fault: PeerFault) -> Self {
        self.peer.insert(n, fault);
        self
    }

    /// Script corruption of the `n`th outbound `cache-state` frame
    /// (1-based).
    pub fn corrupt_frame(mut self, n: u64) -> Self {
        self.frames.insert(n);
        self
    }

    /// How many events each site has fired so far.
    pub fn fired(&self) -> FiredCounts {
        FiredCounts {
            launch_panics: self.launch_fired.load(Ordering::SeqCst),
            disk_faults: self.disk_fired.load(Ordering::SeqCst),
            peer_faults: self.peer_fired.load(Ordering::SeqCst),
            frames_corrupted: self.frame_fired.load(Ordering::SeqCst),
        }
    }

    /// How many times each site has been *consulted* (fired or not) —
    /// useful when sizing ordinals for a new plan.
    pub fn seen(&self) -> FiredCounts {
        FiredCounts {
            launch_panics: self.launch_seen.load(Ordering::SeqCst),
            disk_faults: self.disk_seen.load(Ordering::SeqCst),
            peer_faults: self.peer_seen.load(Ordering::SeqCst),
            frames_corrupted: self.frame_seen.load(Ordering::SeqCst),
        }
    }
}

impl FaultHook for FaultPlan {
    fn on_launch(&self) -> Option<String> {
        let n = self.launch_seen.fetch_add(1, Ordering::SeqCst) + 1;
        if self.panics.contains(&n) {
            self.launch_fired.fetch_add(1, Ordering::SeqCst);
            Some(format!("fault injection: scripted panic on launch #{n}"))
        } else {
            None
        }
    }

    fn on_disk_store(&self) -> Option<DiskFault> {
        let n = self.disk_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let fault = self.disk.get(&n).copied();
        if fault.is_some() {
            self.disk_fired.fetch_add(1, Ordering::SeqCst);
        }
        fault
    }

    fn on_peer_call(&self, _peer: &str) -> Option<PeerFault> {
        let n = self.peer_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let fault = self.peer.get(&n).copied();
        if fault.is_some() {
            self.peer_fired.fetch_add(1, Ordering::SeqCst);
        }
        fault
    }

    fn on_frame_out(&self) -> bool {
        let n = self.frame_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let hit = self.frames.contains(&n);
        if hit {
            self.frame_fired.fetch_add(1, Ordering::SeqCst);
        }
        hit
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_hook_methods_inject_nothing() {
        struct Nop;
        impl FaultHook for Nop {}
        let nop = Nop;
        assert_eq!(nop.on_launch(), None);
        assert_eq!(nop.on_disk_store(), None);
        assert_eq!(nop.on_peer_call("127.0.0.1:1"), None);
        assert!(!nop.on_frame_out());
    }

    #[test]
    fn plan_fires_on_exact_ordinals_and_counts_what_fired() {
        let plan = FaultPlan::new()
            .panic_on_launch(2)
            .disk_fault(1, DiskFault::ShortWrite)
            .disk_fault(3, DiskFault::IoError)
            .peer_fault(2, PeerFault::Refuse)
            .corrupt_frame(1);
        assert_eq!(plan.on_launch(), None, "launch #1 clean");
        let msg = plan.on_launch().expect("launch #2 scripted");
        assert!(msg.contains("#2"), "panic message names the ordinal: {msg}");
        assert_eq!(plan.on_launch(), None, "launch #3 clean again");

        assert_eq!(plan.on_disk_store(), Some(DiskFault::ShortWrite));
        assert_eq!(plan.on_disk_store(), None);
        assert_eq!(plan.on_disk_store(), Some(DiskFault::IoError));

        assert_eq!(plan.on_peer_call("a"), None);
        assert_eq!(plan.on_peer_call("b"), Some(PeerFault::Refuse));

        assert!(plan.on_frame_out());
        assert!(!plan.on_frame_out());

        let fired = plan.fired();
        assert_eq!(
            fired,
            FiredCounts { launch_panics: 1, disk_faults: 2, peer_faults: 1, frames_corrupted: 1 }
        );
        let seen = plan.seen();
        assert_eq!(seen.launch_panics, 3, "three launches consulted");
        assert_eq!(seen.disk_faults, 3);
        assert_eq!(seen.peer_faults, 2);
        assert_eq!(seen.frames_corrupted, 2);
    }

    #[test]
    fn plan_is_deterministic_under_concurrent_consultation() {
        // 8 threads × 16 launches, panics scripted at 5 and 100 (the
        // second never reached): exactly one thread observes a panic
        // regardless of interleaving.
        let plan = Arc::new(FaultPlan::new().panic_on_launch(5).panic_on_launch(100));
        let hits: u64 = std::thread::scope(|s| {
            let handles: Vec<_> = (0..8)
                .map(|_| {
                    let plan = plan.clone();
                    s.spawn(move || {
                        (0..16).filter(|_| plan.on_launch().is_some()).count() as u64
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).sum()
        });
        assert_eq!(hits, 1, "ordinal 5 fires exactly once across threads");
        assert_eq!(plan.seen().launch_panics, 128);
    }

    #[test]
    fn faults_handle_compares_by_activeness_and_prints_state() {
        let off = Faults::none();
        let on = Faults::hooked(Arc::new(FaultPlan::new()));
        let also_on = Faults::hooked(Arc::new(FaultPlan::new().corrupt_frame(1)));
        assert_eq!(off, Faults::default());
        assert_ne!(off, on);
        assert_eq!(on, also_on, "two hooked handles compare equal");
        assert!(!off.is_active() && off.get().is_none());
        assert!(on.is_active() && on.get().is_some());
        assert_eq!(format!("{off:?}"), "Faults(off)");
        assert_eq!(format!("{on:?}"), "Faults(on)");
    }

    #[test]
    fn delay_fault_carries_its_duration() {
        let plan = FaultPlan::new().peer_fault(1, PeerFault::Delay(Duration::from_millis(7)));
        assert_eq!(plan.on_peer_call("x"), Some(PeerFault::Delay(Duration::from_millis(7))));
    }
}
