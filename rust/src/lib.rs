//! # rtf-reuse
//!
//! A Rust + JAX + Pallas reproduction of *Accelerating Sensitivity Analysis
//! in Microscopy Image Segmentation Workflows* (Barreiros & Teodoro, 2018).
//!
//! The crate implements the paper's **multi-level computation reuse** for
//! sensitivity-analysis (SA) studies on top of a Region-Templates-style
//! manager/worker runtime:
//!
//! * [`workflow`] — hierarchical workflow model: coarse-grain *stages*
//!   composed of fine-grain *tasks*, instantiated from JSON stage
//!   descriptors (paper Fig. 7) over the 15-parameter space of Table 1.
//! * [`sampling`] — the SA experiment generators: MOAT (Morris),
//!   VBD (Saltelli), plus Monte-Carlo / Latin-Hypercube / quasi-Monte-Carlo
//!   samplers analyzed in Table 4.
//! * [`merging`] — the paper's contribution: stage-level compact-graph
//!   merging (Alg. 1) and the fine-grain Naïve / SCA / RTMA / TRTMA
//!   task-level merging algorithms (Sec. 3.3).
//! * [`cache`] — the cross-study persistent reuse cache: content-
//!   addressed task memoization (tile fingerprint × quantized task-path
//!   prefix), sharded in-memory LRU with an optional disk tier.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas task
//!   artifacts (`artifacts/*.hlo.txt`); python never runs at request time.
//! * [`coordinator`] — demand-driven manager/worker execution of merged
//!   plans with per-worker task scheduling and dependency resolution.
//! * [`faults`] — deterministic, scripted fault injection (worker
//!   panics, torn disk writes, peer flap, frame corruption) behind a
//!   zero-cost-when-disabled hook, driving the self-healing paths
//!   (retries, circuit breaker, disk quarantine) in `tests/chaos.rs`.
//! * [`obs`] — end-to-end telemetry: 128-bit job traces with
//!   cross-node span stitching, named counters and fixed-bucket latency
//!   histograms with per-tenant scoping, behind a zero-cost-when-off
//!   handle (telemetry off is zero-cost; telemetry on never changes a
//!   result). `docs/OBSERVABILITY.md` is the operator guide.
//! * [`serve`] — the multi-tenant study service: one process-lifetime
//!   shared cache + engine serving many concurrent studies, with
//!   weighted-fair admission, per-tenant byte quotas and accounting,
//!   disk warm-start, graceful drain, and a TCP wire protocol
//!   (`docs/SERVING.md`) with an in-tree client.
//! * [`tune`] — parameter auto-tuning: Nelder-Mead and genetic
//!   optimizers that score candidate parameter sets by running them as
//!   batched studies, memoize revisited quantized points, and ride the
//!   shared reuse cache (a `tune` CLI mode and a serve job kind).
//! * [`simulate`] — discrete-event cluster simulator used for the
//!   8–256-worker scalability studies (Figs. 22/23, Table 5).
//! * [`analysis`] — elementary effects (MOAT) and Sobol indices (VBD),
//!   i.e. the numbers in Table 2.
//! * [`adaptive`] — run-time adaptive SA (the follow-up paper, arXiv
//!   1910.14548): streaming Morris/VBD estimators with confidence
//!   intervals, and an online pruner that cancels not-yet-launched
//!   evaluations once a parameter's CI shows it non-significant —
//!   every pruned unit billed distinctly, never silently dropped.
//! * [`data`] — region-template data abstraction and the synthetic tissue
//!   tile generator standing in for the paper's WSI dataset.
//!
//! See `ARCHITECTURE.md` (repository root) for the top-to-bottom tour —
//! data-flow diagram, life of a study, and the map from every paper
//! section/table to the module that reproduces it.

pub mod adaptive;
pub mod analysis;
pub mod benchx;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod driver;
pub mod error;
pub mod faults;
pub mod jsonx;
pub mod merging;
pub mod obs;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod simulate;
pub mod testutil;
pub mod tune;
pub mod workflow;

pub use error::{Error, Result};
