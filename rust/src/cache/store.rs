//! The sharded, byte-bounded, LRU reuse store.
//!
//! One [`ReuseCache`] is shared by every worker thread of a study — and,
//! crucially, by every *study* that runs while it lives. Lock contention
//! is kept off the hot path by sharding: keys map to one of N independent
//! mutex-protected shards, so concurrent workers almost always lock
//! disjoint shards. Each shard enforces its slice of the byte budget with
//! LRU eviction; with a disk tier configured, entries are written through
//! on insert, evictions become cheap drops, and lookups fall back to disk
//! before declaring a miss.

use std::collections::HashMap;
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::data::Plane;

use super::disk;

/// The 3-plane chain state the cache stores (same shape the coordinator's
/// node store moves between stages), refcount-shared: a cache hit hands
/// back an `Arc` clone — a refcount bump, not a ~3×H×W f32 deep copy —
/// and concurrent readers of the same entry share one allocation.
pub type CachedState = Arc<[Plane; 3]>;

/// Construction-time knobs (surfaced as `cache-*` study-config options).
#[derive(Clone, Debug, PartialEq)]
pub struct CacheConfig {
    /// In-memory ceiling over all shards, in bytes.
    pub capacity_bytes: usize,
    /// Number of independently locked shards.
    pub shards: usize,
    /// Parameter quantization step for key construction (0 = exact).
    pub quantize: f64,
    /// Optional persistent tier: write-through on insert, fallback on
    /// lookup.
    pub spill_dir: Option<PathBuf>,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            capacity_bytes: 256 * 1024 * 1024,
            shards: 8,
            quantize: 0.0,
            spill_dir: None,
        }
    }
}

/// A point-in-time snapshot of the cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// State lookups served from memory.
    pub hits: u64,
    /// State lookups served from the disk tier.
    pub disk_hits: u64,
    /// State lookups that found nothing.
    pub misses: u64,
    /// States newly published (first-time keys; approximate when several
    /// workers publish the same key simultaneously).
    pub inserts: u64,
    /// Entries evicted from memory by the byte bound.
    pub evictions: u64,
    /// Entries written to the disk tier.
    pub spilled: u64,
    /// Metric lookups served / missed.
    pub metric_hits: u64,
    pub metric_misses: u64,
    /// Current and high-water resident bytes.
    pub resident_bytes: u64,
    pub peak_bytes: u64,
}

impl CacheStats {
    /// Fraction of state lookups served from any tier.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.disk_hits + self.misses;
        if total == 0 {
            0.0
        } else {
            (self.hits + self.disk_hits) as f64 / total as f64
        }
    }

    /// `TaskTimer`-style counter rows for study reports.
    pub fn summary(&self) -> Vec<(String, u64)> {
        vec![
            ("cache.hits".into(), self.hits),
            ("cache.disk_hits".into(), self.disk_hits),
            ("cache.misses".into(), self.misses),
            ("cache.inserts".into(), self.inserts),
            ("cache.evictions".into(), self.evictions),
            ("cache.spilled".into(), self.spilled),
            ("cache.metric_hits".into(), self.metric_hits),
            ("cache.metric_misses".into(), self.metric_misses),
            ("cache.resident_bytes".into(), self.resident_bytes),
            ("cache.peak_bytes".into(), self.peak_bytes),
        ]
    }
}

struct Entry {
    state: CachedState,
    bytes: usize,
    tick: u64,
}

#[derive(Default)]
struct Shard {
    map: HashMap<u64, Entry>,
    bytes: usize,
}

/// The cross-study, content-addressed reuse cache.
pub struct ReuseCache {
    cfg: CacheConfig,
    shards: Vec<Mutex<Shard>>,
    metrics: Mutex<HashMap<u64, [f32; 3]>>,
    tick: AtomicU64,
    hits: AtomicU64,
    disk_hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    evictions: AtomicU64,
    spilled: AtomicU64,
    metric_hits: AtomicU64,
    metric_misses: AtomicU64,
    resident: AtomicU64,
    peak: AtomicU64,
}

impl fmt::Debug for ReuseCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ReuseCache")
            .field("cfg", &self.cfg)
            .field("stats", &self.stats())
            .finish()
    }
}

impl ReuseCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let n = cfg.shards.max(1);
        Self {
            cfg,
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            metrics: Mutex::new(HashMap::new()),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            metric_hits: AtomicU64::new(0),
            metric_misses: AtomicU64::new(0),
            resident: AtomicU64::new(0),
            peak: AtomicU64::new(0),
        }
    }

    /// An in-memory cache with the given byte budget and defaults
    /// elsewhere.
    pub fn with_capacity(capacity_bytes: usize) -> Self {
        Self::new(CacheConfig { capacity_bytes, ..CacheConfig::default() })
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// The parameter quantization step keys are built with.
    pub fn quantize_step(&self) -> f64 {
        self.cfg.quantize
    }

    fn shard_of(&self, key: u64) -> &Mutex<Shard> {
        let i = ((key ^ (key >> 32)) as usize) % self.shards.len();
        &self.shards[i]
    }

    fn per_shard_budget(&self) -> usize {
        self.cfg.capacity_bytes / self.shards.len()
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Look up the state for `key`: memory first, then the disk tier.
    /// A memory hit is a refcount bump (the returned `Arc` shares the
    /// resident allocation); a disk hit is promoted back into memory.
    pub fn get_state(&self, key: u64) -> Option<CachedState> {
        {
            let mut s = self.shard_of(key).lock().unwrap();
            if let Some(e) = s.map.get_mut(&key) {
                e.tick = self.next_tick();
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Some(Arc::clone(&e.state));
            }
        }
        if let Some(dir) = &self.cfg.spill_dir {
            if let Some(state) = disk::load_state(dir, key) {
                let state: CachedState = Arc::new(state);
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                self.insert_resident(key, Arc::clone(&state));
                return Some(state);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Count a state hit that was served outside [`ReuseCache::get_state`]
    /// — the batched executor serving a lane from a sibling lane's
    /// just-computed result records it here, exactly as the sequential
    /// path's lookup-after-publication would have counted a hit.
    pub fn note_state_hit(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }

    /// Probe without fetching (planning-time check): true when the key is
    /// resident in memory or present on disk. Does not touch LRU order or
    /// the hit/miss counters.
    pub fn contains_state(&self, key: u64) -> bool {
        if self.shard_of(key).lock().unwrap().map.contains_key(&key) {
            return true;
        }
        match &self.cfg.spill_dir {
            Some(dir) => disk::has_state(dir, key),
            None => false,
        }
    }

    /// Publish a state under `key` (anything convertible into the
    /// refcounted [`CachedState`]; a plain `[Plane; 3]` wraps into a
    /// fresh `Arc`). With a disk tier the entry is written through
    /// immediately; the in-memory copy is subject to LRU. The `inserts`
    /// counter tracks newly published keys (approximate under concurrent
    /// duplicate publication of the same key).
    pub fn put_state(&self, key: u64, state: impl Into<CachedState>) {
        let state = state.into();
        let mut new_on_disk = false;
        if let Some(dir) = &self.cfg.spill_dir {
            if let Ok(true) = disk::store_state(dir, key, &state) {
                self.spilled.fetch_add(1, Ordering::Relaxed);
                new_on_disk = true;
            }
        }
        if self.insert_resident(key, state) || new_on_disk {
            self.inserts.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Returns true when `key` was newly added to the resident map.
    fn insert_resident(&self, key: u64, state: CachedState) -> bool {
        let bytes: usize = state.iter().map(Plane::nbytes).sum();
        let budget = self.per_shard_budget();
        if bytes > budget {
            return false; // larger than a whole shard: disk-only (if configured)
        }
        let tick = self.next_tick();
        let mut s = self.shard_of(key).lock().unwrap();
        if let Some(e) = s.map.get_mut(&key) {
            e.tick = tick;
            return false;
        }
        s.map.insert(key, Entry { state, bytes, tick });
        s.bytes += bytes;
        let mut freed = 0u64;
        while s.bytes > budget {
            // LRU victim: smallest tick. Shard maps stay small enough
            // (budget / state size) that a scan beats maintaining an
            // ordered index under the lock.
            let victim = s
                .map
                .iter()
                .filter(|(&k, _)| k != key)
                .min_by_key(|(_, e)| e.tick)
                .map(|(&k, _)| k);
            match victim {
                Some(v) => {
                    if let Some(e) = s.map.remove(&v) {
                        s.bytes -= e.bytes;
                        freed += e.bytes as u64;
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                None => break,
            }
        }
        let grown = bytes as u64;
        let now = self.resident.fetch_add(grown, Ordering::Relaxed) + grown;
        self.resident.fetch_sub(freed, Ordering::Relaxed);
        self.peak.fetch_max(now.saturating_sub(freed), Ordering::Relaxed);
        true
    }

    /// Look up cached comparison metrics.
    pub fn get_metrics(&self, key: u64) -> Option<[f32; 3]> {
        let m = self.metrics.lock().unwrap();
        match m.get(&key) {
            Some(v) => {
                self.metric_hits.fetch_add(1, Ordering::Relaxed);
                Some(*v)
            }
            None => {
                self.metric_misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Publish comparison metrics (tiny; memory-only, unbounded).
    pub fn put_metrics(&self, key: u64, metrics: [f32; 3]) {
        self.metrics.lock().unwrap().insert(key, metrics);
    }

    /// True when the metrics map holds `key` (planning-time probe).
    pub fn contains_metrics(&self, key: u64) -> bool {
        self.metrics.lock().unwrap().contains_key(&key)
    }

    /// Number of states resident in memory.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently resident in memory.
    pub fn resident_bytes(&self) -> usize {
        self.resident.load(Ordering::Relaxed) as usize
    }

    /// Sorted keys of every state resident in memory (diagnostic / test
    /// aid: two runs that must leave the cache in the same state compare
    /// these).
    pub fn resident_keys(&self) -> Vec<u64> {
        let mut keys: Vec<u64> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().unwrap().map.keys().copied().collect::<Vec<_>>())
            .collect();
        keys.sort_unstable();
        keys
    }

    /// Sorted keys of every cached comparison metric.
    pub fn metric_keys(&self) -> Vec<u64> {
        let mut keys: Vec<u64> = self.metrics.lock().unwrap().keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Snapshot every counter.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            spilled: self.spilled.load(Ordering::Relaxed),
            metric_hits: self.metric_hits.load(Ordering::Relaxed),
            metric_misses: self.metric_misses.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
            peak_bytes: self.peak.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(v: f32, side: usize) -> [Plane; 3] {
        [
            Plane::filled(v, side, side),
            Plane::filled(v, side, side),
            Plane::filled(v, side, side),
        ]
    }

    #[test]
    fn hits_share_the_resident_allocation() {
        let c = ReuseCache::with_capacity(1 << 20);
        c.put_state(7, state(3.0, 4));
        let a = c.get_state(7).expect("hit");
        let b = c.get_state(7).expect("hit");
        // zero-copy: both hits point at the same [Plane; 3] allocation
        assert!(Arc::ptr_eq(&a, &b), "cache hits must be refcount bumps");
        assert_eq!(c.resident_keys(), vec![7]);
        c.put_metrics(9, [1.0, 1.0, 0.0]);
        assert_eq!(c.metric_keys(), vec![9]);
    }

    /// Bytes of one `state(v, 4)`: 3 planes x 16 px x 4 B.
    const S4: usize = 3 * 16 * 4;

    #[test]
    fn put_get_roundtrip_and_counters() {
        let c = ReuseCache::with_capacity(1 << 20);
        assert!(c.get_state(1).is_none());
        c.put_state(1, state(5.0, 4));
        let got = c.get_state(1).expect("hit");
        assert_eq!(got[0].get(0, 0), 5.0);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.inserts), (1, 1, 1));
        assert_eq!(st.resident_bytes as usize, S4);
        assert!(c.contains_state(1));
        assert!(!c.contains_state(2));
    }

    #[test]
    fn lru_evicts_oldest_at_the_byte_bound() {
        // one shard, room for exactly 2 states
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: 2 * S4,
            shards: 1,
            ..CacheConfig::default()
        });
        c.put_state(1, state(1.0, 4));
        c.put_state(2, state(2.0, 4));
        let _ = c.get_state(1); // 1 is now more recent than 2
        c.put_state(3, state(3.0, 4));
        assert!(c.resident_bytes() <= 2 * S4, "bound holds: {}", c.resident_bytes());
        assert!(c.get_state(2).is_none(), "LRU victim was 2");
        assert!(c.get_state(1).is_some());
        assert!(c.get_state(3).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn oversized_states_bypass_memory() {
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: S4 / 2,
            shards: 1,
            ..CacheConfig::default()
        });
        c.put_state(9, state(1.0, 4));
        assert_eq!(c.len(), 0, "state larger than the shard budget stays out");
        assert!(c.get_state(9).is_none());
    }

    #[test]
    fn metrics_roundtrip() {
        let c = ReuseCache::with_capacity(1024);
        assert!(c.get_metrics(5).is_none());
        c.put_metrics(5, [0.9, 0.8, 0.01]);
        assert_eq!(c.get_metrics(5), Some([0.9, 0.8, 0.01]));
        assert!(c.contains_metrics(5));
        let st = c.stats();
        assert_eq!((st.metric_hits, st.metric_misses), (1, 1));
    }

    #[test]
    fn disk_tier_serves_after_eviction() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: S4, // memory holds one state
            shards: 1,
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        c.put_state(1, state(1.0, 4));
        c.put_state(2, state(2.0, 4)); // evicts 1 from memory
        let back = c.get_state(1).expect("served from disk");
        assert_eq!(back[1].get(3, 3), 1.0);
        let st = c.stats();
        assert!(st.disk_hits >= 1, "stats: {st:?}");
        assert!(st.spilled >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_summary_is_labeled() {
        let c = ReuseCache::with_capacity(1024);
        c.put_state(1, state(1.0, 2));
        let rows = c.stats().summary();
        assert!(rows.iter().any(|(k, v)| k == "cache.inserts" && *v == 1));
        assert_eq!(rows.len(), 10);
    }
}
