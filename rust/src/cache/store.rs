//! The tier stack: a sharded, byte-bounded LRU memory tier composed
//! over any number of lower [`CacheTier`]s, plus everything that is not
//! storage — single-flight claims, scoped accounting, the metrics side
//! map and the cross-node claim registry.
//!
//! One [`ReuseCache`] is shared by every worker thread of a study — and,
//! crucially, by every *study* that runs while it lives: the multi-tenant
//! service ([`crate::serve`]) holds exactly one for the whole process.
//! Lock contention is kept off the hot path by sharding: keys map to one
//! of N independent mutex-protected shards, so concurrent workers almost
//! always lock disjoint shards. The [`MemoryTier`] enforces its slice of
//! the byte budget with LRU eviction; lower tiers (the RTC2 disk tier,
//! the cluster's [`super::remote::RemoteTier`]) are consulted in
//! attachment order on a memory miss, and a lower-tier hit is promoted
//! back into memory, owned by the requesting scope.
//!
//! # Concurrency invariants
//!
//! * **Zero-copy hits.** Stored states are `Arc<[Plane; 3]>`
//!   ([`CachedState`]); a hit hands back a refcount bump, never a
//!   ~3×H×W f32 deep copy, and concurrent readers share one allocation.
//! * **Single-flight misses.** [`ReuseCache::lookup_or_claim`] registers
//!   a miss as an in-flight computation; concurrent lookups of the same
//!   key observe [`StateClaim::InFlight`] and wait
//!   ([`ReuseCache::wait_for_flight`]) instead of duplicating the
//!   backend launch. Publication ([`ReuseCache::put_state`]) releases
//!   the flight and wakes the waiters. Claimants must never block on
//!   another flight while holding an unpublished claim — the engine
//!   executes and publishes all of its claims before waiting (see
//!   `runtime/engine.rs`), which rules out claim/wait cycles. Across
//!   nodes, the same discipline extends over the wire: a peer's
//!   `cache-get` lands in [`ReuseCache::serve_remote_get`], which
//!   either serves the state or hands the *requester* a deadline-bearing
//!   claim ([`RemoteServe::Claimed`]) that its `cache-put` settles — two
//!   nodes never duplicate a launch, and a crashed claimant expires.
//!   The v6 *peek* path ([`ReuseCache::peek_state`], wire
//!   `cache-get` with `peek:true`) is the deliberate exception: replica
//!   fallbacks behind an open breaker read claim-free — a miss answers
//!   immediately and registers nothing — so a degraded read can never
//!   wedge behind a claim TTL; the worst case is one duplicated launch,
//!   traded knowingly for liveness.
//! * **Scoped accounting.** Every counted operation takes a
//!   [`CacheCtx`] and bumps the context's scope *and* the global
//!   counters with the same increments, so per-tenant counters sum
//!   exactly to the global [`CacheStats`] when every operation carries a
//!   scope. The remote-serving paths ([`ReuseCache::serve_remote_get`],
//!   [`ReuseCache::serve_remote_put`]) are deliberately *stat-invisible*
//!   on the owner — peer traffic is billed on the requesting node, under
//!   the requesting tenant, as `remote_hits` — which keeps the
//!   scoped-sums-equal-globals invariant true on every node of a
//!   cluster.
//! * **Quota-aware admission.** Entries inserted under a scope are
//!   *owned* by it: the owner's resident-byte counter grows on insert and
//!   shrinks on eviction (whoever triggers the eviction, the *owner* is
//!   charged). A scope built with [`ScopedCounters::with_quota`] is a
//!   byte-bounded tenant: admitting past the quota evicts the tenant's
//!   own least-recently-used entries first, so one tenant can never
//!   crowd the shared memory tier beyond its allowance — its states
//!   remain reachable through the lower tiers.

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::data::Plane;
use crate::faults::Faults;
use crate::obs::{span, HistId};

use super::disk::{self, DiskTier};
use super::key::Key;
use super::tier::{CacheCtx, CacheTier, TierStats, DISK_TIER, MEMORY_TIER};

/// The 3-plane chain state the cache stores (same shape the coordinator's
/// node store moves between stages), refcount-shared: a cache hit hands
/// back an `Arc` clone — a refcount bump, not a ~3×H×W f32 deep copy —
/// and concurrent readers of the same entry share one allocation.
pub type CachedState = Arc<[Plane; 3]>;

/// Construction-time knobs (surfaced as `cache-*` study-config options).
#[derive(Clone, Debug, PartialEq)]
pub struct CacheConfig {
    /// In-memory ceiling over all shards, in bytes.
    pub capacity_bytes: usize,
    /// Number of independently locked shards.
    pub shards: usize,
    /// Parameter quantization step for key construction (0 = exact).
    pub quantize: f64,
    /// Optional persistent tier: write-through on insert, fallback on
    /// lookup.
    pub spill_dir: Option<PathBuf>,
    /// Fault-injection hook threaded into the disk tier (tests/chaos
    /// harness only; [`Faults::none`] — the default — is a single
    /// never-taken branch).
    pub faults: Faults,
}

impl Default for CacheConfig {
    fn default() -> Self {
        Self {
            capacity_bytes: 256 * 1024 * 1024,
            shards: 8,
            quantize: 0.0,
            spill_dir: None,
            faults: Faults::none(),
        }
    }
}

/// A point-in-time snapshot of the cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// State lookups served from memory.
    pub hits: u64,
    /// State lookups served from the disk tier.
    pub disk_hits: u64,
    /// State lookups served by a peer node's cache (cluster mode).
    pub remote_hits: u64,
    /// State lookups that found nothing.
    pub misses: u64,
    /// States newly published (first-time keys; approximate when several
    /// workers publish the same key simultaneously).
    pub inserts: u64,
    /// Entries evicted from memory by the byte bound.
    pub evictions: u64,
    /// Entries written to the disk tier.
    pub spilled: u64,
    /// Metric lookups served / missed.
    pub metric_hits: u64,
    pub metric_misses: u64,
    /// Current and high-water resident bytes.
    pub resident_bytes: u64,
    pub peak_bytes: u64,
}

impl CacheStats {
    /// Fraction of state lookups served from any tier.
    pub fn hit_rate(&self) -> f64 {
        let served = self.hits + self.disk_hits + self.remote_hits;
        let total = served + self.misses;
        if total == 0 {
            0.0
        } else {
            served as f64 / total as f64
        }
    }

    /// `TaskTimer`-style counter rows for study reports.
    pub fn summary(&self) -> Vec<(String, u64)> {
        vec![
            ("cache.hits".into(), self.hits),
            ("cache.disk_hits".into(), self.disk_hits),
            ("cache.remote_hits".into(), self.remote_hits),
            ("cache.misses".into(), self.misses),
            ("cache.inserts".into(), self.inserts),
            ("cache.evictions".into(), self.evictions),
            ("cache.spilled".into(), self.spilled),
            ("cache.metric_hits".into(), self.metric_hits),
            ("cache.metric_misses".into(), self.metric_misses),
            ("cache.resident_bytes".into(), self.resident_bytes),
            ("cache.peak_bytes".into(), self.peak_bytes),
        ]
    }
}

/// Per-scope (per-tenant, per-study — the caller decides the scope)
/// mirror of the lookup/publication counters, plus the scope's resident
/// footprint and optional byte quota. Every counted cache operation
/// whose [`CacheCtx`] carries a scope bumps the scope and the global
/// counters identically, so the sum of all scopes equals the global
/// [`CacheStats`] on the fields a scope tracks (hits, disk hits, remote
/// hits, misses, inserts, metric hits/misses — and evictions/resident
/// bytes when *every* insert was scoped); peak residency remains
/// global-only.
///
/// A scope in the context handed to [`ReuseCache::put_state`] (or to a
/// lookup that promotes a lower-tier entry) becomes the **owner** of the
/// admitted entry: the entry's bytes count against this scope's
/// [`ScopedCounters::resident_bytes`] until the entry is evicted, and
/// the eviction — whoever triggers it — is charged to this scope's
/// eviction counter. Scope identity is the `Arc` pointer, which is why
/// the owning entry points take `&Arc<ScopedCounters>`.
#[derive(Debug, Default)]
pub struct ScopedCounters {
    pub(super) hits: AtomicU64,
    pub(super) disk_hits: AtomicU64,
    pub(super) remote_hits: AtomicU64,
    pub(super) misses: AtomicU64,
    pub(super) inserts: AtomicU64,
    pub(super) evictions: AtomicU64,
    pub(super) metric_hits: AtomicU64,
    pub(super) metric_misses: AtomicU64,
    pub(super) bytes_served: AtomicU64,
    pub(super) resident: AtomicU64,
    /// Memory-tier byte allowance for entries this scope owns
    /// (0 = unlimited). Fixed at construction.
    quota: u64,
    /// Keys of entries this scope currently owns — the quota-eviction
    /// index, so over-quota eviction scans the owner's few entries, not
    /// the whole shared cache. Maintained outside the shard locks (no
    /// lock nesting), so briefly stale keys are possible; eviction
    /// verifies against the shard and prunes stale keys lazily.
    owned: Mutex<HashSet<Key>>,
}

impl ScopedCounters {
    /// A scope whose owned entries may occupy at most `quota_bytes` of
    /// the shared memory tier. Admission past the quota evicts this
    /// scope's own LRU entries (never another tenant's); an entry larger
    /// than the whole quota is not admitted to memory at all (it still
    /// reaches the disk tier, where lookups find it). `0` means
    /// unlimited — identical to the `Default` construction.
    pub fn with_quota(quota_bytes: u64) -> Self {
        Self { quota: quota_bytes, ..Self::default() }
    }

    /// Snapshot as a [`CacheStats`] (the global-only `peak_bytes` and
    /// `spilled` fields stay zero).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            remote_hits: self.remote_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            metric_hits: self.metric_hits.load(Ordering::Relaxed),
            metric_misses: self.metric_misses.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
            ..CacheStats::default()
        }
    }

    /// Bytes of cached state this scope was served (hit payload sizes —
    /// the per-tenant "data moved out of the shared cache" figure; the
    /// states themselves are shared `Arc`s, so these bytes were *not*
    /// copied, merely made available).
    pub fn state_bytes_served(&self) -> u64 {
        self.bytes_served.load(Ordering::Relaxed)
    }

    /// Memory-tier bytes currently occupied by entries this scope owns.
    /// After every scoped `put_state` call returns, this is ≤
    /// [`ScopedCounters::quota_bytes`] (when a quota is set).
    pub fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    /// Entries of this scope evicted from the memory tier (by its own
    /// quota or by the shared shard byte bound).
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// The byte quota this scope was built with (0 = unlimited).
    pub fn quota_bytes(&self) -> u64 {
        self.quota
    }
}

/// Outcome of [`ReuseCache::lookup_or_claim`].
pub enum StateClaim {
    /// The state was cached (any tier) — served zero-copy.
    Ready(CachedState),
    /// Nothing cached and no one computing: the caller now owns the
    /// flight and MUST publish ([`ReuseCache::put_state`]) or release
    /// ([`ReuseCache::release_flight`]) it, on every path. Use
    /// [`FlightClaims`] for panic/error safety.
    Claimed,
    /// Another worker is computing this key; wait with
    /// [`ReuseCache::wait_for_flight`] and look up again.
    InFlight,
}

/// Outcome of [`ReuseCache::lookup_or_claim_metrics`] (same protocol as
/// [`StateClaim`], for the comparison-metric side map).
pub enum MetricsClaim {
    Ready([f32; 3]),
    Claimed,
    InFlight,
}

/// Outcome of serving a peer's `cache-get` on the node that owns the
/// key ([`ReuseCache::serve_remote_get`]).
pub enum RemoteServe {
    /// The owner holds the state (memory or disk) — ship it back.
    Found(CachedState),
    /// Nothing cached and no other node computing: the *requester* now
    /// holds the cross-node claim and must compute locally, then publish
    /// with `cache-put` (which settles the claim). The claim expires
    /// after a TTL, so a crashed requester cannot wedge the key.
    Claimed,
}

struct Entry {
    state: CachedState,
    bytes: usize,
    tick: u64,
    /// The scope whose residency this entry counts against (see
    /// [`ScopedCounters`]); `None` for unscoped inserts (single-study
    /// runs, warm-start pre-admission, peer-published entries).
    owner: Option<Arc<ScopedCounters>>,
}

#[derive(Default)]
struct Shard {
    map: HashMap<Key, Entry>,
    bytes: usize,
}

/// In-flight miss registry (single-flight): keys currently being
/// computed by some worker. Guards both the state and the metric maps —
/// the keyspaces are derived differently and never overlap in practice;
/// a spurious cross-map wait would only delay, never corrupt.
#[derive(Default)]
struct Flights {
    set: Mutex<HashSet<Key>>,
    cv: Condvar,
}

/// Cross-node single-flight registry: keys a *peer node* claimed via
/// `cache-get` and has not yet settled with `cache-put`. Claims carry
/// their grant time so a crashed claimant expires after
/// [`REMOTE_CLAIM_TTL`] instead of wedging the key cluster-wide.
#[derive(Default)]
struct RemoteClaims {
    map: Mutex<HashMap<Key, Instant>>,
    cv: Condvar,
}

/// How long a peer may sit on a cross-node claim before another
/// requester may take it over. Generous: it only bounds the damage of a
/// crashed claimant, and a duplicate launch is merely wasted work.
const REMOTE_CLAIM_TTL: Duration = Duration::from_secs(30);

/// Re-check cadence while a `cache-get` handler waits on someone else's
/// cross-node claim (settles also wake it immediately via the condvar).
const REMOTE_WAIT_SLICE: Duration = Duration::from_millis(100);

/// The resident memory tier: a sharded, byte-bounded, quota-aware LRU.
/// Always the top of the stack; owned concretely by [`ReuseCache`] (the
/// hot path never pays a vtable), exposed as a [`CacheTier`] for
/// introspection and tests.
pub struct MemoryTier {
    capacity_bytes: usize,
    shards: Vec<Mutex<Shard>>,
    tick: AtomicU64,
    hits: AtomicU64,
    stores: AtomicU64,
    evictions: AtomicU64,
    resident: AtomicU64,
    peak: AtomicU64,
}

impl MemoryTier {
    fn new(capacity_bytes: usize, nshards: usize) -> Self {
        Self {
            capacity_bytes,
            shards: (0..nshards.max(1)).map(|_| Mutex::new(Shard::default())).collect(),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            resident: AtomicU64::new(0),
            peak: AtomicU64::new(0),
        }
    }

    fn shard_of(&self, key: Key) -> &Mutex<Shard> {
        let x = key.lo() ^ key.hi();
        let i = ((x ^ (x >> 32)) as usize) % self.shards.len();
        &self.shards[i]
    }

    fn per_shard_budget(&self) -> usize {
        self.capacity_bytes / self.shards.len()
    }

    fn next_tick(&self) -> u64 {
        self.tick.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Resident probe: bumps the LRU tick, touches no counters (the
    /// stack does the billing; peeks stay invisible).
    fn probe(&self, key: Key) -> Option<CachedState> {
        let mut s = self.shard_of(key).lock().unwrap();
        if let Some(e) = s.map.get_mut(&key) {
            e.tick = self.next_tick();
            Some(Arc::clone(&e.state))
        } else {
            None
        }
    }

    fn contains(&self, key: Key) -> bool {
        self.shard_of(key).lock().unwrap().map.contains_key(&key)
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    fn resident_keys(&self) -> Vec<Key> {
        let mut keys: Vec<Key> = self
            .shards
            .iter()
            .flat_map(|s| s.lock().unwrap().map.keys().copied().collect::<Vec<_>>())
            .collect();
        keys.sort_unstable();
        keys
    }

    fn resident_bytes(&self) -> u64 {
        self.resident.load(Ordering::Relaxed)
    }

    fn evictions_total(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Remove an evicted entry's bytes from the books, charging the
    /// *owning* scope (not whoever triggered the eviction).
    fn charge_eviction(&self, entry: &Entry) {
        self.evictions.fetch_add(1, Ordering::Relaxed);
        self.resident.fetch_sub(entry.bytes as u64, Ordering::Relaxed);
        if let Some(o) = &entry.owner {
            o.resident.fetch_sub(entry.bytes as u64, Ordering::Relaxed);
            o.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Evict the least-recently-used entry *owned by* `owner`, using
    /// the owner's key index — O(entries the owner holds), never a walk
    /// of the whole shared cache; ticks are read live from the shards
    /// (one lock at a time, never nested with the index lock) so the
    /// choice is exact LRU. Returns false only when the owner has no
    /// resident entries left; a concurrent removal of the chosen victim
    /// counts as progress and returns true, letting the quota loop
    /// re-check.
    fn evict_scope_lru(&self, owner: &Arc<ScopedCounters>) -> bool {
        let keys: Vec<Key> = owner.owned.lock().unwrap().iter().copied().collect();
        let mut best: Option<(Key, u64)> = None;
        let mut stale: Vec<Key> = Vec::new();
        for key in keys {
            let s = self.shard_of(key).lock().unwrap();
            match s.map.get(&key) {
                Some(e) if e.owner.as_ref().is_some_and(|o| Arc::ptr_eq(o, owner)) => {
                    if best.is_none_or(|(_, t)| e.tick < t) {
                        best = Some((key, e.tick));
                    }
                }
                _ => stale.push(key), // evicted or re-owned since indexed
            }
        }
        if !stale.is_empty() {
            let mut owned = owner.owned.lock().unwrap();
            for k in &stale {
                owned.remove(k);
            }
        }
        let Some((key, _)) = best else {
            return false;
        };
        let removed = {
            let mut s = self.shard_of(key).lock().unwrap();
            let still_owned = s
                .map
                .get(&key)
                .is_some_and(|e| e.owner.as_ref().is_some_and(|o| Arc::ptr_eq(o, owner)));
            if still_owned {
                if let Some(e) = s.map.remove(&key) {
                    s.bytes -= e.bytes;
                    self.charge_eviction(&e);
                }
                true
            } else {
                false // raced with another eviction: caller re-checks
            }
        };
        if removed {
            owner.owned.lock().unwrap().remove(&key);
        }
        true
    }

    /// Bring `owner`'s resident bytes back under its quota by evicting
    /// its own LRU entries. Runs after every owned insert, so the quota
    /// bound holds whenever no insert is mid-flight — each concurrent
    /// inserter enforces its own addition before returning.
    fn enforce_quota(&self, owner: &Arc<ScopedCounters>) {
        if owner.quota == 0 {
            return;
        }
        while owner.resident.load(Ordering::Relaxed) > owner.quota {
            if !self.evict_scope_lru(owner) {
                break;
            }
        }
    }

    /// Returns true when `key` was newly added to the resident map.
    fn insert(&self, key: Key, state: CachedState, owner: Option<&Arc<ScopedCounters>>) -> bool {
        let bytes: usize = state.iter().map(Plane::nbytes).sum();
        let budget = self.per_shard_budget();
        if bytes > budget {
            return false; // larger than a whole shard: lower tiers only
        }
        if let Some(o) = owner {
            if o.quota > 0 && bytes as u64 > o.quota {
                return false; // larger than the whole quota: lower tiers only
            }
        }
        let tick = self.next_tick();
        let mut s = self.shard_of(key).lock().unwrap();
        if let Some(e) = s.map.get_mut(&key) {
            e.tick = tick;
            return false;
        }
        s.map.insert(key, Entry { state, bytes, tick, owner: owner.cloned() });
        s.bytes += bytes;
        if let Some(o) = owner {
            o.resident.fetch_add(bytes as u64, Ordering::Relaxed);
        }
        let mut freed = 0u64;
        let mut evicted_owned: Vec<(Arc<ScopedCounters>, Key)> = Vec::new();
        while s.bytes > budget {
            // LRU victim: smallest tick. Shard maps stay small enough
            // (budget / state size) that a scan beats maintaining an
            // ordered index under the lock.
            let victim = s
                .map
                .iter()
                .filter(|(&k, _)| k != key)
                .min_by_key(|(_, e)| e.tick)
                .map(|(&k, _)| k);
            match victim {
                Some(v) => {
                    if let Some(e) = s.map.remove(&v) {
                        s.bytes -= e.bytes;
                        freed += e.bytes as u64;
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                        if let Some(o) = &e.owner {
                            o.resident.fetch_sub(e.bytes as u64, Ordering::Relaxed);
                            o.evictions.fetch_add(1, Ordering::Relaxed);
                            evicted_owned.push((Arc::clone(o), v));
                        }
                    }
                }
                None => break,
            }
        }
        drop(s);
        // index maintenance happens outside the shard lock (the owned
        // set and the shards are never locked together)
        for (o, k) in &evicted_owned {
            o.owned.lock().unwrap().remove(k);
        }
        if let Some(o) = owner {
            o.owned.lock().unwrap().insert(key);
        }
        let grown = bytes as u64;
        let now = self.resident.fetch_add(grown, Ordering::Relaxed) + grown;
        self.resident.fetch_sub(freed, Ordering::Relaxed);
        self.peak.fetch_max(now.saturating_sub(freed), Ordering::Relaxed);
        if let Some(o) = owner {
            // after the shard lock is released: quota eviction re-locks
            // shards one at a time
            self.enforce_quota(o);
        }
        true
    }
}

impl CacheTier for MemoryTier {
    fn name(&self) -> &'static str {
        MEMORY_TIER
    }

    fn lookup(&self, key: Key, _ctx: &CacheCtx) -> Option<CachedState> {
        let state = self.probe(key)?;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(state)
    }

    fn store(&self, key: Key, state: &CachedState, ctx: &CacheCtx) -> bool {
        if self.insert(key, Arc::clone(state), ctx.scope()) {
            self.stores.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    fn evict_scope(&self, scope: &Arc<ScopedCounters>) -> bool {
        self.evict_scope_lru(scope)
    }

    fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            resident_bytes: self.resident.load(Ordering::Relaxed),
            ..TierStats::default()
        }
    }
}

/// The cross-study, content-addressed reuse cache: the [`MemoryTier`]
/// stacked over the attached lower tiers, plus claims and accounting.
pub struct ReuseCache {
    cfg: CacheConfig,
    memory: MemoryTier,
    /// Lower tiers, consulted in order on a memory miss and written
    /// through on publication. The disk tier is installed at
    /// construction (when `spill_dir` is set); the service attaches the
    /// remote tier after boot ([`ReuseCache::attach_tier`]).
    lower: RwLock<Vec<Arc<dyn CacheTier>>>,
    metrics: Mutex<HashMap<Key, [f32; 3]>>,
    flights: Flights,
    remote: RemoteClaims,
    hits: AtomicU64,
    disk_hits: AtomicU64,
    remote_hits: AtomicU64,
    misses: AtomicU64,
    inserts: AtomicU64,
    spilled: AtomicU64,
    metric_hits: AtomicU64,
    metric_misses: AtomicU64,
}

impl fmt::Debug for ReuseCache {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ReuseCache")
            .field("cfg", &self.cfg)
            .field("stats", &self.stats())
            .finish()
    }
}

impl ReuseCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let memory = MemoryTier::new(cfg.capacity_bytes, cfg.shards);
        let mut lower: Vec<Arc<dyn CacheTier>> = Vec::new();
        if let Some(dir) = &cfg.spill_dir {
            lower.push(Arc::new(DiskTier::new(dir.clone()).with_faults(cfg.faults.clone())));
        }
        Self {
            cfg,
            memory,
            lower: RwLock::new(lower),
            metrics: Mutex::new(HashMap::new()),
            flights: Flights::default(),
            remote: RemoteClaims::default(),
            hits: AtomicU64::new(0),
            disk_hits: AtomicU64::new(0),
            remote_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inserts: AtomicU64::new(0),
            spilled: AtomicU64::new(0),
            metric_hits: AtomicU64::new(0),
            metric_misses: AtomicU64::new(0),
        }
    }

    /// An in-memory cache with the given byte budget and defaults
    /// elsewhere.
    pub fn with_capacity(capacity_bytes: usize) -> Self {
        Self::new(CacheConfig { capacity_bytes, ..CacheConfig::default() })
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// The parameter quantization step keys are built with.
    pub fn quantize_step(&self) -> f64 {
        self.cfg.quantize
    }

    /// The resident memory tier (top of the stack), viewable as a
    /// [`CacheTier`] trait object.
    pub fn memory_tier(&self) -> &MemoryTier {
        &self.memory
    }

    /// Snapshot of the lower tiers, in consultation order.
    pub fn tiers(&self) -> Vec<Arc<dyn CacheTier>> {
        self.lower.read().unwrap().clone()
    }

    /// Attach a lower tier below every tier already present. Lookups
    /// consult it on a miss of everything above; publications write
    /// through to it. The counter mapping keys on [`CacheTier::name`]:
    /// `"disk"` bills as `disk_hits`/`spilled`, anything else as
    /// `remote_hits`.
    pub fn attach_tier(&self, tier: Arc<dyn CacheTier>) {
        self.lower.write().unwrap().push(tier);
    }

    fn bump(global: &AtomicU64, scoped: Option<&AtomicU64>) {
        global.fetch_add(1, Ordering::Relaxed);
        if let Some(s) = scoped {
            s.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Credit a served state's payload size to the scope (per-tenant
    /// byte accounting; no global counterpart — globals track residency).
    fn credit_bytes(scope: Option<&Arc<ScopedCounters>>, state: &CachedState) {
        if let Some(s) = scope {
            let bytes: usize = state.iter().map(Plane::nbytes).sum();
            s.bytes_served.fetch_add(bytes as u64, Ordering::Relaxed);
        }
    }

    /// Bill a memory-tier hit to the context.
    fn count_memory_hit(&self, ctx: &CacheCtx, state: &CachedState) {
        Self::bump(&self.hits, ctx.scope().map(|s| &s.hits));
        Self::credit_bytes(ctx.scope(), state);
    }

    /// Consult the lower tiers in order; a hit is billed by tier name,
    /// promoted into memory owned by the requesting scope (no `inserts`
    /// bump — promotion is not publication), and served.
    fn lookup_lower(&self, key: Key, ctx: &CacheCtx) -> Option<CachedState> {
        let tiers = self.lower.read().unwrap();
        for tier in tiers.iter() {
            let found = Self::lookup_one(tier.as_ref(), key, ctx);
            let Some(state) = found else {
                continue;
            };
            if tier.name() == DISK_TIER {
                Self::bump(&self.disk_hits, ctx.scope().map(|s| &s.disk_hits));
            } else {
                Self::bump(&self.remote_hits, ctx.scope().map(|s| &s.remote_hits));
            }
            Self::credit_bytes(ctx.scope(), &state);
            self.memory.insert(key, Arc::clone(&state), ctx.scope());
            return Some(state);
        }
        None
    }

    /// One lower-tier consultation, timed and traced when the context
    /// carries an active telemetry handle: the lookup's own span id is
    /// allocated *before* the call and handed down via a child context,
    /// so the remote tier can stamp it onto its wire frames (the owner's
    /// `serve-get` span parents under it); the tier's latency lands in
    /// its per-tier histogram. Off path: one never-taken branch.
    fn lookup_one(tier: &dyn CacheTier, key: Key, ctx: &CacheCtx) -> Option<CachedState> {
        let Some(o) = ctx.obs().get().cloned() else {
            return tier.lookup(key, ctx);
        };
        let span_id = o.next_span();
        let started = Instant::now();
        let found = match ctx.span() {
            Some(sc) => tier.lookup(key, &ctx.with_span(sc.child(span_id))),
            None => tier.lookup(key, ctx),
        };
        let dur = started.elapsed();
        let tenant = ctx.span().map(|sc| sc.tenant.as_ref());
        o.observe(HistId::lookup_for_tier(tier.name()), tenant, dur);
        if let Some(sc) = ctx.span() {
            let outcome = if found.is_some() { "hit" } else { "miss" };
            o.emit_timed(sc, span::LOOKUP, span_id, started, dur, format!("{} {outcome}", tier.name()));
        }
        found
    }

    /// Time a memory-tier probe into the memory-lookup histogram (no
    /// span — memory probes are nanosecond-scale and would flood the
    /// ring; the histogram is the observable).
    fn probe_memory(&self, key: Key, ctx: &CacheCtx) -> Option<CachedState> {
        let Some(o) = ctx.obs().get() else {
            return self.memory.lookup(key, ctx);
        };
        let started = Instant::now();
        let found = self.memory.lookup(key, ctx);
        let tenant = ctx.span().map(|sc| sc.tenant.as_ref());
        o.observe(HistId::LookupMemory, tenant, started.elapsed());
        found
    }

    /// Look up the state for `key`: memory first, then the lower tiers
    /// in order. A memory hit is a refcount bump (the returned `Arc`
    /// shares the resident allocation); a lower-tier hit is promoted
    /// back into memory, charged to (owned by) the context's scope.
    pub fn get_state(&self, key: Key, ctx: &CacheCtx) -> Option<CachedState> {
        if let Some(state) = self.probe_memory(key, ctx) {
            self.count_memory_hit(ctx, &state);
            return Some(state);
        }
        if let Some(state) = self.lookup_lower(key, ctx) {
            return Some(state);
        }
        Self::bump(&self.misses, ctx.scope().map(|s| &s.misses));
        None
    }

    /// Single-flight lookup: a hit is served zero-copy; a miss *claims*
    /// the key (registering it in flight, counted as a miss — so under
    /// full single-flight discipline, `misses` equals backend
    /// computations); a key someone else is computing returns
    /// [`StateClaim::InFlight`] without touching any counter — the
    /// caller waits and retries, and the eventual resolution is what
    /// gets counted.
    pub fn lookup_or_claim(&self, key: Key, ctx: &CacheCtx) -> StateClaim {
        if let Some(state) = self.probe_memory(key, ctx) {
            self.count_memory_hit(ctx, &state);
            return StateClaim::Ready(state);
        }
        {
            let mut flights = self.flights.set.lock().unwrap();
            if flights.contains(&key) {
                return StateClaim::InFlight;
            }
            // the owner may have published between the probe and the lock
            if let Some(state) = self.memory.lookup(key, ctx) {
                self.count_memory_hit(ctx, &state);
                return StateClaim::Ready(state);
            }
            // claim BEFORE the lower-tier probes, so the (slow) disk
            // read or peer round-trip below runs without the global
            // flight lock — concurrent lookups of this key wait on the
            // claim; everyone else proceeds
            flights.insert(key);
        }
        if let Some(state) = self.lookup_lower(key, ctx) {
            // promoted to memory: waiters re-probe and hit
            self.release_flight(key);
            return StateClaim::Ready(state);
        }
        Self::bump(&self.misses, ctx.scope().map(|s| &s.misses));
        StateClaim::Claimed
    }

    /// Single-flight lookup on the comparison-metric map (see
    /// [`ReuseCache::lookup_or_claim`] for the protocol). Metrics are
    /// tiny and memory-only; they never travel through the tier stack.
    pub fn lookup_or_claim_metrics(&self, key: Key, ctx: &CacheCtx) -> MetricsClaim {
        if let Some(m) = self.metrics.lock().unwrap().get(&key) {
            Self::bump(&self.metric_hits, ctx.scope().map(|s| &s.metric_hits));
            return MetricsClaim::Ready(*m);
        }
        let mut flights = self.flights.set.lock().unwrap();
        if flights.contains(&key) {
            return MetricsClaim::InFlight;
        }
        if let Some(m) = self.metrics.lock().unwrap().get(&key) {
            Self::bump(&self.metric_hits, ctx.scope().map(|s| &s.metric_hits));
            return MetricsClaim::Ready(*m);
        }
        flights.insert(key);
        Self::bump(&self.metric_misses, ctx.scope().map(|s| &s.metric_misses));
        MetricsClaim::Claimed
    }

    /// Release an in-flight claim without publishing (error/abandon
    /// path). Idempotent; wakes every waiter so one of them can
    /// re-claim. [`ReuseCache::put_state`] / [`ReuseCache::put_metrics`]
    /// release automatically on publication.
    pub fn release_flight(&self, key: Key) {
        let mut flights = self.flights.set.lock().unwrap();
        if flights.remove(&key) {
            self.flights.cv.notify_all();
        }
    }

    /// Block until `key` is no longer in flight (it may be published,
    /// abandoned, or even already evicted — the caller must look up
    /// again and, on a miss, claim for itself). Callers must not hold
    /// any unpublished claim of their own while waiting.
    pub fn wait_for_flight(&self, key: Key) {
        let mut flights = self.flights.set.lock().unwrap();
        while flights.contains(&key) {
            flights = self.flights.cv.wait(flights).unwrap();
        }
    }

    /// [`ReuseCache::wait_for_flight`] with a deadline: returns false if
    /// the key is *still* in flight when `timeout` elapses. A false
    /// return means the flight's owner is wedged (or merely very slow) —
    /// the caller should give up on the claim and compute the key
    /// itself, un-claimed: a possible duplicate launch, never a
    /// deadlock. The engine uses this so one stuck worker (or a crashed
    /// remote claimant) cannot block every waiter forever.
    pub fn wait_for_flight_for(&self, key: Key, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut flights = self.flights.set.lock().unwrap();
        while flights.contains(&key) {
            let Some(left) = deadline.checked_duration_since(Instant::now()) else {
                return false;
            };
            let (guard, _) = self.flights.cv.wait_timeout(flights, left).unwrap();
            flights = guard;
        }
        true
    }

    /// Count a state hit that was served outside the cache's own lookup
    /// paths — the batched executor serving a lane from a sibling lane's
    /// just-computed result records it here, exactly as the sequential
    /// path's lookup-after-publication would have counted a hit.
    pub fn note_state_hit(&self, ctx: &CacheCtx) {
        Self::bump(&self.hits, ctx.scope().map(|s| &s.hits));
    }

    /// Probe without fetching (planning-time check): true when the key
    /// is resident in memory or present on disk. Deliberately *local*
    /// tiers only — a planning pass must not pay a network round-trip
    /// per key, and must not disturb peers' cross-node claims. Does not
    /// touch LRU order or the hit/miss counters.
    pub fn contains_state(&self, key: Key) -> bool {
        if self.memory.contains(key) {
            return true;
        }
        match &self.cfg.spill_dir {
            Some(dir) => disk::has_state(dir, key),
            None => false,
        }
    }

    /// Publish a state under `key` (anything convertible into the
    /// refcounted [`CachedState`]; a plain `[Plane; 3]` wraps into a
    /// fresh `Arc`). The state is written through every lower tier
    /// (disk immediately; in cluster mode the remote tier ships it to
    /// the peer that owns the key), then admitted to memory owned by
    /// the context's scope. The `inserts` counter tracks newly published
    /// keys (approximate under concurrent duplicate publication of the
    /// same key); what a *peer* stores is the peer's business and never
    /// bumps it. Publication releases any in-flight claim on `key` and
    /// wakes its waiters — including peer `cache-get` handlers parked on
    /// a cross-node claim.
    pub fn put_state(&self, key: Key, state: impl Into<CachedState>, ctx: &CacheCtx) {
        let state = state.into();
        let mut new_on_disk = false;
        {
            let tiers = self.lower.read().unwrap();
            for tier in tiers.iter() {
                let stored = tier.store(key, &state, ctx);
                if stored && tier.name() == DISK_TIER {
                    self.spilled.fetch_add(1, Ordering::Relaxed);
                    new_on_disk = true;
                }
            }
        }
        if self.memory.insert(key, state, ctx.scope()) || new_on_disk {
            Self::bump(&self.inserts, ctx.scope().map(|s| &s.inserts));
        }
        self.release_flight(key);
        self.settle_remote(key);
    }

    /// Look up cached comparison metrics.
    pub fn get_metrics(&self, key: Key, ctx: &CacheCtx) -> Option<[f32; 3]> {
        let m = self.metrics.lock().unwrap();
        match m.get(&key) {
            Some(v) => {
                Self::bump(&self.metric_hits, ctx.scope().map(|s| &s.metric_hits));
                Some(*v)
            }
            None => {
                Self::bump(&self.metric_misses, ctx.scope().map(|s| &s.metric_misses));
                None
            }
        }
    }

    /// Publish comparison metrics (tiny; resident in memory, persisted
    /// append-only next to the disk tier so a warm-restarted process
    /// skips the comparison launches too). Releases any in-flight claim
    /// on `key`.
    pub fn put_metrics(&self, key: Key, metrics: [f32; 3]) {
        let new = self.metrics.lock().unwrap().insert(key, metrics).is_none();
        if new {
            self.append_metrics_log(key, metrics);
        }
        self.release_flight(key);
    }

    /// Append one metrics entry to the spill directory's `metrics.log`.
    /// One line per entry — `key` + the three f32 bit patterns + an
    /// FNV-1a-64 line checksum, all hex — written with a single
    /// `O_APPEND` write so concurrent publishers never interleave
    /// mid-line. No fsync: metrics are cheap to recompute, and the
    /// loader stops at the first torn line. Write failures are silently
    /// dropped (the log, like the whole disk tier, is an accelerator).
    fn append_metrics_log(&self, key: Key, metrics: [f32; 3]) {
        use std::io::Write;
        let Some(dir) = &self.cfg.spill_dir else {
            return;
        };
        if std::fs::create_dir_all(dir).is_err() {
            return;
        }
        let Ok(mut file) =
            std::fs::OpenOptions::new().create(true).append(true).open(dir.join(METRICS_LOG))
        else {
            return;
        };
        let body = format!(
            "{:032x} {:08x} {:08x} {:08x}",
            key.as_u128(),
            metrics[0].to_bits(),
            metrics[1].to_bits(),
            metrics[2].to_bits()
        );
        let _ = writeln!(file, "{body} {:016x}", disk::fnv1a64(body.as_bytes()));
    }

    /// Re-load persisted metrics ([`ReuseCache::put_metrics`]'s log)
    /// into the metrics map. Loading stops at the first line that fails
    /// to parse or checksum — everything past a torn append is suspect.
    /// Returns how many entries were admitted (already-resident keys
    /// count as loaded; duplicate lines are harmless).
    fn load_metrics_log(&self) -> u64 {
        let Some(dir) = &self.cfg.spill_dir else {
            return 0;
        };
        let Ok(text) = std::fs::read_to_string(dir.join(METRICS_LOG)) else {
            return 0;
        };
        let mut loaded = 0;
        let mut metrics = self.metrics.lock().unwrap();
        for line in text.lines() {
            let Some(entry) = parse_metrics_line(line) else {
                break;
            };
            let (key, m) = entry;
            metrics.insert(key, m);
            loaded += 1;
        }
        loaded
    }

    /// True when the metrics map holds `key` (planning-time probe).
    pub fn contains_metrics(&self, key: Key) -> bool {
        self.metrics.lock().unwrap().contains_key(&key)
    }

    // ------------------------------------------------------------------
    // The owner side of the cluster fabric: serving peers' cache-get /
    // cache-put. These paths are STAT-INVISIBLE — they bump neither the
    // global nor any scoped counter (tier-local diagnostics aside) — so
    // every node's scoped sums still equal its globals: peer traffic is
    // billed on the requesting node, as that tenant's `remote_hits`.
    // ------------------------------------------------------------------

    /// Uncounted local probe (memory, then disk): the owner answering a
    /// peer's `cache-get`. No promotion, no LRU-billing, no counters —
    /// the requester does its own accounting.
    pub fn peek_state(&self, key: Key) -> Option<CachedState> {
        if let Some(state) = self.memory.probe(key) {
            return Some(state);
        }
        let ctx = CacheCtx::unscoped();
        let tiers = self.lower.read().unwrap();
        for tier in tiers.iter().filter(|t| t.name() == DISK_TIER) {
            if let Some(state) = tier.lookup(key, &ctx) {
                return Some(state);
            }
        }
        None
    }

    /// Serve a peer's `cache-get` for a key this node owns: the state
    /// if any local tier holds it, else a cross-node claim — blocking
    /// while *another* requester holds the claim, so two nodes never
    /// launch the same task. Claims expire after a TTL (30 s), so a
    /// crashed requester cannot wedge the key.
    pub fn serve_remote_get(&self, key: Key) -> RemoteServe {
        loop {
            if let Some(state) = self.peek_state(key) {
                return RemoteServe::Found(state);
            }
            let mut claims = self.remote.map.lock().unwrap();
            let held = claims.get(&key).is_some_and(|since| since.elapsed() < REMOTE_CLAIM_TTL);
            if held {
                // someone else is computing this key: wait for its
                // cache-put (or claim expiry) and re-check from the top
                let (guard, _) = self.remote.cv.wait_timeout(claims, REMOTE_WAIT_SLICE).unwrap();
                drop(guard);
            } else {
                // no active claim (or an expired one): this requester
                // takes over and computes locally
                claims.insert(key, Instant::now());
                return RemoteServe::Claimed;
            }
        }
    }

    /// Accept a peer's `cache-put`: admit the published state locally
    /// (write-through to disk, then memory) and settle any cross-node
    /// claim on the key. Like warm-start pre-admission, the entry is
    /// unowned and uncounted — the computing node already billed the
    /// launch; the owner is just the key's home. Returns true when any
    /// local tier newly stored it.
    pub fn serve_remote_put(&self, key: Key, state: [Plane; 3]) -> bool {
        let state: CachedState = Arc::new(state);
        let ctx = CacheCtx::unscoped();
        let mut stored = false;
        {
            let tiers = self.lower.read().unwrap();
            for tier in tiers.iter().filter(|t| t.name() == DISK_TIER) {
                if tier.store(key, &state, &ctx) {
                    self.spilled.fetch_add(1, Ordering::Relaxed);
                    stored = true;
                }
            }
        }
        if self.memory.insert(key, state, None) {
            stored = true;
        }
        self.settle_remote(key);
        stored
    }

    /// Settle the cross-node claim on `key` (if any) and wake every
    /// `cache-get` handler parked on it. Called on `cache-put` and on
    /// every local publication, so waiters re-peek promptly.
    pub fn settle_remote(&self, key: Key) {
        let mut claims = self.remote.map.lock().unwrap();
        if claims.remove(&key).is_some() {
            self.remote.cv.notify_all();
        }
    }

    /// Number of states resident in memory.
    pub fn len(&self) -> usize {
        self.memory.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently resident in memory.
    pub fn resident_bytes(&self) -> usize {
        self.memory.resident_bytes() as usize
    }

    /// Sorted keys of every state resident in memory (diagnostic / test
    /// aid: two runs that must leave the cache in the same state compare
    /// these).
    pub fn resident_keys(&self) -> Vec<Key> {
        self.memory.resident_keys()
    }

    /// Sorted keys of every cached comparison metric.
    pub fn metric_keys(&self) -> Vec<Key> {
        let mut keys: Vec<Key> = self.metrics.lock().unwrap().keys().copied().collect();
        keys.sort_unstable();
        keys
    }

    /// Per-tier diagnostic counters, top of the stack first — the
    /// memory tier, then every attached lower tier in consultation
    /// order. The remote tier's row carries the circuit-breaker
    /// transition counts ([`TierStats::breaker_opens`] /
    /// [`TierStats::breaker_closes`]).
    pub fn tier_stats(&self) -> Vec<(&'static str, TierStats)> {
        let mut out = vec![(MEMORY_TIER, self.memory.stats())];
        for tier in self.lower.read().unwrap().iter() {
            out.push((tier.name(), tier.stats()));
        }
        out
    }

    /// Snapshot every counter.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            disk_hits: self.disk_hits.load(Ordering::Relaxed),
            remote_hits: self.remote_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            evictions: self.memory.evictions_total(),
            spilled: self.spilled.load(Ordering::Relaxed),
            metric_hits: self.metric_hits.load(Ordering::Relaxed),
            metric_misses: self.metric_misses.load(Ordering::Relaxed),
            resident_bytes: self.memory.resident_bytes(),
            peak_bytes: self.memory.peak_bytes(),
        }
    }

    /// Pre-admit persisted disk-tier entries into the memory tier, so a
    /// freshly started process serves *memory* hits from its first
    /// lookup instead of paying a disk read per key (the service runs
    /// this at boot — "the first tenant of the day is warm").
    ///
    /// The spill directory is scanned for current-format entries, which
    /// are admitted newest-first (modification time, the best available
    /// recency signal across a restart) until the next entry would push
    /// resident bytes past the configured capacity; the remainder — and
    /// any unreadable or stale-format file — is skipped and stays
    /// disk-served. Admitted entries are unowned (no tenant is charged
    /// for warmth shared by everyone) and touch none of the hit/miss
    /// counters. A no-op without a disk tier.
    pub fn warm_start(&self) -> WarmStartReport {
        let mut report = WarmStartReport::default();
        let Some(dir) = &self.cfg.spill_dir else {
            return report;
        };
        // reclaim crash debris first: orphaned temp files from writers
        // that died pre-rename, and checksum-quarantined entries
        report.swept = disk::sweep_debris(dir);
        report.metrics_loaded = self.load_metrics_log();
        let mut entries = disk::scan_states(dir);
        entries.sort_by(|a, b| b.1.cmp(&a.1)); // newest first
        report.scanned = entries.len() as u64;
        let capacity = self.cfg.capacity_bytes as u64;
        for (key, _, file_len) in entries {
            // payload = file length minus header + checksum overhead
            let payload = file_len.saturating_sub(disk::ENTRY_OVERHEAD_BYTES as u64);
            if self.memory.resident_bytes() + payload > capacity {
                report.skipped += 1;
                continue;
            }
            match disk::load_state(dir, key) {
                Some(state) => {
                    let state: CachedState = Arc::new(state);
                    let bytes: usize = state.iter().map(Plane::nbytes).sum();
                    if self.memory.insert(key, state, None) {
                        report.admitted += 1;
                        report.admitted_bytes += bytes as u64;
                    } else {
                        report.skipped += 1;
                    }
                }
                None => report.skipped += 1,
            }
        }
        report
    }
}

/// What [`ReuseCache::warm_start`] found and admitted.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WarmStartReport {
    /// Current-format entries found in the spill directory.
    pub scanned: u64,
    /// Entries pre-admitted into the memory tier.
    pub admitted: u64,
    /// Bytes those entries occupy resident.
    pub admitted_bytes: u64,
    /// Entries left disk-only (capacity reached, unreadable, or already
    /// resident).
    pub skipped: u64,
    /// Crash debris reclaimed before the scan: orphaned `.tmp-*` files
    /// and checksum-quarantined `*.bad` entries.
    pub swept: u64,
    /// Comparison metrics re-loaded from the persisted metrics log.
    pub metrics_loaded: u64,
}

/// File name of the append-only comparison-metrics log kept next to the
/// disk tier's state files (see [`ReuseCache::put_metrics`]).
const METRICS_LOG: &str = "metrics.log";

/// Parse one metrics-log line (`key bits0 bits1 bits2 checksum`, all
/// hex); `None` on any malformed or checksum-failing field.
fn parse_metrics_line(line: &str) -> Option<(Key, [f32; 3])> {
    let (body, sum) = line.rsplit_once(' ')?;
    if u64::from_str_radix(sum, 16).ok()? != disk::fnv1a64(body.as_bytes()) {
        return None;
    }
    let mut fields = body.split(' ');
    let raw = u128::from_str_radix(fields.next()?, 16).ok()?;
    let mut m = [0f32; 3];
    for v in m.iter_mut() {
        *v = f32::from_bits(u32::from_str_radix(fields.next()?, 16).ok()?);
    }
    if fields.next().is_some() {
        return None;
    }
    Some((Key::from_parts((raw >> 64) as u64, raw as u64), m))
}

/// RAII holder for claimed flights: any key still held when this drops
/// (error or panic on the compute path) is released so waiters wake and
/// re-claim instead of blocking forever. Keys published via
/// [`ReuseCache::put_state`] / [`ReuseCache::put_metrics`] are already
/// released; [`FlightClaims::settle`] additionally forgets them here so
/// the drop cannot race a later claimant of the same key.
pub struct FlightClaims {
    cache: Arc<ReuseCache>,
    keys: Vec<Key>,
}

impl FlightClaims {
    pub fn new(cache: Arc<ReuseCache>) -> Self {
        Self { cache, keys: Vec::new() }
    }

    /// Track a key this caller just claimed.
    pub fn add(&mut self, key: Key) {
        self.keys.push(key);
    }

    /// The key was published (flight already released) — stop tracking.
    pub fn settle(&mut self, key: Key) {
        self.keys.retain(|&k| k != key);
    }
}

impl Drop for FlightClaims {
    fn drop(&mut self) {
        for &k in &self.keys {
            self.cache.release_flight(k);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::tier::REMOTE_TIER;

    fn state(v: f32, side: usize) -> [Plane; 3] {
        [
            Plane::filled(v, side, side),
            Plane::filled(v, side, side),
            Plane::filled(v, side, side),
        ]
    }

    fn k(v: u64) -> Key {
        Key::from(v)
    }

    fn ux() -> CacheCtx {
        CacheCtx::unscoped()
    }

    #[test]
    fn hits_share_the_resident_allocation() {
        let c = ReuseCache::with_capacity(1 << 20);
        c.put_state(k(7), state(3.0, 4), &ux());
        let a = c.get_state(k(7), &ux()).expect("hit");
        let b = c.get_state(k(7), &ux()).expect("hit");
        // zero-copy: both hits point at the same [Plane; 3] allocation
        assert!(Arc::ptr_eq(&a, &b), "cache hits must be refcount bumps");
        assert_eq!(c.resident_keys(), vec![k(7)]);
        c.put_metrics(k(9), [1.0, 1.0, 0.0]);
        assert_eq!(c.metric_keys(), vec![k(9)]);
    }

    /// Bytes of one `state(v, 4)`: 3 planes x 16 px x 4 B.
    const S4: usize = 3 * 16 * 4;

    #[test]
    fn put_get_roundtrip_and_counters() {
        let c = ReuseCache::with_capacity(1 << 20);
        assert!(c.get_state(k(1), &ux()).is_none());
        c.put_state(k(1), state(5.0, 4), &ux());
        let got = c.get_state(k(1), &ux()).expect("hit");
        assert_eq!(got[0].get(0, 0), 5.0);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.inserts), (1, 1, 1));
        assert_eq!(st.resident_bytes as usize, S4);
        assert!(c.contains_state(k(1)));
        assert!(!c.contains_state(k(2)));
    }

    #[test]
    fn keys_equal_in_the_low_64_bits_are_distinct_entries() {
        // the aliasing the 64-bit keys risked: two distinct computations
        // whose (old, truncated) keys collide. With 128-bit keys they are
        // separate entries; the old u64-keyed map stored exactly one.
        let c = ReuseCache::with_capacity(1 << 20);
        let a = Key::from_parts(0xAAAA, 0x42);
        let b = Key::from_parts(0xBBBB, 0x42);
        assert_eq!(a.lo(), b.lo(), "constructed to collide at 64 bits");
        c.put_state(a, state(1.0, 4), &ux());
        c.put_state(b, state(2.0, 4), &ux());
        assert_eq!(c.len(), 2, "no aliasing: both chains keep their state");
        assert_eq!(c.get_state(a, &ux()).unwrap()[0].get(0, 0), 1.0);
        assert_eq!(c.get_state(b, &ux()).unwrap()[0].get(0, 0), 2.0);
    }

    #[test]
    fn lru_evicts_oldest_at_the_byte_bound() {
        // one shard, room for exactly 2 states
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: 2 * S4,
            shards: 1,
            ..CacheConfig::default()
        });
        c.put_state(k(1), state(1.0, 4), &ux());
        c.put_state(k(2), state(2.0, 4), &ux());
        let _ = c.get_state(k(1), &ux()); // 1 is now more recent than 2
        c.put_state(k(3), state(3.0, 4), &ux());
        assert!(c.resident_bytes() <= 2 * S4, "bound holds: {}", c.resident_bytes());
        assert!(c.get_state(k(2), &ux()).is_none(), "LRU victim was 2");
        assert!(c.get_state(k(1), &ux()).is_some());
        assert!(c.get_state(k(3), &ux()).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn oversized_states_bypass_memory() {
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: S4 / 2,
            shards: 1,
            ..CacheConfig::default()
        });
        c.put_state(k(9), state(1.0, 4), &ux());
        assert_eq!(c.len(), 0, "state larger than the shard budget stays out");
        assert!(c.get_state(k(9), &ux()).is_none());
    }

    #[test]
    fn metrics_roundtrip() {
        let c = ReuseCache::with_capacity(1024);
        assert!(c.get_metrics(k(5), &ux()).is_none());
        c.put_metrics(k(5), [0.9, 0.8, 0.01]);
        assert_eq!(c.get_metrics(k(5), &ux()), Some([0.9, 0.8, 0.01]));
        assert!(c.contains_metrics(k(5)));
        let st = c.stats();
        assert_eq!((st.metric_hits, st.metric_misses), (1, 1));
    }

    #[test]
    fn disk_tier_serves_after_eviction() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: S4, // memory holds one state
            shards: 1,
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        c.put_state(k(1), state(1.0, 4), &ux());
        c.put_state(k(2), state(2.0, 4), &ux()); // evicts 1 from memory
        let back = c.get_state(k(1), &ux()).expect("served from disk");
        assert_eq!(back[1].get(3, 3), 1.0);
        let st = c.stats();
        assert!(st.disk_hits >= 1, "stats: {st:?}");
        assert!(st.spilled >= 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_summary_is_labeled() {
        let c = ReuseCache::with_capacity(1024);
        c.put_state(k(1), state(1.0, 2), &ux());
        let rows = c.stats().summary();
        assert!(rows.iter().any(|(key, v)| key == "cache.inserts" && *v == 1));
        assert!(rows.iter().any(|(key, _)| key == "cache.remote_hits"));
        assert_eq!(rows.len(), 11);
    }

    #[test]
    fn claim_protocol_single_thread() {
        let c = ReuseCache::with_capacity(1 << 20);
        // first lookup claims
        assert!(matches!(c.lookup_or_claim(k(1), &ux()), StateClaim::Claimed));
        // a second lookup (another worker) observes the flight
        assert!(matches!(c.lookup_or_claim(k(1), &ux()), StateClaim::InFlight));
        // publication resolves the flight; the next lookup is a hit
        c.put_state(k(1), state(1.0, 4), &ux());
        assert!(matches!(c.lookup_or_claim(k(1), &ux()), StateClaim::Ready(_)));
        // abandoned claims release: the next lookup re-claims
        assert!(matches!(c.lookup_or_claim(k(2), &ux()), StateClaim::Claimed));
        c.release_flight(k(2));
        assert!(matches!(c.lookup_or_claim(k(2), &ux()), StateClaim::Claimed));
        c.release_flight(k(2));
        let st = c.stats();
        assert_eq!(st.misses, 3, "each claim counts one miss");
        assert_eq!(st.hits, 1);
    }

    #[test]
    fn scoped_counters_mirror_globals() {
        let c = ReuseCache::with_capacity(1 << 20);
        let a = Arc::new(ScopedCounters::default());
        let b = Arc::new(ScopedCounters::default());
        let ca = CacheCtx::scoped(Arc::clone(&a));
        let cb = CacheCtx::scoped(Arc::clone(&b));
        // tenant a: one miss-claim + publish + one hit
        assert!(matches!(c.lookup_or_claim(k(1), &ca), StateClaim::Claimed));
        c.put_state(k(1), state(1.0, 4), &ca);
        assert!(c.get_state(k(1), &ca).is_some());
        // tenant b: hits a's state; one metric miss-claim + publish
        assert!(c.get_state(k(1), &cb).is_some());
        assert!(matches!(c.lookup_or_claim_metrics(k(9), &cb), MetricsClaim::Claimed));
        c.put_metrics(k(9), [1.0, 1.0, 0.0]);
        assert!(c.get_metrics(k(9), &cb).is_some());

        let (sa, sb, g) = (a.stats(), b.stats(), c.stats());
        assert_eq!((sa.misses, sa.inserts, sa.hits), (1, 1, 1));
        assert_eq!((sb.hits, sb.metric_misses, sb.metric_hits), (1, 1, 1));
        // the scopes partition the global counters exactly
        assert_eq!(sa.hits + sb.hits, g.hits);
        assert_eq!(sa.misses + sb.misses, g.misses);
        assert_eq!(sa.inserts + sb.inserts, g.inserts);
        assert_eq!(sa.metric_hits + sb.metric_hits, g.metric_hits);
        assert_eq!(sa.metric_misses + sb.metric_misses, g.metric_misses);
    }

    #[test]
    fn quota_evicts_the_owners_lru_first() {
        // plenty of shared capacity, but the tenant may own at most 2
        // states — its third insert evicts its own oldest entry
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: 1 << 20,
            shards: 1,
            ..CacheConfig::default()
        });
        let t = Arc::new(ScopedCounters::with_quota(2 * S4 as u64));
        let ct = CacheCtx::scoped(Arc::clone(&t));
        c.put_state(k(1), state(1.0, 4), &ct);
        c.put_state(k(2), state(2.0, 4), &ct);
        assert_eq!(t.resident_bytes(), 2 * S4 as u64);
        c.put_state(k(3), state(3.0, 4), &ct);
        assert_eq!(t.resident_bytes(), 2 * S4 as u64, "quota bound holds");
        assert_eq!(t.evictions(), 1);
        assert!(c.get_state(k(1), &ux()).is_none(), "the tenant's LRU entry was evicted");
        assert!(c.get_state(k(2), &ux()).is_some());
        assert!(c.get_state(k(3), &ux()).is_some());
        // another tenant is untouched by the first one's quota
        let u = Arc::new(ScopedCounters::default());
        let cu = CacheCtx::scoped(Arc::clone(&u));
        c.put_state(k(9), state(9.0, 4), &cu);
        assert_eq!(u.resident_bytes(), S4 as u64);
        assert_eq!(u.evictions(), 0);
    }

    #[test]
    fn oversized_for_quota_stays_out_of_memory() {
        let t = Arc::new(ScopedCounters::with_quota(S4 as u64 / 2));
        let ct = CacheCtx::scoped(Arc::clone(&t));
        let c = ReuseCache::with_capacity(1 << 20);
        c.put_state(k(1), state(1.0, 4), &ct);
        assert_eq!(c.len(), 0, "entry larger than the whole quota is not admitted");
        assert_eq!(t.resident_bytes(), 0);
    }

    #[test]
    fn shard_eviction_charges_the_owning_scope() {
        // one shard, room for exactly 2 states; A's entry is the LRU
        // victim of B's second insert — A is charged, not B
        let c = ReuseCache::new(CacheConfig {
            capacity_bytes: 2 * S4,
            shards: 1,
            ..CacheConfig::default()
        });
        let a = Arc::new(ScopedCounters::default());
        let b = Arc::new(ScopedCounters::default());
        let ca = CacheCtx::scoped(Arc::clone(&a));
        let cb = CacheCtx::scoped(Arc::clone(&b));
        c.put_state(k(1), state(1.0, 4), &ca);
        c.put_state(k(2), state(2.0, 4), &cb);
        c.put_state(k(3), state(3.0, 4), &cb);
        assert_eq!(a.resident_bytes(), 0, "A's entry was evicted");
        assert_eq!(a.evictions(), 1, "the eviction is charged to the owner");
        assert_eq!(b.resident_bytes(), 2 * S4 as u64);
        assert_eq!(b.evictions(), 0);
        // owners partition residency: scope sums equal the global gauge
        assert_eq!(
            a.resident_bytes() + b.resident_bytes(),
            c.resident_bytes() as u64,
            "scoped residency sums to the global counter"
        );
    }

    #[test]
    fn warm_start_preadmits_disk_entries() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-warm-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let cold = ReuseCache::new(CacheConfig {
                capacity_bytes: 1 << 20,
                spill_dir: Some(dir.clone()),
                ..CacheConfig::default()
            });
            cold.put_state(k(1), state(1.0, 4), &ux());
            cold.put_state(k(2), state(2.0, 4), &ux());
        }
        // a fresh process: nothing resident until warm_start pre-admits
        let warm = ReuseCache::new(CacheConfig {
            capacity_bytes: 1 << 20,
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        assert_eq!(warm.len(), 0);
        let report = warm.warm_start();
        assert_eq!(report.scanned, 2);
        assert_eq!(report.admitted, 2);
        assert_eq!(report.admitted_bytes, 2 * S4 as u64);
        assert_eq!(warm.len(), 2);
        // the first lookup is a MEMORY hit, not a disk read
        assert!(warm.get_state(k(1), &ux()).is_some());
        let st = warm.stats();
        assert_eq!((st.hits, st.disk_hits), (1, 0), "warm-start makes lookups memory hits");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_start_respects_capacity_and_tolerates_junk() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-warmcap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let cold = ReuseCache::new(CacheConfig {
                capacity_bytes: 1 << 20,
                spill_dir: Some(dir.clone()),
                ..CacheConfig::default()
            });
            for i in 0..4 {
                cold.put_state(k(i), state(i as f32, 4), &ux());
            }
        }
        // junk the scanner must skip without erroring
        std::fs::write(dir.join(format!("{:032x}.state", 0xbadu64)), b"XXXXjunk").unwrap();
        let warm = ReuseCache::new(CacheConfig {
            capacity_bytes: 2 * S4, // memory holds two of the four states
            shards: 1,
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        let report = warm.warm_start();
        assert_eq!(report.scanned, 5);
        assert_eq!(report.admitted, 2, "admission stops at capacity");
        assert_eq!(report.skipped, 3);
        assert!(warm.resident_bytes() <= 2 * S4);
        assert_eq!(warm.stats().evictions, 0, "warm-start never thrashes the LRU");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn warm_start_sweeps_crash_debris_and_counts_it() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-sweep-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let cold = ReuseCache::new(CacheConfig {
                spill_dir: Some(dir.clone()),
                ..CacheConfig::default()
            });
            cold.put_state(k(1), state(1.0, 4), &ux());
        }
        // debris a mid-write death leaves behind: an orphaned temp file
        // and a quarantined (checksum-failed) entry
        std::fs::write(dir.join(".tmp-1234-0-00000000000000000000000000000009"), b"torn")
            .unwrap();
        std::fs::write(dir.join(format!("{:032x}.bad", 9u64)), b"RTC3bad").unwrap();
        let warm = ReuseCache::new(CacheConfig {
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        let report = warm.warm_start();
        assert_eq!(report.swept, 2, "orphan + quarantined entry reclaimed");
        assert_eq!(report.scanned, 1);
        assert_eq!(report.admitted, 1, "live entries unaffected by the sweep");
        assert_eq!(warm.warm_start().swept, 0, "sweep is idempotent");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metrics_persist_across_a_restart() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-mlog-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CacheConfig { spill_dir: Some(dir.clone()), ..CacheConfig::default() };
        {
            let cold = ReuseCache::new(cfg.clone());
            cold.put_metrics(k(5), [0.75, 0.5, 0.125]);
            cold.put_metrics(k(6), [1.0, -0.0, f32::MIN_POSITIVE]);
            cold.put_metrics(k(5), [0.75, 0.5, 0.125]); // re-publication: no extra line
        }
        let warm = ReuseCache::new(cfg);
        assert!(warm.get_metrics(k(5), &ux()).is_none(), "nothing resident before warm start");
        let report = warm.warm_start();
        assert_eq!(report.metrics_loaded, 2);
        assert_eq!(warm.get_metrics(k(5), &ux()), Some([0.75, 0.5, 0.125]));
        let m6 = warm.get_metrics(k(6), &ux()).expect("second entry loaded");
        assert_eq!(m6[1].to_bits(), (-0.0f32).to_bits(), "bit-exact through the log");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_metrics_log_tail_stops_the_load() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-mtorn-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CacheConfig { spill_dir: Some(dir.clone()), ..CacheConfig::default() };
        {
            let cold = ReuseCache::new(cfg.clone());
            cold.put_metrics(k(1), [0.1, 0.2, 0.3]);
            cold.put_metrics(k(2), [0.4, 0.5, 0.6]);
        }
        // crash mid-append: truncate the log inside the last line
        let log = dir.join("metrics.log");
        let mut bytes = std::fs::read(&log).unwrap();
        bytes.truncate(bytes.len() - 10);
        std::fs::write(&log, &bytes).unwrap();
        let warm = ReuseCache::new(cfg);
        let report = warm.warm_start();
        assert_eq!(report.metrics_loaded, 1, "the torn tail is not trusted");
        assert!(warm.get_metrics(k(1), &ux()).is_some());
        assert!(warm.get_metrics(k(2), &ux()).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bounded_flight_wait_times_out_and_recovers() {
        let c = Arc::new(ReuseCache::with_capacity(1 << 20));
        assert!(matches!(c.lookup_or_claim(k(1), &ux()), StateClaim::Claimed));
        // the claim holder is wedged: a bounded waiter gives up…
        let t0 = Instant::now();
        assert!(!c.wait_for_flight_for(k(1), Duration::from_millis(50)));
        assert!(t0.elapsed() >= Duration::from_millis(50));
        // …and a publication wakes a bounded waiter well before its deadline
        let publisher = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                c.put_state(k(1), state(1.0, 4), &CacheCtx::unscoped());
            })
        };
        assert!(c.wait_for_flight_for(k(1), Duration::from_secs(30)));
        publisher.join().unwrap();
        assert!(matches!(c.lookup_or_claim(k(1), &ux()), StateClaim::Ready(_)));
        // no flight at all: an immediate true
        assert!(c.wait_for_flight_for(k(7), Duration::from_millis(1)));
    }

    #[test]
    fn tier_stats_lists_the_stack_in_order() {
        let dir = std::env::temp_dir().join(format!("rtf-cache-tstats-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let c = ReuseCache::new(CacheConfig {
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        c.put_state(k(1), state(1.0, 4), &ux());
        let rows = c.tier_stats();
        assert_eq!(rows[0].0, MEMORY_TIER);
        assert_eq!(rows[1].0, DISK_TIER);
        assert_eq!(rows[0].1.stores, 1);
        assert_eq!(rows[1].1.stores, 1);
        assert_eq!(rows[1].1.breaker_opens, 0, "no breaker on the disk tier");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn flight_claims_release_on_drop() {
        let c = Arc::new(ReuseCache::with_capacity(1 << 20));
        {
            let mut claims = FlightClaims::new(c.clone());
            assert!(matches!(c.lookup_or_claim(k(5), &ux()), StateClaim::Claimed));
            claims.add(k(5));
            // simulated error path: claims dropped without publishing
        }
        // the flight is gone: a new worker can claim
        assert!(matches!(c.lookup_or_claim(k(5), &ux()), StateClaim::Claimed));
        c.release_flight(k(5));
    }

    #[test]
    fn warm_started_entries_are_visible_through_tier_trait_objects() {
        // satellite: warm-start must interoperate with the trait-object
        // view of the stack — entries pre-admitted at boot serve through
        // &dyn CacheTier exactly like entries inserted through the API
        let dir = std::env::temp_dir().join(format!("rtf-cache-warmtier-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let cold = ReuseCache::new(CacheConfig {
                capacity_bytes: 1 << 20,
                spill_dir: Some(dir.clone()),
                ..CacheConfig::default()
            });
            cold.put_state(k(1), state(1.0, 4), &ux());
        }
        let warm = ReuseCache::new(CacheConfig {
            capacity_bytes: 1 << 20,
            spill_dir: Some(dir.clone()),
            ..CacheConfig::default()
        });
        warm.warm_start();
        let memory: &dyn CacheTier = warm.memory_tier();
        assert_eq!(memory.name(), MEMORY_TIER);
        let served = memory.lookup(k(1), &ux()).expect("warm entry via the trait object");
        assert_eq!(served[0].get(0, 0), 1.0);
        assert!(memory.stats().hits >= 1, "tier-local hit counted");
        // the disk tier object below it also serves the same entry
        let tiers = warm.tiers();
        assert_eq!(tiers.len(), 1);
        assert_eq!(tiers[0].name(), DISK_TIER);
        assert!(tiers[0].lookup(k(1), &ux()).is_some(), "disk tier via the trait object");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A lower tier living in a test-controlled map, attached under the
    /// remote name — exercises the stack's name-keyed counter routing
    /// and write-through without a network.
    struct MapTier {
        map: Mutex<HashMap<Key, CachedState>>,
    }

    impl MapTier {
        fn new() -> Self {
            Self { map: Mutex::new(HashMap::new()) }
        }
    }

    impl CacheTier for MapTier {
        fn name(&self) -> &'static str {
            REMOTE_TIER
        }
        fn lookup(&self, key: Key, _ctx: &CacheCtx) -> Option<CachedState> {
            self.map.lock().unwrap().get(&key).cloned()
        }
        fn store(&self, key: Key, state: &CachedState, _ctx: &CacheCtx) -> bool {
            self.map.lock().unwrap().insert(key, Arc::clone(state)).is_none()
        }
        fn evict_scope(&self, _scope: &Arc<ScopedCounters>) -> bool {
            false
        }
        fn stats(&self) -> TierStats {
            TierStats::default()
        }
    }

    #[test]
    fn attached_tier_hits_bill_as_remote_and_promote_into_memory() {
        let c = ReuseCache::with_capacity(1 << 20);
        let tier = Arc::new(MapTier::new());
        tier.map.lock().unwrap().insert(k(1), Arc::new(state(1.0, 4)));
        c.attach_tier(Arc::clone(&tier) as Arc<dyn CacheTier>);

        let scope = Arc::new(ScopedCounters::default());
        let ctx = CacheCtx::scoped(Arc::clone(&scope));
        // the miss falls through memory to the attached tier
        assert!(matches!(c.lookup_or_claim(k(1), &ctx), StateClaim::Ready(_)));
        let st = c.stats();
        assert_eq!((st.hits, st.remote_hits, st.misses), (0, 1, 0));
        assert_eq!(scope.stats().remote_hits, 1, "billed under the requesting scope");
        // the hit was promoted: the next lookup is a memory hit
        assert!(c.get_state(k(1), &ctx).is_some());
        assert_eq!(c.stats().hits, 1);
        assert!(st.inserts == 0, "promotion is not publication");
        // publications write through to the attached tier
        c.put_state(k(2), state(2.0, 4), &ctx);
        assert!(tier.map.lock().unwrap().contains_key(&k(2)), "write-through on publish");
        // ...but what the remote tier stored never bumps local inserts
        assert_eq!(c.stats().inserts, 1, "one local publication, one insert");
    }

    #[test]
    fn attached_tier_miss_does_not_poison_single_flight() {
        // satellite: a remote-tier miss must fall through to a local
        // launch (Claimed) and leave the flight protocol fully usable
        let c = ReuseCache::with_capacity(1 << 20);
        c.attach_tier(Arc::new(MapTier::new()));
        assert!(matches!(c.lookup_or_claim(k(3), &ux()), StateClaim::Claimed));
        assert!(matches!(c.lookup_or_claim(k(3), &ux()), StateClaim::InFlight));
        c.put_state(k(3), state(3.0, 4), &ux());
        assert!(matches!(c.lookup_or_claim(k(3), &ux()), StateClaim::Ready(_)));
        let st = c.stats();
        assert_eq!((st.misses, st.hits), (1, 1));
    }

    #[test]
    fn remote_claims_single_flight_across_the_wire_boundary() {
        // the owner side of the cluster fabric: the first cache-get for
        // an absent key claims; a concurrent one blocks until the
        // requester's cache-put settles the claim, then serves the state
        let c = Arc::new(ReuseCache::with_capacity(1 << 20));
        match c.serve_remote_get(k(1)) {
            RemoteServe::Claimed => {}
            RemoteServe::Found(_) => panic!("nothing cached yet"),
        }
        let waiter = {
            let c = Arc::clone(&c);
            std::thread::spawn(move || c.serve_remote_get(k(1)))
        };
        std::thread::sleep(Duration::from_millis(50));
        assert!(c.serve_remote_put(k(1), state(1.0, 4)), "put admits the state");
        match waiter.join().expect("waiter thread") {
            RemoteServe::Found(s) => assert_eq!(s[0].get(0, 0), 1.0),
            RemoteServe::Claimed => panic!("the settle must wake the waiter with the state"),
        }
        // once cached, gets serve immediately
        assert!(matches!(c.serve_remote_get(k(1)), RemoteServe::Found(_)));
    }

    #[test]
    fn remote_serving_paths_are_stat_invisible() {
        // the owner answering peers must not disturb its own billing:
        // scoped sums == globals stays true on every node of a cluster
        let c = ReuseCache::with_capacity(1 << 20);
        assert!(c.serve_remote_put(k(8), state(8.0, 4)));
        assert!(matches!(c.serve_remote_get(k(8)), RemoteServe::Found(_)));
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.inserts, st.remote_hits), (0, 0, 0, 0));
        assert_eq!(c.len(), 1, "the peer-published entry is resident");
        // and the entry is unowned: no scope is ever charged for it
        let t = Arc::new(ScopedCounters::with_quota(1));
        c.memory_tier().enforce_quota(&t);
        assert_eq!(t.evictions(), 0);
    }
}
