//! Cross-study persistent reuse cache (content-addressed task
//! memoization).
//!
//! The paper exploits recurrence *within* one planned study: the compact
//! graph collapses identical stage instances, and per-bucket reuse trees
//! execute shared task prefixes once (§3). But SA workloads are recurrent
//! *across* studies too — MOAT screens feed VBD refinements, tuning loops
//! re-run overlapping designs, and successive SA iterations re-execute
//! most of their task chains (arXiv:1910.14548 measures the biggest wins
//! there). This module makes that reuse first-class:
//!
//! * [`key`] — content-addressed 128-bit keys ([`Key`]): tile-content
//!   fingerprint chained through the (optionally quantized) signature of
//!   every executed task. Keys are stable across studies, seeds,
//!   processes and tenants; the width gives the collision margin a
//!   process-lifetime multi-tenant cache needs.
//! * [`tier`] — the composable storage abstraction: every tier of the
//!   cache implements [`CacheTier`] (lookup / store / evict-scope /
//!   stats), and every cache call carries one [`CacheCtx`] — the
//!   collapsed accounting context naming the tenant scope the operation
//!   bills to.
//! * [`ReuseCache`] — the tier *stack*: a sharded, byte-bounded LRU
//!   memory tier over 3-plane states, composed over any number of lower
//!   tiers — the write-through RTC2 disk tier for persistence and, in
//!   cluster mode, the [`RemoteTier`] — plus a side map of cached
//!   comparison metrics. Concurrency-safe by design: zero-copy `Arc`
//!   hits, single-flight miss claims
//!   ([`ReuseCache::lookup_or_claim`]) so concurrent studies never
//!   duplicate a backend launch, and per-tenant [`ScopedCounters`]
//!   that sum exactly to the global [`CacheStats`]. Scopes built with
//!   [`ScopedCounters::with_quota`] bound how much of the shared memory
//!   tier a tenant's entries may occupy (quota-aware admission; each
//!   eviction is charged to the entry's *owning* scope), and
//!   [`ReuseCache::warm_start`] pre-admits persisted disk-tier entries
//!   at process start so the first lookups of the day are memory hits.
//! * [`remote`] — the cluster fabric: [`RemoteTier`] rendezvous-hashes
//!   the 128-bit key space across the peer list ([`PeerRing`]) and, for
//!   keys another node owns, fetches and publishes entries over the
//!   serve wire protocol (`cache-get` / `cache-put`, rtfp v3). The
//!   owner side ([`ReuseCache::serve_remote_get`] /
//!   [`ReuseCache::serve_remote_put`]) extends single-flight claims
//!   across the remote boundary, so two nodes never duplicate a launch.
//!
//! Integration points: [`crate::runtime::PjrtEngine`] consults/populates
//! the cache at task granularity, [`crate::coordinator`] shares one cache
//! across worker threads and fingerprints tiles/references,
//! [`crate::merging::prune_cached`] subtracts already-cached prefixes
//! from unit costs at planning time, [`crate::config::CacheSettings`]
//! exposes the knobs, and [`crate::serve`] holds one process-lifetime
//! cache across every tenant's studies.
//!
//! Cost model: a cache-cold run pays for its future reuse — every task
//! miss materializes the output state host-side for insertion (plus a
//! synchronous disk write when the persistent tier is on), where
//! cache-off execution keeps states device-resident along the chain.
//! The two-study bench reports this cold overhead explicitly; enable the
//! cache when studies recur, not for strict one-shots. With `quantize`
//! \> 0 reuse is approximate and first-writer-wins (see
//! [`crate::config::CacheSettings::quantize`]); `quantize = 0` reuse is
//! exact and changes no results.

pub mod key;
pub mod remote;
pub mod tier;

mod disk;
mod store;

pub use disk::DiskTier;
pub use key::{
    candidate_key, chain_key, content_fingerprint, fold_keys, metrics_key, node_input_key,
    quantize, reference_fingerprints, task_cache_sig, tile_fingerprints, Key,
};
pub use remote::{PeerRing, RemoteTier, HOT_WATERMARK};
pub use store::{
    CacheConfig, CacheStats, CachedState, FlightClaims, MemoryTier, MetricsClaim, RemoteServe,
    ReuseCache, ScopedCounters, StateClaim, WarmStartReport,
};
pub use tier::{CacheCtx, CacheTier, TierStats};
