//! The persistent disk tier of the reuse cache.
//!
//! Entries are written write-through as one file per key under the
//! configured directory, so cached states survive process restarts and
//! are shared between studies run at different times (the cross-study
//! "persistent" in the cache's name). The format is self-describing and
//! versioned; unreadable, truncated or *stale-version* files are treated
//! as misses, never as errors — the cache is an accelerator, not a
//! source of truth.
//!
//! # Format versioning
//!
//! The current format is `RTC2`: 128-bit keys, file names of 32 hex
//! digits (`{key:032x}.state`). The pre-widening `RTC1` format used
//! 64-bit keys and 16-hex names; a spill directory may legitimately hold
//! both after an upgrade. Version handling is explicit rather than
//! accidental:
//!
//! * [`has_state`] / [`load_state`] accept only current-version files —
//!   a stale file at a probed path reads as a miss, not garbage.
//! * [`store_state`] *overwrites* a stale-version file parked at the
//!   key's path; without this, a stale file would both refuse to load
//!   and block re-publication, pinning the key to a permanent miss.
//! * Old-format files at old-format paths are simply never probed (the
//!   name widths differ) and age out with the directory.

use std::io::Read;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::data::Plane;

use super::key::Key;
use super::store::{CachedState, ScopedCounters};
use super::tier::{CacheCtx, CacheTier, TierStats, DISK_TIER};

/// The persistent tier as a [`CacheTier`]: wraps this module's
/// free functions behind the trait the cache stack composes. The stack
/// keys its counter mapping on [`CacheTier::name`] — a hit from this
/// tier is billed as `disk_hits`, a fresh store as `spilled`.
pub struct DiskTier {
    dir: PathBuf,
    hits: AtomicU64,
    stores: AtomicU64,
}

impl DiskTier {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), hits: AtomicU64::new(0), stores: AtomicU64::new(0) }
    }

    /// The spill directory this tier reads and writes.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl CacheTier for DiskTier {
    fn name(&self) -> &'static str {
        DISK_TIER
    }

    fn lookup(&self, key: Key, _ctx: &CacheCtx) -> Option<CachedState> {
        let state = load_state(&self.dir, key)?;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(Arc::new(state))
    }

    fn store(&self, key: Key, state: &CachedState, _ctx: &CacheCtx) -> bool {
        // Ok(false) (already present) and write errors are both "not
        // newly stored"; the disk is an accelerator, not a ledger.
        if matches!(store_state(&self.dir, key, state), Ok(true)) {
            self.stores.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    fn evict_scope(&self, _scope: &Arc<ScopedCounters>) -> bool {
        false // the disk tier has no scoped residency to reclaim
    }

    fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            resident_bytes: 0,
        }
    }
}

/// File magic + format version. `RTC1` was the 64-bit-key format; bump
/// this whenever the on-disk layout or the key derivation changes
/// incompatibly, so stale entries are invalidated rather than misread.
const MAGIC: &[u8; 4] = b"RTC2";

/// Discriminator for temp-file names (concurrent writers never collide).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// One 3-plane state as stored on disk.
pub(crate) fn state_path(dir: &Path, key: Key) -> PathBuf {
    dir.join(format!("{:032x}.state", key.as_u128()))
}

/// True when the file at `path` starts with the current-version magic.
fn is_current_version(path: &Path) -> bool {
    let mut magic = [0u8; 4];
    match std::fs::File::open(path) {
        Ok(mut f) => f.read_exact(&mut magic).is_ok() && &magic == MAGIC,
        Err(_) => false,
    }
}

/// True when the key has a current-version on-disk entry (magic check,
/// no content check).
pub(crate) fn has_state(dir: &Path, key: Key) -> bool {
    is_current_version(&state_path(dir, key))
}

/// Write a state for `key`, atomically (temp file + rename). Returns
/// `Ok(false)` when a current-version entry was already present; a
/// stale-version file at the path is overwritten.
pub(crate) fn store_state(dir: &Path, key: Key, state: &[Plane; 3]) -> std::io::Result<bool> {
    let path = state_path(dir, key);
    if path.exists() && is_current_version(&path) {
        return Ok(false);
    }
    std::fs::create_dir_all(dir)?;
    let mut bytes: Vec<u8> = Vec::with_capacity(16 + state[0].nbytes() * 3);
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&(state[0].height() as u32).to_le_bytes());
    bytes.extend_from_slice(&(state[0].width() as u32).to_le_bytes());
    for plane in state {
        for v in plane.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    let tmp = dir.join(format!(
        ".tmp-{}-{}-{:032x}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        key.as_u128()
    ));
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, &path)?;
    Ok(true)
}

/// Scan a spill directory for current-format entries: every
/// `{key:032x}.state` file, with its modification time and byte length.
/// Used by the service's warm-start pass to pre-admit recently written
/// states into the memory tier. Unreadable entries, foreign files and
/// old-format (16-hex) names are skipped silently; the magic of each
/// candidate is checked later by [`load_state`], not here.
pub(crate) fn scan_states(dir: &Path) -> Vec<(Key, std::time::SystemTime, u64)> {
    let mut out = Vec::new();
    let Ok(read) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in read.flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("state") {
            continue;
        }
        let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else {
            continue;
        };
        if stem.len() != 32 {
            continue; // old-format (16-hex) or foreign name
        }
        let Ok(raw) = u128::from_str_radix(stem, 16) else {
            continue;
        };
        let Ok(meta) = entry.metadata() else {
            continue;
        };
        let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        out.push((Key::from_parts((raw >> 64) as u64, raw as u64), mtime, meta.len()));
    }
    out
}

/// Load the state for `key`, if present, current-version and well-formed.
pub(crate) fn load_state(dir: &Path, key: Key) -> Option<[Plane; 3]> {
    let bytes = std::fs::read(state_path(dir, key)).ok()?;
    if bytes.len() < 12 || &bytes[..4] != MAGIC {
        return None;
    }
    let h = u32::from_le_bytes(bytes[4..8].try_into().ok()?) as usize;
    let w = u32::from_le_bytes(bytes[8..12].try_into().ok()?) as usize;
    if bytes.len() != 12 + 3 * h * w * 4 {
        return None;
    }
    let mut planes = Vec::with_capacity(3);
    for p in 0..3 {
        let start = 12 + p * h * w * 4;
        let data: Vec<f32> = bytes[start..start + h * w * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        planes.push(Plane::new(data, h, w).ok()?);
    }
    let mut it = planes.into_iter();
    Some([it.next()?, it.next()?, it.next()?])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rtf-cache-disk-{tag}-{}", std::process::id()))
    }

    fn state(v: f32) -> [Plane; 3] {
        [Plane::filled(v, 3, 2), Plane::filled(v + 1.0, 3, 2), Plane::filled(v + 2.0, 3, 2)]
    }

    fn k(v: u64) -> Key {
        Key::from(v)
    }

    #[test]
    fn roundtrip_and_idempotent_store() {
        let dir = tmp_dir("rt");
        let s = state(4.0);
        assert!(store_state(&dir, k(0xabc), &s).unwrap(), "first store is new");
        assert!(!store_state(&dir, k(0xabc), &s).unwrap(), "second store is a no-op");
        assert!(has_state(&dir, k(0xabc)));
        let loaded = load_state(&dir, k(0xabc)).unwrap();
        assert_eq!(loaded[0].get(2, 1), 4.0);
        assert_eq!(loaded[2].get(0, 0), 6.0);
        assert!(load_state(&dir, k(0xdef)).is_none(), "absent key misses");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_files_read_as_misses() {
        let dir = tmp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(state_path(&dir, k(7)), b"RTC2garbage").unwrap();
        assert!(load_state(&dir, k(7)).is_none());
        std::fs::write(state_path(&dir, k(8)), b"XXXX").unwrap();
        assert!(load_state(&dir, k(8)).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keys_differing_only_in_the_high_half_store_separately() {
        let dir = tmp_dir("hi-lo");
        let a = Key::from_parts(1, 42);
        let b = Key::from_parts(2, 42);
        store_state(&dir, a, &state(1.0)).unwrap();
        store_state(&dir, b, &state(9.0)).unwrap();
        assert_eq!(load_state(&dir, a).unwrap()[0].get(0, 0), 1.0);
        assert_eq!(load_state(&dir, b).unwrap()[0].get(0, 0), 9.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_lists_current_format_entries_only() {
        let dir = tmp_dir("scan");
        std::fs::create_dir_all(&dir).unwrap();
        store_state(&dir, k(1), &state(1.0)).unwrap();
        store_state(&dir, Key::from_parts(9, 2), &state(2.0)).unwrap();
        // noise the scan must skip: old-format name, foreign file, junk hex
        std::fs::write(dir.join(format!("{:016x}.state", 3u64)), b"RTC1old").unwrap();
        std::fs::write(dir.join("notes.txt"), b"hello").unwrap();
        std::fs::write(dir.join(format!("{:0>32}.state", "zz")), b"RTC2").unwrap();
        let mut keys: Vec<Key> = scan_states(&dir).iter().map(|(k, _, _)| *k).collect();
        keys.sort_unstable();
        assert_eq!(keys, vec![k(1), Key::from_parts(9, 2)]);
        let (_, _, len) = scan_states(&dir)[0];
        assert_eq!(len as usize, 12 + 3 * 6 * 4, "scan reports the file length");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_version_dir_ignores_and_reclaims_stale_entries() {
        let dir = tmp_dir("mixed");
        std::fs::create_dir_all(&dir).unwrap();
        let key = k(0xfeed);

        // a pre-widening RTC1 file under its old 16-hex name: never
        // probed (name widths differ), never an error
        std::fs::write(dir.join(format!("{:016x}.state", 0xfeedu64)), b"RTC1oldpayload")
            .unwrap();
        assert!(!has_state(&dir, key), "old-format file must not read as a hit");
        assert!(load_state(&dir, key).is_none());

        // a stale-version file parked at the CURRENT path (e.g. a future
        // downgrade/upgrade cycle): ignored on read, overwritten on store
        std::fs::write(state_path(&dir, key), b"RTC1staleblob").unwrap();
        assert!(!has_state(&dir, key), "stale magic must not read as a hit");
        assert!(load_state(&dir, key).is_none(), "stale magic must not be misread");
        assert!(
            store_state(&dir, key, &state(3.0)).unwrap(),
            "store must reclaim a stale-version path, not treat it as present"
        );
        assert!(has_state(&dir, key));
        assert_eq!(load_state(&dir, key).unwrap()[0].get(0, 0), 3.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
