//! The persistent disk tier of the reuse cache.
//!
//! Entries are written write-through as one file per key under the
//! configured directory, so cached states survive process restarts and
//! are shared between studies run at different times (the cross-study
//! "persistent" in the cache's name). The format is self-describing and
//! versioned; unreadable, truncated or *stale-version* files are treated
//! as misses, never as errors — the cache is an accelerator, not a
//! source of truth.
//!
//! # Crash safety
//!
//! A process can die at any instruction, so every store is
//! temp-file → `fsync` → atomic rename: the final `.state` name only
//! ever points at fully durable bytes, and a crash mid-write leaves at
//! worst an orphaned `.tmp-*` file (swept and counted at the next
//! warm start — [`sweep_debris`]). Against the failure the rename
//! cannot rule out — bytes torn *before* the fsync by a dying kernel,
//! or rotted afterwards — every entry carries a trailing FNV-1a-64
//! checksum verified on load; an entry whose checksum does not match
//! is **quarantined** (renamed to `{key}.bad`, reclaimed at warm
//! start) and reads as a miss, so one bad sector can never wedge a key
//! or serve corrupt planes.
//!
//! # Format versioning
//!
//! The current format is `RTC3`: 128-bit keys, file names of 32 hex
//! digits (`{key:032x}.state`), checksummed payload. `RTC2` was the
//! same layout without the checksum; the pre-widening `RTC1` format
//! used 64-bit keys and 16-hex names. A spill directory may
//! legitimately hold all three after upgrades. Version handling is
//! explicit rather than accidental:
//!
//! * [`has_state`] / [`load_state`] accept only current-version files —
//!   a stale file at a probed path reads as a miss, not garbage.
//! * [`store_state`] *overwrites* a stale-version file parked at the
//!   key's path; without this, a stale file would both refuse to load
//!   and block re-publication, pinning the key to a permanent miss.
//! * Old-format files at old-format paths are simply never probed (the
//!   name widths differ) and age out with the directory.

use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::data::Plane;
use crate::faults::{DiskFault, Faults};

use super::key::Key;
use super::store::{CachedState, ScopedCounters};
use super::tier::{CacheCtx, CacheTier, TierStats, DISK_TIER};

/// The persistent tier as a [`CacheTier`]: wraps this module's
/// free functions behind the trait the cache stack composes. The stack
/// keys its counter mapping on [`CacheTier::name`] — a hit from this
/// tier is billed as `disk_hits`, a fresh store as `spilled`.
pub struct DiskTier {
    dir: PathBuf,
    faults: Faults,
    hits: AtomicU64,
    stores: AtomicU64,
}

impl DiskTier {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self {
            dir: dir.into(),
            faults: Faults::none(),
            hits: AtomicU64::new(0),
            stores: AtomicU64::new(0),
        }
    }

    /// Install a fault hook consulted on every store attempt
    /// ([`crate::faults::FaultHook::on_disk_store`]).
    pub fn with_faults(mut self, faults: Faults) -> Self {
        self.faults = faults;
        self
    }

    /// The spill directory this tier reads and writes.
    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

impl CacheTier for DiskTier {
    fn name(&self) -> &'static str {
        DISK_TIER
    }

    fn lookup(&self, key: Key, _ctx: &CacheCtx) -> Option<CachedState> {
        let state = load_state(&self.dir, key)?;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(Arc::new(state))
    }

    fn store(&self, key: Key, state: &CachedState, _ctx: &CacheCtx) -> bool {
        let fault = self.faults.get().and_then(|h| h.on_disk_store());
        // Ok(false) (already present) and write errors are both "not
        // newly stored"; the disk is an accelerator, not a ledger.
        if matches!(store_state_faulted(&self.dir, key, state, fault), Ok(true)) {
            self.stores.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    fn evict_scope(&self, _scope: &Arc<ScopedCounters>) -> bool {
        false // the disk tier has no scoped residency to reclaim
    }

    fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            ..TierStats::default()
        }
    }
}

/// File magic + format version. `RTC1` was the 64-bit-key format,
/// `RTC2` the 128-bit format without a checksum; bump this whenever the
/// on-disk layout or the key derivation changes incompatibly, so stale
/// entries are invalidated rather than misread.
const MAGIC: &[u8; 4] = b"RTC3";

/// Bytes before the plane payload: magic + height(u32 LE) + width(u32 LE).
const HEADER_BYTES: usize = 12;

/// Fixed overhead of one entry: header plus the trailing FNV-1a-64
/// checksum (8 bytes LE, computed over header + payload).
pub(crate) const ENTRY_OVERHEAD_BYTES: usize = HEADER_BYTES + 8;

/// Discriminator for temp-file names (concurrent writers never collide).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// FNV-1a over 64 bits — the entry checksum (and the metrics-log line
/// checksum in [`super::store`]). Not cryptographic; it guards against
/// torn writes and bit rot, not adversaries (the spill dir is trusted,
/// same trust model as the cluster fabric).
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        hash ^= u64::from(b);
        hash = hash.wrapping_mul(0x1000_0000_01b3);
    }
    hash
}

/// One 3-plane state as stored on disk.
pub(crate) fn state_path(dir: &Path, key: Key) -> PathBuf {
    dir.join(format!("{:032x}.state", key.as_u128()))
}

/// Where a corrupt entry is parked ([`quarantine`]).
fn bad_path(dir: &Path, key: Key) -> PathBuf {
    dir.join(format!("{:032x}.bad", key.as_u128()))
}

/// True when the file at `path` starts with the current-version magic.
fn is_current_version(path: &Path) -> bool {
    let mut magic = [0u8; 4];
    match std::fs::File::open(path) {
        Ok(mut f) => f.read_exact(&mut magic).is_ok() && &magic == MAGIC,
        Err(_) => false,
    }
}

/// True when the key has a current-version on-disk entry (magic check,
/// no content check).
pub(crate) fn has_state(dir: &Path, key: Key) -> bool {
    is_current_version(&state_path(dir, key))
}

/// Park a corrupt current-version entry at `{key}.bad` so it stops
/// answering probes (and stops blocking re-publication) but survives
/// for post-mortem until the next warm-start sweep reclaims it.
fn quarantine(dir: &Path, key: Key) {
    let _ = std::fs::rename(state_path(dir, key), bad_path(dir, key));
}

/// Write a state for `key` durably: serialize with a trailing checksum,
/// write to a temp file, `fsync`, then atomically rename into place.
/// Returns `Ok(false)` when a current-version entry was already
/// present; a stale-version file at the path is overwritten.
pub(crate) fn store_state(dir: &Path, key: Key, state: &[Plane; 3]) -> std::io::Result<bool> {
    store_state_faulted(dir, key, state, None)
}

/// [`store_state`] with an optional scripted fault applied:
/// [`DiskFault::IoError`] fails the store outright;
/// [`DiskFault::ShortWrite`] persists a *torn* entry under the final
/// name (truncated payload, stale checksum — what a crash between
/// write-out and fsync leaves behind) and reports success, so the
/// corruption is only caught by the next lookup's checksum pass.
fn store_state_faulted(
    dir: &Path,
    key: Key,
    state: &[Plane; 3],
    fault: Option<DiskFault>,
) -> std::io::Result<bool> {
    let path = state_path(dir, key);
    if path.exists() && is_current_version(&path) {
        return Ok(false);
    }
    if let Some(DiskFault::IoError) = fault {
        return Err(std::io::Error::other("fault injection: scripted disk I/O error"));
    }
    std::fs::create_dir_all(dir)?;
    let mut bytes: Vec<u8> = Vec::with_capacity(ENTRY_OVERHEAD_BYTES + state[0].nbytes() * 3);
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&(state[0].height() as u32).to_le_bytes());
    bytes.extend_from_slice(&(state[0].width() as u32).to_le_bytes());
    for plane in state {
        for v in plane.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    bytes.extend_from_slice(&fnv1a64(&bytes).to_le_bytes());
    if let Some(DiskFault::ShortWrite) = fault {
        bytes.truncate(bytes.len() / 2);
    }
    let tmp = dir.join(format!(
        ".tmp-{}-{}-{:032x}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed),
        key.as_u128()
    ));
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&bytes)?;
        // The rename below only orders the *name*; the data must be
        // durable first or a crash can publish a torn entry.
        f.sync_all()?;
    }
    std::fs::rename(&tmp, &path)?;
    // Make the rename itself durable (the directory holds the name).
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(true)
}

/// Remove write debris from a spill directory: orphaned `.tmp-*` files
/// (a writer died pre-rename) and quarantined `*.bad` entries (a
/// checksum caught corruption). Returns how many files were reclaimed.
/// Called from the warm-start pass, which assumes — like warm start
/// itself — that no other process is writing the directory at boot.
pub(crate) fn sweep_debris(dir: &Path) -> u64 {
    let Ok(read) = std::fs::read_dir(dir) else {
        return 0;
    };
    let mut swept = 0;
    for entry in read.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let Some(name) = name.to_str() else {
            continue;
        };
        let is_debris =
            name.starts_with(".tmp-") || path.extension().and_then(|e| e.to_str()) == Some("bad");
        if is_debris && std::fs::remove_file(&path).is_ok() {
            swept += 1;
        }
    }
    swept
}

/// Scan a spill directory for current-format entries: every
/// `{key:032x}.state` file, with its modification time and byte length.
/// Used by the service's warm-start pass to pre-admit recently written
/// states into the memory tier. Unreadable entries, foreign files and
/// old-format (16-hex) names are skipped silently; the magic and
/// checksum of each candidate are checked later by [`load_state`], not
/// here.
pub(crate) fn scan_states(dir: &Path) -> Vec<(Key, std::time::SystemTime, u64)> {
    let mut out = Vec::new();
    let Ok(read) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in read.flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some("state") {
            continue;
        }
        let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else {
            continue;
        };
        if stem.len() != 32 {
            continue; // old-format (16-hex) or foreign name
        }
        let Ok(raw) = u128::from_str_radix(stem, 16) else {
            continue;
        };
        let Ok(meta) = entry.metadata() else {
            continue;
        };
        let mtime = meta.modified().unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        out.push((Key::from_parts((raw >> 64) as u64, raw as u64), mtime, meta.len()));
    }
    out
}

/// Load the state for `key`, if present, current-version, well-formed
/// and checksum-clean. A current-version entry that fails validation
/// (truncated, wrong length, checksum mismatch) is quarantined on the
/// spot — see the module docs — and reads as a miss; a stale-version
/// file is left in place for [`store_state`] to reclaim.
pub(crate) fn load_state(dir: &Path, key: Key) -> Option<[Plane; 3]> {
    let bytes = std::fs::read(state_path(dir, key)).ok()?;
    if bytes.len() < 4 || &bytes[..4] != MAGIC {
        return None; // stale version or foreign bytes: a plain miss
    }
    if bytes.len() < ENTRY_OVERHEAD_BYTES {
        quarantine(dir, key);
        return None;
    }
    let (body, sum) = bytes.split_at(bytes.len() - 8);
    if fnv1a64(body) != u64::from_le_bytes(sum.try_into().ok()?) {
        quarantine(dir, key);
        return None;
    }
    let h = u32::from_le_bytes(bytes[4..8].try_into().ok()?) as usize;
    let w = u32::from_le_bytes(bytes[8..12].try_into().ok()?) as usize;
    if bytes.len() != ENTRY_OVERHEAD_BYTES + 3 * h * w * 4 {
        quarantine(dir, key);
        return None;
    }
    let mut planes = Vec::with_capacity(3);
    for p in 0..3 {
        let start = HEADER_BYTES + p * h * w * 4;
        let data: Vec<f32> = bytes[start..start + h * w * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        planes.push(Plane::new(data, h, w).ok()?);
    }
    let mut it = planes.into_iter();
    Some([it.next()?, it.next()?, it.next()?])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faults::FaultPlan;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rtf-cache-disk-{tag}-{}", std::process::id()))
    }

    fn state(v: f32) -> [Plane; 3] {
        [Plane::filled(v, 3, 2), Plane::filled(v + 1.0, 3, 2), Plane::filled(v + 2.0, 3, 2)]
    }

    fn k(v: u64) -> Key {
        Key::from(v)
    }

    #[test]
    fn roundtrip_and_idempotent_store() {
        let dir = tmp_dir("rt");
        let s = state(4.0);
        assert!(store_state(&dir, k(0xabc), &s).unwrap(), "first store is new");
        assert!(!store_state(&dir, k(0xabc), &s).unwrap(), "second store is a no-op");
        assert!(has_state(&dir, k(0xabc)));
        let loaded = load_state(&dir, k(0xabc)).unwrap();
        assert_eq!(loaded[0].get(2, 1), 4.0);
        assert_eq!(loaded[2].get(0, 0), 6.0);
        assert!(load_state(&dir, k(0xdef)).is_none(), "absent key misses");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_current_version_files_miss_and_are_quarantined() {
        let dir = tmp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        // current magic, garbage body: quarantined, not misread
        std::fs::write(state_path(&dir, k(7)), b"RTC3garbage").unwrap();
        assert!(load_state(&dir, k(7)).is_none());
        assert!(!state_path(&dir, k(7)).exists(), "corrupt entry left the probe path");
        assert!(bad_path(&dir, k(7)).exists(), "corrupt entry parked for post-mortem");
        assert!(
            store_state(&dir, k(7), &state(1.0)).unwrap(),
            "quarantined key republishes fresh"
        );
        assert_eq!(load_state(&dir, k(7)).unwrap()[0].get(0, 0), 1.0);
        // foreign magic: a plain miss, left in place
        std::fs::write(state_path(&dir, k(8)), b"XXXX").unwrap();
        assert!(load_state(&dir, k(8)).is_none());
        assert!(state_path(&dir, k(8)).exists(), "stale/foreign file is not quarantined");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_catches_a_flipped_payload_byte() {
        let dir = tmp_dir("bitrot");
        store_state(&dir, k(0x50), &state(2.0)).unwrap();
        let path = state_path(&dir, k(0x50));
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[HEADER_BYTES + 5] ^= 0x40; // flip one payload bit
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_state(&dir, k(0x50)).is_none(), "rotted entry must not load");
        assert!(bad_path(&dir, k(0x50)).exists(), "rotted entry quarantined");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scripted_disk_faults_tear_or_fail_stores() {
        let dir = tmp_dir("faulted");
        let plan = std::sync::Arc::new(
            FaultPlan::new()
                .disk_fault(1, DiskFault::ShortWrite)
                .disk_fault(2, DiskFault::IoError),
        );
        let tier = DiskTier::new(&dir).with_faults(Faults::hooked(plan.clone()));
        let ctx = CacheCtx::unscoped();
        let s: CachedState = Arc::new(state(5.0));

        // #1 short write: reported stored, but the persisted entry is
        // torn and the checksum turns the next lookup into a miss
        assert!(tier.store(k(1), &s, &ctx), "a torn write looks successful to the writer");
        assert!(tier.lookup(k(1), &ctx).is_none(), "checksum catches the tear");
        assert!(bad_path(&dir, k(1)).exists());

        // #2 io error: nothing persisted at all
        assert!(!tier.store(k(2), &s, &ctx));
        assert!(!state_path(&dir, k(2)).exists());

        // #3 unscripted: clean store, clean read-back
        assert!(tier.store(k(3), &s, &ctx));
        assert_eq!(tier.lookup(k(3), &ctx).unwrap()[0].get(0, 0), 5.0);
        assert_eq!(plan.fired().disk_faults, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sweep_reclaims_tmp_orphans_and_quarantined_entries() {
        let dir = tmp_dir("sweep");
        std::fs::create_dir_all(&dir).unwrap();
        store_state(&dir, k(1), &state(1.0)).unwrap();
        std::fs::write(dir.join(".tmp-999-0-deadbeef"), b"partial").unwrap();
        std::fs::write(bad_path(&dir, k(9)), b"RTC3torn").unwrap();
        std::fs::write(dir.join("notes.txt"), b"keep me").unwrap();
        assert_eq!(sweep_debris(&dir), 2, "one orphan + one quarantined entry");
        assert_eq!(sweep_debris(&dir), 0, "sweep is idempotent");
        assert!(load_state(&dir, k(1)).is_some(), "live entries survive the sweep");
        assert!(dir.join("notes.txt").exists(), "foreign files survive the sweep");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn keys_differing_only_in_the_high_half_store_separately() {
        let dir = tmp_dir("hi-lo");
        let a = Key::from_parts(1, 42);
        let b = Key::from_parts(2, 42);
        store_state(&dir, a, &state(1.0)).unwrap();
        store_state(&dir, b, &state(9.0)).unwrap();
        assert_eq!(load_state(&dir, a).unwrap()[0].get(0, 0), 1.0);
        assert_eq!(load_state(&dir, b).unwrap()[0].get(0, 0), 9.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_lists_current_format_entries_only() {
        let dir = tmp_dir("scan");
        std::fs::create_dir_all(&dir).unwrap();
        store_state(&dir, k(1), &state(1.0)).unwrap();
        store_state(&dir, Key::from_parts(9, 2), &state(2.0)).unwrap();
        // noise the scan must skip: old-format name, foreign file, junk hex
        std::fs::write(dir.join(format!("{:016x}.state", 3u64)), b"RTC1old").unwrap();
        std::fs::write(dir.join("notes.txt"), b"hello").unwrap();
        std::fs::write(dir.join(format!("{:0>32}.state", "zz")), b"RTC3").unwrap();
        let mut keys: Vec<Key> = scan_states(&dir).iter().map(|(k, _, _)| *k).collect();
        keys.sort_unstable();
        assert_eq!(keys, vec![k(1), Key::from_parts(9, 2)]);
        let (_, _, len) = scan_states(&dir)[0];
        assert_eq!(
            len as usize,
            ENTRY_OVERHEAD_BYTES + 3 * 6 * 4,
            "scan reports the file length"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mixed_version_dir_ignores_and_reclaims_stale_entries() {
        let dir = tmp_dir("mixed");
        std::fs::create_dir_all(&dir).unwrap();
        let key = k(0xfeed);

        // a pre-widening RTC1 file under its old 16-hex name: never
        // probed (name widths differ), never an error
        std::fs::write(dir.join(format!("{:016x}.state", 0xfeedu64)), b"RTC1oldpayload")
            .unwrap();
        assert!(!has_state(&dir, key), "old-format file must not read as a hit");
        assert!(load_state(&dir, key).is_none());

        // a stale-version file parked at the CURRENT path (the
        // pre-checksum RTC2 era): ignored on read, overwritten on store
        std::fs::write(state_path(&dir, key), b"RTC2staleblob").unwrap();
        assert!(!has_state(&dir, key), "stale magic must not read as a hit");
        assert!(load_state(&dir, key).is_none(), "stale magic must not be misread");
        assert!(
            store_state(&dir, key, &state(3.0)).unwrap(),
            "store must reclaim a stale-version path, not treat it as present"
        );
        assert!(has_state(&dir, key));
        assert_eq!(load_state(&dir, key).unwrap()[0].get(0, 0), 3.0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
