//! The persistent disk tier of the reuse cache.
//!
//! Entries are written write-through as one file per key under the
//! configured directory, so cached states survive process restarts and
//! are shared between studies run at different times (the cross-study
//! "persistent" in the cache's name). The format is self-describing and
//! versioned; unreadable or truncated files are treated as misses, never
//! as errors — the cache is an accelerator, not a source of truth.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::Plane;

/// File magic + format version.
const MAGIC: &[u8; 4] = b"RTC1";

/// Discriminator for temp-file names (concurrent writers never collide).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// One 3-plane state as stored on disk.
pub(crate) fn state_path(dir: &Path, key: u64) -> PathBuf {
    dir.join(format!("{key:016x}.state"))
}

/// True when the key has a plausible on-disk entry (no content check).
pub(crate) fn has_state(dir: &Path, key: u64) -> bool {
    state_path(dir, key).exists()
}

/// Write a state for `key`, atomically (temp file + rename). Returns
/// `Ok(false)` when the key was already present.
pub(crate) fn store_state(dir: &Path, key: u64, state: &[Plane; 3]) -> std::io::Result<bool> {
    let path = state_path(dir, key);
    if path.exists() {
        return Ok(false);
    }
    std::fs::create_dir_all(dir)?;
    let mut bytes: Vec<u8> = Vec::with_capacity(16 + state[0].nbytes() * 3);
    bytes.extend_from_slice(MAGIC);
    bytes.extend_from_slice(&(state[0].height() as u32).to_le_bytes());
    bytes.extend_from_slice(&(state[0].width() as u32).to_le_bytes());
    for plane in state {
        for v in plane.data() {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    let tmp = dir.join(format!(
        ".tmp-{}-{}-{key:016x}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::write(&tmp, &bytes)?;
    std::fs::rename(&tmp, &path)?;
    Ok(true)
}

/// Load the state for `key`, if present and well-formed.
pub(crate) fn load_state(dir: &Path, key: u64) -> Option<[Plane; 3]> {
    let bytes = std::fs::read(state_path(dir, key)).ok()?;
    if bytes.len() < 12 || &bytes[..4] != MAGIC {
        return None;
    }
    let h = u32::from_le_bytes(bytes[4..8].try_into().ok()?) as usize;
    let w = u32::from_le_bytes(bytes[8..12].try_into().ok()?) as usize;
    if bytes.len() != 12 + 3 * h * w * 4 {
        return None;
    }
    let mut planes = Vec::with_capacity(3);
    for p in 0..3 {
        let start = 12 + p * h * w * 4;
        let data: Vec<f32> = bytes[start..start + h * w * 4]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        planes.push(Plane::new(data, h, w).ok()?);
    }
    let mut it = planes.into_iter();
    Some([it.next()?, it.next()?, it.next()?])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rtf-cache-disk-{tag}-{}", std::process::id()))
    }

    fn state(v: f32) -> [Plane; 3] {
        [Plane::filled(v, 3, 2), Plane::filled(v + 1.0, 3, 2), Plane::filled(v + 2.0, 3, 2)]
    }

    #[test]
    fn roundtrip_and_idempotent_store() {
        let dir = tmp_dir("rt");
        let s = state(4.0);
        assert!(store_state(&dir, 0xabc, &s).unwrap(), "first store is new");
        assert!(!store_state(&dir, 0xabc, &s).unwrap(), "second store is a no-op");
        assert!(has_state(&dir, 0xabc));
        let loaded = load_state(&dir, 0xabc).unwrap();
        assert_eq!(loaded[0].get(2, 1), 4.0);
        assert_eq!(loaded[2].get(0, 0), 6.0);
        assert!(load_state(&dir, 0xdef).is_none(), "absent key misses");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_files_read_as_misses() {
        let dir = tmp_dir("corrupt");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(state_path(&dir, 7), b"RTC1garbage").unwrap();
        assert!(load_state(&dir, 7).is_none());
        std::fs::write(state_path(&dir, 8), b"XXXX").unwrap();
        assert!(load_state(&dir, 8).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
