//! Content-addressed cache keys.
//!
//! A cached state is identified by the *entire computation that produced
//! it*: the content fingerprint of the input tile, chained with the
//! (quantized) signature of every task executed since, in order. Unlike
//! the within-study signatures of [`crate::workflow::instantiate_study`]
//! — which root at the tile *id* — these keys root at the tile *content*,
//! so they are stable across studies, processes and seeds: two studies
//! computing the same task prefix on the same pixels produce the same
//! key, whatever their ids say.
//!
//! Quantization is the approximate-reuse knob: with step `q > 0`, every
//! task parameter is snapped to the `q`-grid before hashing, so parameter
//! vectors that differ by less than the grid resolution share keys (and
//! therefore states). `q = 0` means exact reuse only.
//!
//! # 128-bit keys
//!
//! Keys are 128-bit FNV-1a chains ([`Key`]). A cross-key collision would
//! silently alias two distinct computations — the cache would serve the
//! wrong state, bit-for-bit plausibly. The original 64-bit chains were
//! adequate for study-scale populations (≤ millions of distinct
//! prefixes), but the long-lived multi-tenant service ([`crate::serve`])
//! accumulates keys for the lifetime of the process across every tenant:
//! at 2⁶⁴ the birthday bound reaches a 50% collision chance near 5·10⁹
//! entries, while at 2¹²⁸ it stays negligible (< 10⁻¹⁸) past 10²⁰
//! entries. Task *signatures* ([`task_cache_sig`]) remain 64-bit words —
//! they are ingredients folded into the 128-bit chain, not cache keys
//! themselves.
//!
//! Disk-tier entries written under the old 64-bit format are versioned
//! out, not silently orphaned: see [`crate::cache`]'s `disk` module
//! (`RTC2` magic, 32-hex file names).

use std::collections::HashMap;
use std::fmt;

use crate::data::Plane;
use crate::merging::CompactGraph;
use crate::workflow::{sig_hash, str_bits, StageInstance, TaskInstance};

/// A 128-bit content-addressed cache key.
///
/// Constructed only by the chaining/fingerprint functions of this module
/// (plus the zero-extending [`From<u64>`] embedding used for key roots
/// and tests). Ordered and hashable so key sets can be compared in
/// tests; displayed as 32 hex digits — the disk tier's file-name format.
#[derive(Clone, Copy, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Key(u128);

impl Key {
    /// The two 64-bit halves, `(hi, lo)`.
    pub fn from_parts(hi: u64, lo: u64) -> Key {
        Key(((hi as u128) << 64) | lo as u128)
    }

    /// Low 64 bits — what the pre-widening cache would have keyed on.
    pub fn lo(self) -> u64 {
        self.0 as u64
    }

    /// High 64 bits.
    pub fn hi(self) -> u64 {
        (self.0 >> 64) as u64
    }

    /// The raw 128-bit value (disk file names, diagnostics).
    pub fn as_u128(self) -> u128 {
        self.0
    }
}

/// Zero-extending embedding of a 64-bit word (key roots such as the
/// artifact fingerprint, and test keys). This is an *identity* embedding,
/// not a hash — every derived key runs through [`Fnv128`] anyway.
impl From<u64> for Key {
    fn from(v: u64) -> Key {
        Key(v as u128)
    }
}

impl fmt::Debug for Key {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Key({:032x})", self.0)
    }
}

impl fmt::Display for Key {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

/// Streaming FNV-1a over 64-bit words (byte-compatible with
/// [`sig_hash`] over the same word sequence). Still used for 64-bit task
/// signatures; cache keys chain through [`Fnv128`].
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    pub fn mix(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// FNV-1a 128 offset basis.
const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
/// FNV-1a 128 prime: 2¹²⁸-domain FNV prime, 2⁸⁸ + 2⁸ + 0x3b.
const FNV128_PRIME: u128 = (1 << 88) + (1 << 8) + 0x3b;

/// Streaming 128-bit FNV-1a over 64-bit words — the key-derivation hash.
pub struct Fnv128(u128);

impl Fnv128 {
    pub fn new() -> Self {
        Fnv128(FNV128_OFFSET)
    }

    /// Absorb one 64-bit word (little-endian bytes, matching [`Fnv`]).
    pub fn mix(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u128;
            self.0 = self.0.wrapping_mul(FNV128_PRIME);
        }
    }

    pub fn finish(&self) -> Key {
        Key(self.0)
    }
}

impl Default for Fnv128 {
    fn default() -> Self {
        Self::new()
    }
}

/// Snap a parameter value onto the quantization grid (`step = 0` keeps
/// the value exact).
pub fn quantize(v: f64, step: f64) -> f64 {
    if step > 0.0 {
        (v / step).round() * step
    } else {
        v
    }
}

/// Cache signature of one task instance: task identity + quantized
/// parameter values. A 64-bit ingredient word, not a cache key — it is
/// folded into the 128-bit chain by [`chain_key`].
pub fn task_cache_sig(task: &TaskInstance, step: f64) -> u64 {
    let mut parts = vec![str_bits(&task.name), str_bits(&task.lib_call)];
    parts.extend(task.params.iter().map(|&v| quantize(v, step).to_bits()));
    sig_hash(&parts)
}

/// Extend a chain key by one executed task: FNV-1a 128 over the previous
/// key's two halves and the task signature word.
pub fn chain_key(prev: Key, task_sig: u64) -> Key {
    let mut h = Fnv128::new();
    h.mix(prev.lo());
    h.mix(prev.hi());
    h.mix(task_sig);
    h.finish()
}

/// Fold two full keys into one (artifact fingerprint × tile fingerprint
/// roots; chain key × reference fingerprint for metric keys). Order-
/// sensitive, like [`chain_key`].
pub fn fold_keys(a: Key, b: Key) -> Key {
    let mut h = Fnv128::new();
    h.mix(a.lo());
    h.mix(a.hi());
    h.mix(b.lo());
    h.mix(b.hi());
    h.finish()
}

/// The key comparison metrics are memoized under: the unit's input key
/// extended by the compare task's signature, folded with the
/// reference-mask fingerprint. Defined ONCE here so the executor
/// (`coordinator/exec.rs`) and the planning probe
/// (`merging/study.rs::prune_cached`) can never drift.
pub fn metrics_key(base: Key, compare_sig: u64, ref_fp: Key) -> Key {
    fold_keys(chain_key(base, compare_sig), ref_fp)
}

/// Quantized identity of one candidate parameter vector — the tuning
/// subsystem's per-run memo key ([`crate::tune`]). With step `q > 0`
/// every value snaps to the `q`-grid before hashing, so optimizer
/// iterates that land in the same grid cell share a key (and therefore a
/// memoized score) — the "revisited quantized points" reuse of run-time
/// SA/tuning optimization. `q = 0` keys exactly. These are namespace-
/// disjoint from task-chain keys by construction: chain keys always pass
/// through [`chain_key`]/[`fold_keys`], candidate keys never do.
pub fn candidate_key(params: &[f64], step: f64) -> Key {
    let mut h = Fnv128::new();
    h.mix(params.len() as u64);
    for &v in params {
        h.mix(quantize(v, step).to_bits());
    }
    h.finish()
}

/// Content fingerprint of a set of planes (shape + every pixel's bits) —
/// the key root for tiles and the reference-mask discriminator for
/// cached metrics.
pub fn content_fingerprint(planes: &[&Plane]) -> Key {
    let mut h = Fnv128::new();
    for p in planes {
        h.mix(p.height() as u64);
        h.mix(p.width() as u64);
        for &v in p.data() {
            h.mix(v.to_bits() as u64);
        }
    }
    h.finish()
}

/// Content key of the state a compact node receives as *input*: the tile
/// fingerprint folded through every task of every upstream stage along
/// the node's parent chain.
pub fn node_input_key(
    graph: &CompactGraph,
    instances: &[StageInstance],
    node: usize,
    tile_fp: Key,
    step: f64,
) -> Key {
    let mut chain = Vec::new();
    let mut cur = graph.nodes[node].parent;
    while let Some(p) = cur {
        chain.push(p);
        cur = graph.nodes[p].parent;
    }
    let mut key = tile_fp;
    for &p in chain.iter().rev() {
        for t in &instances[graph.nodes[p].rep].tasks {
            key = chain_key(key, task_cache_sig(t, step));
        }
    }
    key
}

/// Content fingerprints of a study's tiles, keyed by tile id.
pub fn tile_fingerprints(tiles: &HashMap<u64, crate::data::TileSet>) -> HashMap<u64, Key> {
    tiles
        .iter()
        .map(|(&id, t)| (id, content_fingerprint(&[&t.r, &t.g, &t.b])))
        .collect()
}

/// Content fingerprints of a study's reference masks, keyed by tile id.
pub fn reference_fingerprints(references: &HashMap<u64, Plane>) -> HashMap<u64, Key> {
    references.iter().map(|(&id, p)| (id, content_fingerprint(&[p]))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(params: &[f64]) -> TaskInstance {
        let mut parts = vec![str_bits("t2"), str_bits("lib")];
        parts.extend(params.iter().map(|v| v.to_bits()));
        TaskInstance {
            name: "t2".into(),
            lib_call: "lib".into(),
            params: params.to_vec(),
            sig: sig_hash(&parts),
        }
    }

    #[test]
    fn quantization_controls_key_equality() {
        let a = task(&[40.0, 8.0]);
        let b = task(&[40.4, 8.0]);
        let c = task(&[43.0, 8.0]);
        // exact mode distinguishes everything
        assert_ne!(task_cache_sig(&a, 0.0), task_cache_sig(&b, 0.0));
        // step 1.0: 40.4 rounds onto 40.0, 43.0 does not
        assert_eq!(task_cache_sig(&a, 1.0), task_cache_sig(&b, 1.0));
        assert_ne!(task_cache_sig(&a, 1.0), task_cache_sig(&c, 1.0));
        // coarser step merges all three
        assert_eq!(task_cache_sig(&a, 10.0), task_cache_sig(&c, 10.0));
    }

    #[test]
    fn chain_keys_are_order_sensitive() {
        let root = Key::from(7u64);
        let x = chain_key(chain_key(root, 1), 2);
        let y = chain_key(chain_key(root, 2), 1);
        assert_ne!(x, y);
        assert_ne!(chain_key(root, 1), chain_key(Key::from(8u64), 1));
    }

    #[test]
    fn chain_keys_populate_both_halves() {
        // the widened chain must disperse into the high 64 bits too —
        // otherwise the widening is cosmetic and the collision margin
        // is still the old 64-bit one
        let k = chain_key(Key::from(7u64), 1);
        assert_ne!(k.hi(), 0, "high half unused: widening is cosmetic");
        assert_ne!(k.lo(), 0);
        let l = chain_key(Key::from(7u64), 2);
        assert_ne!(k.hi(), l.hi(), "distinct chains differ in the high half");
        assert_ne!(k.lo(), l.lo(), "distinct chains differ in the low half");
    }

    #[test]
    fn key_parts_roundtrip_and_format() {
        let k = Key::from_parts(0xdead_beef, 0x1234_5678);
        assert_eq!(k.hi(), 0xdead_beef);
        assert_eq!(k.lo(), 0x1234_5678);
        assert_eq!(format!("{k}"), format!("{:032x}", k.as_u128()));
        assert_eq!(Key::from(5u64), Key::from_parts(0, 5));
    }

    #[test]
    fn fold_keys_is_order_sensitive() {
        let a = Key::from(1u64);
        let b = Key::from(2u64);
        assert_ne!(fold_keys(a, b), fold_keys(b, a));
        assert_ne!(fold_keys(a, b), fold_keys(a, a));
        // metrics_key folds the reference fingerprint after the chain
        let m1 = metrics_key(a, 9, b);
        let m2 = metrics_key(a, 9, a);
        let m3 = metrics_key(b, 9, b);
        assert_ne!(m1, m2);
        assert_ne!(m1, m3);
        assert_eq!(m1, fold_keys(chain_key(a, 9), b));
    }

    #[test]
    fn candidate_keys_quantize_and_discriminate() {
        let a = [40.0, 8.0];
        let b = [40.4, 8.0];
        let c = [8.0, 40.0];
        assert_ne!(candidate_key(&a, 0.0), candidate_key(&b, 0.0), "exact keys differ");
        assert_eq!(candidate_key(&a, 1.0), candidate_key(&b, 1.0), "grid cell shared");
        assert_ne!(candidate_key(&a, 1.0), candidate_key(&c, 1.0), "order matters");
        // length is part of the identity: a prefix never aliases
        assert_ne!(candidate_key(&a, 0.0), candidate_key(&a[..1], 0.0));
        assert_eq!(candidate_key(&a, 0.0), candidate_key(&[40.0, 8.0], 0.0));
    }

    #[test]
    fn content_fingerprint_sees_pixels_and_shape() {
        let a = Plane::filled(1.0, 2, 3);
        let b = Plane::filled(1.0, 3, 2);
        let mut c = Plane::filled(1.0, 2, 3);
        c.set(1, 1, 2.0);
        assert_eq!(content_fingerprint(&[&a]), content_fingerprint(&[&a.clone()]));
        assert_ne!(content_fingerprint(&[&a]), content_fingerprint(&[&b]));
        assert_ne!(content_fingerprint(&[&a]), content_fingerprint(&[&c]));
    }

    #[test]
    fn streaming_fnv_matches_sig_hash() {
        let mut h = Fnv::new();
        h.mix(3);
        h.mix(9);
        assert_eq!(h.finish(), sig_hash(&[3, 9]));
    }

    #[test]
    fn fnv128_word_streaming_is_deterministic() {
        let mut a = Fnv128::new();
        a.mix(3);
        a.mix(9);
        let mut b = Fnv128::new();
        b.mix(3);
        b.mix(9);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv128::new();
        c.mix(9);
        c.mix(3);
        assert_ne!(a.finish(), c.finish(), "word order matters");
    }
}
