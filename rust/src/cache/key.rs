//! Content-addressed cache keys.
//!
//! A cached state is identified by the *entire computation that produced
//! it*: the content fingerprint of the input tile, chained with the
//! (quantized) signature of every task executed since, in order. Unlike
//! the within-study signatures of [`crate::workflow::instantiate_study`]
//! — which root at the tile *id* — these keys root at the tile *content*,
//! so they are stable across studies, processes and seeds: two studies
//! computing the same task prefix on the same pixels produce the same
//! key, whatever their ids say.
//!
//! Quantization is the approximate-reuse knob: with step `q > 0`, every
//! task parameter is snapped to the `q`-grid before hashing, so parameter
//! vectors that differ by less than the grid resolution share keys (and
//! therefore states). `q = 0` means exact reuse only.
//!
//! Keys are 64-bit FNV-1a chains: compact and fast, but not
//! collision-resistant — a cross-key collision would silently alias two
//! distinct computations. At study scale (≤ millions of distinct
//! prefixes) the birthday bound keeps this negligible; widening to
//! 128-bit keys before the multi-tenant/serving phase is tracked in
//! ROADMAP.md.

use std::collections::HashMap;

use crate::data::Plane;
use crate::merging::CompactGraph;
use crate::workflow::{sig_hash, str_bits, StageInstance, TaskInstance};

/// Streaming FNV-1a over 64-bit words (byte-compatible with
/// [`sig_hash`] over the same word sequence).
pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }

    pub fn mix(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Self {
        Self::new()
    }
}

/// Snap a parameter value onto the quantization grid (`step = 0` keeps
/// the value exact).
pub fn quantize(v: f64, step: f64) -> f64 {
    if step > 0.0 {
        (v / step).round() * step
    } else {
        v
    }
}

/// Cache signature of one task instance: task identity + quantized
/// parameter values.
pub fn task_cache_sig(task: &TaskInstance, step: f64) -> u64 {
    let mut parts = vec![str_bits(&task.name), str_bits(&task.lib_call)];
    parts.extend(task.params.iter().map(|&v| quantize(v, step).to_bits()));
    sig_hash(&parts)
}

/// Extend a chain key by one executed task.
pub fn chain_key(prev: u64, task_sig: u64) -> u64 {
    sig_hash(&[prev, task_sig])
}

/// Content fingerprint of a set of planes (shape + every pixel's bits) —
/// the key root for tiles and the reference-mask discriminator for
/// cached metrics.
pub fn content_fingerprint(planes: &[&Plane]) -> u64 {
    let mut h = Fnv::new();
    for p in planes {
        h.mix(p.height() as u64);
        h.mix(p.width() as u64);
        for &v in p.data() {
            h.mix(v.to_bits() as u64);
        }
    }
    h.finish()
}

/// Content key of the state a compact node receives as *input*: the tile
/// fingerprint folded through every task of every upstream stage along
/// the node's parent chain.
pub fn node_input_key(
    graph: &CompactGraph,
    instances: &[StageInstance],
    node: usize,
    tile_fp: u64,
    step: f64,
) -> u64 {
    let mut chain = Vec::new();
    let mut cur = graph.nodes[node].parent;
    while let Some(p) = cur {
        chain.push(p);
        cur = graph.nodes[p].parent;
    }
    let mut key = tile_fp;
    for &p in chain.iter().rev() {
        for t in &instances[graph.nodes[p].rep].tasks {
            key = chain_key(key, task_cache_sig(t, step));
        }
    }
    key
}

/// Content fingerprints of a study's tiles, keyed by tile id.
pub fn tile_fingerprints(tiles: &HashMap<u64, crate::data::TileSet>) -> HashMap<u64, u64> {
    tiles
        .iter()
        .map(|(&id, t)| (id, content_fingerprint(&[&t.r, &t.g, &t.b])))
        .collect()
}

/// Content fingerprints of a study's reference masks, keyed by tile id.
pub fn reference_fingerprints(references: &HashMap<u64, Plane>) -> HashMap<u64, u64> {
    references.iter().map(|(&id, p)| (id, content_fingerprint(&[p]))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(params: &[f64]) -> TaskInstance {
        let mut parts = vec![str_bits("t2"), str_bits("lib")];
        parts.extend(params.iter().map(|v| v.to_bits()));
        TaskInstance {
            name: "t2".into(),
            lib_call: "lib".into(),
            params: params.to_vec(),
            sig: sig_hash(&parts),
        }
    }

    #[test]
    fn quantization_controls_key_equality() {
        let a = task(&[40.0, 8.0]);
        let b = task(&[40.4, 8.0]);
        let c = task(&[43.0, 8.0]);
        // exact mode distinguishes everything
        assert_ne!(task_cache_sig(&a, 0.0), task_cache_sig(&b, 0.0));
        // step 1.0: 40.4 rounds onto 40.0, 43.0 does not
        assert_eq!(task_cache_sig(&a, 1.0), task_cache_sig(&b, 1.0));
        assert_ne!(task_cache_sig(&a, 1.0), task_cache_sig(&c, 1.0));
        // coarser step merges all three
        assert_eq!(task_cache_sig(&a, 10.0), task_cache_sig(&c, 10.0));
    }

    #[test]
    fn chain_keys_are_order_sensitive() {
        let x = chain_key(chain_key(7, 1), 2);
        let y = chain_key(chain_key(7, 2), 1);
        assert_ne!(x, y);
        assert_ne!(chain_key(7, 1), chain_key(8, 1));
    }

    #[test]
    fn content_fingerprint_sees_pixels_and_shape() {
        let a = Plane::filled(1.0, 2, 3);
        let b = Plane::filled(1.0, 3, 2);
        let mut c = Plane::filled(1.0, 2, 3);
        c.set(1, 1, 2.0);
        assert_eq!(content_fingerprint(&[&a]), content_fingerprint(&[&a.clone()]));
        assert_ne!(content_fingerprint(&[&a]), content_fingerprint(&[&b]));
        assert_ne!(content_fingerprint(&[&a]), content_fingerprint(&[&c]));
    }

    #[test]
    fn streaming_fnv_matches_sig_hash() {
        let mut h = Fnv::new();
        h.mix(3);
        h.mix(9);
        assert_eq!(h.finish(), sig_hash(&[3, 9]));
    }
}
