//! The cluster cache fabric: a [`CacheTier`] backed by peer nodes.
//!
//! In cluster mode (`serve peers=ADDR,...`) every node runs the same
//! tier stack locally — memory over disk — and attaches one
//! [`RemoteTier`] below them. The 128-bit key space is partitioned
//! across the peer list by rendezvous hashing ([`PeerRing`]): each key
//! has exactly one *owning* node, every node computes the same owner
//! from the same sorted peer list, and adding a peer moves only the
//! keys it wins. For keys this node owns the remote tier is inert
//! (lookups and stores return immediately); for keys another node owns
//! it speaks the serve wire protocol (rtfp v3) to the owner:
//!
//! * `lookup` sends `cache-get` and blocks until the owner answers
//!   `cache-state` — either `found` with the 3-plane payload, or
//!   `claimed`, meaning this node now holds the **cross-node
//!   single-flight claim** and must compute locally. While another node
//!   holds the claim the owner parks the request
//!   ([`super::ReuseCache::serve_remote_get`]), so two nodes never
//!   duplicate a launch.
//! * `store` publishes the computed state with `cache-put`, settling
//!   the claim on the owner so parked peers wake to a `found` reply.
//!
//! Failure model: the fabric is an *optimization*, never a correctness
//! dependency. Any connect, send, or decode failure degrades the call
//! to a plain miss (`lookup → None`, `store → false`) and the engine
//! falls through to a local launch; broken connections are dropped and
//! re-dialed on the next call. Results stay bit-identical between
//! 1-node and N-node runs because a remote hit returns the exact bytes
//! the owner stored ([`planes_to_hex`] is a lossless `f32` codec).
//!
//! [`planes_to_hex`]: crate::serve::protocol::planes_to_hex

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::serve::protocol::{
    planes_from_hex, read_frame, write_frame, Message, WireCachePut, PROTOCOL_VERSION,
};
use crate::{Error, Result};

use super::key::{Fnv128, Key};
use super::store::{CachedState, ScopedCounters};
use super::tier::{CacheCtx, CacheTier, TierStats, REMOTE_TIER};

/// Dial budget per peer connection. Short on purpose: a down peer
/// should cost one lookup half a second, not hang a study.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(500);
/// Read budget per reply. Long enough to sit out another node's
/// in-flight computation behind a cross-node claim.
const READ_TIMEOUT: Duration = Duration::from_secs(120);
/// Write budget per request frame.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Rendezvous (highest-random-weight) partition of the 128-bit key
/// space across a peer list.
///
/// The peer list is sorted and deduplicated at construction, so every
/// node that was handed the same set of addresses — in any order —
/// computes the same owner for every key. Scores are 128-bit FNV
/// digests of the key mixed with the peer address, so ties are
/// vanishingly unlikely and the assignment is uniform in expectation.
#[derive(Clone, Debug)]
pub struct PeerRing {
    peers: Vec<String>,
    self_idx: usize,
}

impl PeerRing {
    /// Build the ring. `self_addr` (this node's listen address) must be
    /// a member of `peers` — the partition only covers nodes that are
    /// actually serving their shard.
    pub fn new(peers: &[String], self_addr: &str) -> Result<Self> {
        let mut peers: Vec<String> = peers.to_vec();
        peers.sort();
        peers.dedup();
        if peers.is_empty() {
            return Err(Error::Config("peers= list is empty".into()));
        }
        let self_idx = peers.iter().position(|p| p == self_addr).ok_or_else(|| {
            Error::Config(format!(
                "peers= list {peers:?} must include this node's listen address `{self_addr}`"
            ))
        })?;
        Ok(Self { peers, self_idx })
    }

    fn score(key: Key, addr: &str) -> Key {
        let mut f = Fnv128::new();
        f.mix(key.lo());
        f.mix(key.hi());
        for b in addr.as_bytes() {
            f.mix(u64::from(*b));
        }
        f.finish()
    }

    /// Index (into the sorted peer list) of the node owning `key`.
    pub fn owner_of(&self, key: Key) -> usize {
        (0..self.peers.len())
            .max_by_key(|&i| Self::score(key, &self.peers[i]))
            .expect("ring is never empty")
    }

    /// Does this node own `key`?
    pub fn is_local(&self, key: Key) -> bool {
        self.owner_of(key) == self.self_idx
    }

    /// The sorted, deduplicated peer list.
    pub fn peers(&self) -> &[String] {
        &self.peers
    }

    /// This node's address as it appears in the ring.
    pub fn self_addr(&self) -> &str {
        &self.peers[self.self_idx]
    }

    fn addr(&self, idx: usize) -> &str {
        &self.peers[idx]
    }
}

/// The remote tier: fetches and publishes cache entries over the serve
/// wire protocol, one pooled connection set per peer.
pub struct RemoteTier {
    ring: PeerRing,
    /// Idle connections per peer (parallel to `ring.peers()`), returned
    /// after a successful exchange, dropped on any error.
    pools: Vec<Mutex<Vec<TcpStream>>>,
    hits: AtomicU64,
    stores: AtomicU64,
}

impl RemoteTier {
    /// Build the tier for this node. Does not dial anyone — connections
    /// are opened lazily on the first lookup/store per peer.
    pub fn new(peers: &[String], self_addr: &str) -> Result<Self> {
        let ring = PeerRing::new(peers, self_addr)?;
        let pools = ring.peers().iter().map(|_| Mutex::new(Vec::new())).collect();
        Ok(Self { ring, pools, hits: AtomicU64::new(0), stores: AtomicU64::new(0) })
    }

    /// The key partition this tier routes by.
    pub fn ring(&self) -> &PeerRing {
        &self.ring
    }

    /// Dial a peer and run the `hello` handshake in the `peer` role.
    fn connect(&self, addr: &str) -> Result<TcpStream> {
        let sock = addr
            .to_socket_addrs()
            .map_err(Error::Io)?
            .next()
            .ok_or_else(|| Error::Protocol(format!("peer `{addr}` does not resolve")))?;
        let stream = TcpStream::connect_timeout(&sock, CONNECT_TIMEOUT).map_err(Error::Io)?;
        stream.set_read_timeout(Some(READ_TIMEOUT)).map_err(Error::Io)?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).map_err(Error::Io)?;
        let hello = Message::Hello { version: PROTOCOL_VERSION, role: "peer".into() };
        match Self::exchange(&stream, &hello)? {
            Message::Hello { version, .. } if version == PROTOCOL_VERSION => Ok(stream),
            Message::Hello { version, .. } => Err(Error::Protocol(format!(
                "peer {addr} speaks protocol v{version}, this node v{PROTOCOL_VERSION}"
            ))),
            Message::Error { code, message } => {
                Err(Error::Protocol(format!("peer {addr} refused [{code}]: {message}")))
            }
            other => Err(Error::Protocol(format!(
                "peer {addr}: expected `hello`, got `{}`",
                other.type_name()
            ))),
        }
    }

    /// One request/response exchange on an open connection. Safe to
    /// wrap the stream in a fresh `BufReader` per call: the protocol is
    /// strictly request/response on this connection, so the reader
    /// never buffers past the reply frame.
    fn exchange(stream: &TcpStream, msg: &Message) -> Result<Message> {
        let mut writer = BufWriter::new(stream);
        write_frame(&mut writer, msg)?;
        writer.flush().map_err(Error::Io)?;
        drop(writer);
        let mut reader = BufReader::new(stream);
        match read_frame(&mut reader)? {
            Some(reply) => Ok(reply),
            None => Err(Error::Protocol("peer closed the connection".into())),
        }
    }

    /// Send `msg` to peer `idx`, reusing a pooled connection when one
    /// is idle. A stale pooled connection is dropped and the call
    /// retried once on a fresh dial.
    fn call(&self, idx: usize, msg: &Message) -> Result<Message> {
        if let Some(stream) = self.pools[idx].lock().unwrap().pop() {
            if let Ok(reply) = Self::exchange(&stream, msg) {
                self.pools[idx].lock().unwrap().push(stream);
                return Ok(reply);
            }
        }
        let stream = self.connect(self.ring.addr(idx))?;
        let reply = Self::exchange(&stream, msg)?;
        self.pools[idx].lock().unwrap().push(stream);
        Ok(reply)
    }
}

impl CacheTier for RemoteTier {
    fn name(&self) -> &'static str {
        REMOTE_TIER
    }

    fn lookup(&self, key: Key, _ctx: &CacheCtx) -> Option<CachedState> {
        let owner = self.ring.owner_of(key);
        if owner == self.ring.self_idx {
            return None;
        }
        match self.call(owner, &Message::CacheGet { key }).ok()? {
            Message::CacheState(state) if state.found => {
                let planes = planes_from_hex(state.h, state.w, &state.planes).ok()?;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::new(planes))
            }
            // `claimed` (or anything unexpected): this node computes
            // locally and publishes through `store`.
            _ => None,
        }
    }

    fn store(&self, key: Key, state: &CachedState, _ctx: &CacheCtx) -> bool {
        let owner = self.ring.owner_of(key);
        if owner == self.ring.self_idx {
            return false;
        }
        let put = Message::CachePut(Box::new(WireCachePut::new(key, state)));
        match self.call(owner, &put) {
            Ok(Message::CacheOk { stored: true, .. }) => {
                self.stores.fetch_add(1, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    fn evict_scope(&self, _scope: &Arc<ScopedCounters>) -> bool {
        false
    }

    fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            resident_bytes: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Plane;
    use crate::serve::protocol::WireCacheState;
    use std::net::TcpListener;

    fn state() -> CachedState {
        Arc::new([Plane::filled(1.0, 2, 2), Plane::filled(0.5, 2, 2), Plane::filled(2.0, 2, 2)])
    }

    #[test]
    fn ring_is_order_insensitive_and_covers_every_peer() {
        let a = vec!["h1:1".to_string(), "h2:2".to_string(), "h3:3".to_string()];
        let b = vec!["h3:3".to_string(), "h1:1".to_string(), "h2:2".to_string()];
        let ra = PeerRing::new(&a, "h1:1").unwrap();
        let rb = PeerRing::new(&b, "h2:2").unwrap();
        let mut owned = [0usize; 3];
        for i in 0..512u64 {
            let key = Key::from(i);
            let owner = ra.owner_of(key);
            assert_eq!(
                ra.peers()[owner],
                rb.peers()[rb.owner_of(key)],
                "same owner from any list order"
            );
            owned[owner] += 1;
        }
        assert!(owned.iter().all(|&n| n > 0), "every peer owns a shard: {owned:?}");
    }

    #[test]
    fn ring_requires_self_membership_and_a_nonempty_list() {
        let peers = vec!["h1:1".to_string(), "h2:2".to_string()];
        let err = PeerRing::new(&peers, "h9:9").unwrap_err();
        assert!(err.to_string().contains("h9:9"), "error names the missing address: {err}");
        assert!(PeerRing::new(&[], "h1:1").is_err());
        // duplicates collapse
        let dup = vec!["h1:1".to_string(), "h1:1".to_string(), "h2:2".to_string()];
        assert_eq!(PeerRing::new(&dup, "h1:1").unwrap().peers().len(), 2);
    }

    #[test]
    fn self_owned_keys_are_inert_and_dead_peers_degrade_to_misses() {
        // Port 1 on loopback refuses immediately: the fabric must turn
        // that into a plain miss, not an error or a hang.
        let peers = vec!["127.0.0.1:1".to_string(), "127.0.0.1:9".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:9").unwrap();
        let ctx = CacheCtx::unscoped();
        let (mut local, mut remote) = (0, 0);
        for i in 0..64u64 {
            let key = Key::from(i);
            if tier.ring().is_local(key) {
                local += 1;
            } else {
                remote += 1;
            }
            assert!(tier.lookup(key, &ctx).is_none());
            assert!(!tier.store(key, &state(), &ctx));
            if local > 0 && remote > 1 {
                break;
            }
        }
        assert!(local > 0 && remote > 0, "sampled both shards ({local} local, {remote} remote)");
        assert_eq!(tier.stats(), TierStats::default(), "failed calls never count");
    }

    /// A one-connection mini peer: handshakes, then answers `cache-get`
    /// with `found` and `cache-put` with `stored`.
    fn spawn_mini_peer(listener: TcpListener) -> std::thread::JoinHandle<u32> {
        std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            let mut served = 0;
            while let Ok(Some(msg)) = read_frame(&mut reader) {
                let reply = match msg {
                    Message::Hello { .. } => {
                        Message::Hello { version: PROTOCOL_VERSION, role: "server".into() }
                    }
                    Message::CacheGet { key } => {
                        served += 1;
                        Message::CacheState(Box::new(WireCacheState::found(key, &state())))
                    }
                    Message::CachePut(put) => {
                        served += 1;
                        Message::CacheOk { key: put.key, stored: true }
                    }
                    other => panic!("mini peer got {}", other.type_name()),
                };
                write_frame(&mut writer, &reply).unwrap();
                writer.flush().unwrap();
            }
            served
        })
    }

    #[test]
    fn fetches_and_publishes_through_a_live_peer_on_one_pooled_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = spawn_mini_peer(listener);

        let peers = vec![addr.clone(), "127.0.0.1:1".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:1").unwrap();
        let ctx = CacheCtx::unscoped();
        let key = (0..u64::MAX)
            .map(Key::from)
            .find(|k| tier.ring().peers()[tier.ring().owner_of(*k)] == addr)
            .unwrap();

        let got = tier.lookup(key, &ctx).expect("peer holds the state");
        assert_eq!(got[0].data(), state()[0].data(), "payload survives the wire");
        assert!(tier.store(key, &state(), &ctx), "publish acknowledges");
        assert_eq!(tier.stats(), TierStats { hits: 1, stores: 1, resident_bytes: 0 });

        drop(tier); // closes the pooled connection; the peer thread exits
        assert_eq!(handle.join().unwrap(), 2, "both calls reused one connection");
    }
}
