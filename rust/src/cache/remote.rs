//! The cluster cache fabric: a [`CacheTier`] backed by peer nodes.
//!
//! In cluster mode (`serve peers=ADDR,...`) every node runs the same
//! tier stack locally — memory over disk — and attaches one
//! [`RemoteTier`] below them. The 128-bit key space is partitioned
//! across the peer list by rendezvous hashing ([`PeerRing`]): each key
//! has exactly one *owning* node, every node computes the same owner
//! from the same sorted peer list, and adding a peer moves only the
//! keys it wins. For keys this node owns the remote tier is inert
//! (lookups and stores return immediately); for keys another node owns
//! it speaks the serve wire protocol (rtfp v4) to the owner:
//!
//! * `lookup` sends `cache-get` and blocks until the owner answers
//!   `cache-state` — either `found` with the 3-plane payload, or
//!   `claimed`, meaning this node now holds the **cross-node
//!   single-flight claim** and must compute locally. While another node
//!   holds the claim the owner parks the request
//!   ([`super::ReuseCache::serve_remote_get`]), so two nodes never
//!   duplicate a launch.
//! * `store` publishes the computed state with `cache-put`, settling
//!   the claim on the owner so parked peers wake to a `found` reply.
//!
//! Failure model: the fabric is an *optimization*, never a correctness
//! dependency. Any connect, send, or decode failure degrades the call
//! to a plain miss (`lookup → None`, `store → false`) and the engine
//! falls through to a local launch; broken (or timed-out, or
//! poison-replying) connections are dropped — never returned to the
//! pool — and re-dialed on the next call. Results stay bit-identical
//! between 1-node and N-node runs because a remote hit returns the
//! exact bytes the owner stored ([`planes_to_hex`] is a lossless `f32`
//! codec).
//!
//! # Circuit breaker
//!
//! A peer that fails *repeatedly* should not cost every lookup a dial
//! timeout. Each peer carries a breaker:
//!
//! * **Closed** (healthy): calls flow; [`BREAKER_THRESHOLD`]
//!   *consecutive* failures trip it **Open**.
//! * **Open**: calls fail immediately (degrading to local execution,
//!   zero network cost) until [`BREAKER_COOLDOWN`] elapses; the first
//!   call after that flips the breaker **HalfOpen** and goes through as
//!   the probe.
//! * **HalfOpen**: exactly one probe is in flight; concurrent calls
//!   still fail fast. A successful probe re-closes the breaker, a
//!   failed one re-opens it for another cooldown.
//!
//! Transitions are counted in [`TierStats::breaker_opens`] /
//! [`TierStats::breaker_closes`] — `tests/chaos.rs` asserts a flapped
//! peer trips and then recovers. While a breaker is open the fault
//! hook's per-call ordinal does **not** advance (the call never
//! happens), so scripted fault plans stay deterministic regardless of
//! how many lookups race the cooldown window.
//!
//! Breaker and connection-pool state is keyed **per peer address**, not
//! per ring slot: one failed call marks the *peer* down for every key
//! it owns (a dead peer is not rediscovered key by key), and live
//! membership changes ([`RemoteTier::add_peer`] /
//! [`RemoteTier::remove_peer`]) rebuild the ring without resetting the
//! surviving peers' health.
//!
//! # Replication (protocol v6)
//!
//! With `replicas=1` (the default in cluster mode) a *hot* key — one
//! the owner has served at least [`HOT_WATERMARK`] times — is also
//! pushed to the peer with the key's second-highest rendezvous score
//! ([`PeerRing::replica_of`]). When a lookup's owner call fails (dead
//! peer or open breaker) the tier degrades to a **claim-free peek** at
//! the replica (`cache-get` with `peek`) instead of straight to a local
//! launch. The peek registers no cross-node claim, so the degraded mode
//! can at worst duplicate a launch — it can never wedge one — and
//! replication never changes a result, only where it's served from.
//!
//! [`planes_to_hex`]: crate::serve::protocol::planes_to_hex

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use crate::faults::{Faults, PeerFault};
use crate::obs::{HistId, Obs};
use crate::serve::protocol::{
    planes_from_hex, read_frame, write_frame, Message, WireCachePut, WireTrace,
    PROTOCOL_VERSION,
};
use crate::{Error, Result};

use super::key::{Fnv128, Key};
use super::store::{CachedState, ScopedCounters};
use super::tier::{CacheCtx, CacheTier, TierStats, REMOTE_TIER};

/// Dial budget per peer connection. Short on purpose: a down peer
/// should cost one lookup half a second, not hang a study.
const CONNECT_TIMEOUT: Duration = Duration::from_millis(500);
/// Read budget per reply. Long enough to sit out another node's
/// in-flight computation behind a cross-node claim.
const READ_TIMEOUT: Duration = Duration::from_secs(120);
/// Write budget per request frame.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Consecutive failures that trip a peer's breaker open.
const BREAKER_THRESHOLD: u32 = 3;
/// How long an open breaker refuses traffic before admitting one
/// half-open probe.
const BREAKER_COOLDOWN: Duration = Duration::from_millis(250);

/// Serve-count watermark at which an owner pushes a key's state to its
/// replica (see [`RemoteTier::note_served`]).
pub const HOT_WATERMARK: u32 = 2;
/// Bound on the hot-key tracker; crossing it clears the map. Counts
/// restart from zero — replication is an optimization, so losing a
/// count only delays a push, never loses data.
const HOT_TRACKER_CAP: usize = 65_536;

/// Rendezvous (highest-random-weight) partition of the 128-bit key
/// space across a peer list.
///
/// The peer list is sorted and deduplicated at construction, so every
/// node that was handed the same set of addresses — in any order —
/// computes the same owner for every key. Scores are 128-bit FNV
/// digests of the key mixed with the peer address, so ties are
/// vanishingly unlikely and the assignment is uniform in expectation.
#[derive(Clone, Debug)]
pub struct PeerRing {
    peers: Vec<String>,
    self_idx: usize,
}

impl PeerRing {
    /// Build the ring. `self_addr` (this node's listen address) must be
    /// a member of `peers` — the partition only covers nodes that are
    /// actually serving their shard.
    pub fn new(peers: &[String], self_addr: &str) -> Result<Self> {
        let mut peers: Vec<String> = peers.to_vec();
        peers.sort();
        peers.dedup();
        if peers.is_empty() {
            return Err(Error::Config("peers= list is empty".into()));
        }
        let self_idx = peers.iter().position(|p| p == self_addr).ok_or_else(|| {
            Error::Config(format!(
                "peers= list {peers:?} must include this node's listen address `{self_addr}`"
            ))
        })?;
        Ok(Self { peers, self_idx })
    }

    fn score(key: Key, addr: &str) -> Key {
        let mut f = Fnv128::new();
        f.mix(key.lo());
        f.mix(key.hi());
        for b in addr.as_bytes() {
            f.mix(u64::from(*b));
        }
        f.finish()
    }

    /// Index (into the sorted peer list) of the node owning `key`.
    pub fn owner_of(&self, key: Key) -> usize {
        (0..self.peers.len())
            .max_by_key(|&i| Self::score(key, &self.peers[i]))
            .expect("ring is never empty")
    }

    /// Does this node own `key`?
    pub fn is_local(&self, key: Key) -> bool {
        self.owner_of(key) == self.self_idx
    }

    /// The first `n` ring positions for `key` in descending rendezvous
    /// score order: the owner first, then the replica targets.
    pub fn owners_of(&self, key: Key, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.peers.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(Self::score(key, &self.peers[i])));
        idx.truncate(n);
        idx
    }

    /// Index of the key's replica target — the peer with the
    /// second-highest rendezvous score. `None` on a single-node ring.
    pub fn replica_of(&self, key: Key) -> Option<usize> {
        self.owners_of(key, 2).get(1).copied()
    }

    /// A new ring with `addr` added (idempotent when already present).
    /// Rendezvous hashing makes the change minimally disruptive: only
    /// the keys the new peer *wins* change owner.
    pub fn join(&self, addr: &str) -> Result<Self> {
        let mut peers = self.peers.clone();
        peers.push(addr.to_string());
        Self::new(&peers, self.self_addr())
    }

    /// A new ring with `addr` removed (idempotent when absent): only
    /// the departed peer's keys change owner. Removing this node's own
    /// address collapses the ring to just this node — an excluded node
    /// keeps serving, local-only, instead of erroring.
    pub fn leave(&self, addr: &str) -> Self {
        if addr == self.self_addr() {
            return Self { peers: vec![addr.to_string()], self_idx: 0 };
        }
        let peers: Vec<String> =
            self.peers.iter().filter(|p| p.as_str() != addr).cloned().collect();
        Self::new(&peers, self.self_addr()).expect("this node stays a ring member")
    }

    /// The sorted, deduplicated peer list.
    pub fn peers(&self) -> &[String] {
        &self.peers
    }

    /// This node's address as it appears in the ring.
    pub fn self_addr(&self) -> &str {
        &self.peers[self.self_idx]
    }

    /// The address at a ring index (as returned by [`Self::owner_of`]).
    pub fn addr(&self, idx: usize) -> &str {
        &self.peers[idx]
    }
}

/// One peer's circuit-breaker state (see the module docs).
enum BreakerState {
    Closed { failures: u32 },
    Open { since: Instant },
    HalfOpen,
}

/// The remote tier: fetches and publishes cache entries over the serve
/// wire protocol, one pooled connection set per peer, each peer behind
/// its own circuit breaker. Pools and breakers are keyed by peer
/// *address* so a live membership change never resets a surviving
/// peer's health, and one open breaker fails fast for every key that
/// peer owns.
pub struct RemoteTier {
    ring: RwLock<PeerRing>,
    /// This node's ring address; immutable for the tier's lifetime
    /// (leaving your own ring collapses it rather than renaming you).
    self_addr: String,
    /// Idle connections per peer address, returned after a successful
    /// exchange, dropped on any error.
    pools: Mutex<HashMap<String, Vec<TcpStream>>>,
    breakers: Mutex<HashMap<String, BreakerState>>,
    /// Replication factor: how many ring positions beyond the owner may
    /// hold a hot key (0 disables the replica read path).
    replicas: usize,
    /// Per-key remote-serve counts for hot-watermark replication.
    hot: Mutex<HashMap<Key, u32>>,
    connect_timeout: Duration,
    read_timeout: Duration,
    write_timeout: Duration,
    faults: Faults,
    /// Telemetry handle: peer round-trip latencies land in the
    /// [`HistId::PeerRtt`] histogram. Off ([`Obs::none`]) by default.
    obs: Obs,
    hits: AtomicU64,
    stores: AtomicU64,
    breaker_opens: AtomicU64,
    breaker_closes: AtomicU64,
    replica_hits: AtomicU64,
}

impl RemoteTier {
    /// Build the tier for this node. Does not dial anyone — connections
    /// are opened lazily on the first lookup/store per peer.
    pub fn new(peers: &[String], self_addr: &str) -> Result<Self> {
        let ring = PeerRing::new(peers, self_addr)?;
        let self_addr = ring.self_addr().to_string();
        Ok(Self {
            ring: RwLock::new(ring),
            self_addr,
            pools: Mutex::new(HashMap::new()),
            breakers: Mutex::new(HashMap::new()),
            replicas: 1,
            hot: Mutex::new(HashMap::new()),
            connect_timeout: CONNECT_TIMEOUT,
            read_timeout: READ_TIMEOUT,
            write_timeout: WRITE_TIMEOUT,
            faults: Faults::none(),
            obs: Obs::none(),
            hits: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            breaker_opens: AtomicU64::new(0),
            breaker_closes: AtomicU64::new(0),
            replica_hits: AtomicU64::new(0),
        })
    }

    /// Install a fault hook consulted before every admitted peer call
    /// ([`crate::faults::FaultHook::on_peer_call`]).
    pub fn with_faults(mut self, faults: Faults) -> Self {
        self.faults = faults;
        self
    }

    /// Install the telemetry handle (peer RTT histogram; off by
    /// default).
    pub fn with_obs(mut self, obs: Obs) -> Self {
        self.obs = obs;
        self
    }

    /// Set the replication factor (the `replicas=` serve flag).
    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Override the connect/read/write timeouts (test aid: the
    /// timeout-path tests shrink the read budget to milliseconds so a
    /// stalled peer is observed quickly).
    pub fn with_timeouts(mut self, connect: Duration, read: Duration, write: Duration) -> Self {
        self.connect_timeout = connect;
        self.read_timeout = read;
        self.write_timeout = write;
        self
    }

    /// A snapshot of the key partition this tier routes by. The ring
    /// can change under live membership — callers hold a consistent
    /// copy, not a reference.
    pub fn ring(&self) -> PeerRing {
        self.ring.read().unwrap().clone()
    }

    /// This node's ring address.
    pub fn self_addr(&self) -> &str {
        &self.self_addr
    }

    /// Add `addr` to the ring without a restart (idempotent). Returns
    /// the new ring size. Surviving peers' breaker and pool state is
    /// untouched — it is keyed by address, not ring slot.
    pub fn add_peer(&self, addr: &str) -> Result<usize> {
        let mut ring = self.ring.write().unwrap();
        *ring = ring.join(addr)?;
        Ok(ring.peers().len())
    }

    /// Remove `addr` from the ring without a restart (idempotent;
    /// removing this node collapses the ring to a single-node one).
    /// Drops the departed peer's pooled connections and breaker state.
    /// Returns the new ring size.
    pub fn remove_peer(&self, addr: &str) -> usize {
        let size = {
            let mut ring = self.ring.write().unwrap();
            *ring = ring.leave(addr);
            ring.peers().len()
        };
        self.pools.lock().unwrap().remove(addr);
        self.breakers.lock().unwrap().remove(addr);
        size
    }

    /// Count one remote serve of a key this node owns; `true` exactly
    /// when the count crosses [`HOT_WATERMARK`] — the caller should
    /// then push the state to [`RemoteTier::replica_addr`].
    pub fn note_served(&self, key: Key) -> bool {
        let mut hot = self.hot.lock().unwrap();
        if hot.len() >= HOT_TRACKER_CAP {
            hot.clear();
        }
        let count = hot.entry(key).or_insert(0);
        *count += 1;
        *count == HOT_WATERMARK
    }

    /// Where `key`'s replica lives under the current ring — `None`
    /// when replication is off, the ring is single-node, or the
    /// replica position is this node.
    pub fn replica_addr(&self, key: Key) -> Option<String> {
        if self.replicas == 0 {
            return None;
        }
        let ring = self.ring.read().unwrap();
        let addr = ring.addr(ring.replica_of(key)?).to_string();
        (addr != self.self_addr).then_some(addr)
    }

    /// Owner of `key` under the current ring — `None` when this node
    /// is the owner. Membership handoff uses this to push now-foreign
    /// keys to their new home.
    pub fn owner_addr(&self, key: Key) -> Option<String> {
        let ring = self.ring.read().unwrap();
        let addr = ring.addr(ring.owner_of(key)).to_string();
        (addr != self.self_addr).then_some(addr)
    }

    /// Publish a state to a *specific* peer (replication or membership
    /// handoff): a plain `cache-put`, counted under `stores`.
    /// Best-effort like every fabric call.
    pub fn publish_to(&self, addr: &str, key: Key, state: &CachedState) -> bool {
        if addr == self.self_addr {
            return false;
        }
        let put = Message::CachePut(Box::new(WireCachePut::new(key, state)));
        match self.call(addr, &put) {
            Ok(Message::CacheOk { stored: true, .. }) => {
                self.stores.fetch_add(1, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    /// A control-plane exchange with a specific peer (membership relays:
    /// `peer-join` / `peer-leave`). Same transport as the data plane —
    /// pooled connections, fault hook, and the per-address breaker — so
    /// an unreachable peer costs the relay one fast failure, not a
    /// timeout per message.
    pub fn control(&self, addr: &str, msg: &Message) -> Result<Message> {
        self.call(addr, msg)
    }

    /// Dial a peer and run the `hello` handshake in the `peer` role.
    fn connect(&self, addr: &str) -> Result<TcpStream> {
        let sock = addr
            .to_socket_addrs()
            .map_err(Error::Io)?
            .next()
            .ok_or_else(|| Error::Protocol(format!("peer `{addr}` does not resolve")))?;
        let stream =
            TcpStream::connect_timeout(&sock, self.connect_timeout).map_err(Error::Io)?;
        stream.set_read_timeout(Some(self.read_timeout)).map_err(Error::Io)?;
        stream.set_write_timeout(Some(self.write_timeout)).map_err(Error::Io)?;
        let hello = Message::Hello { version: PROTOCOL_VERSION, role: "peer".into() };
        match Self::exchange(&stream, &hello)? {
            Message::Hello { version, .. } if version == PROTOCOL_VERSION => Ok(stream),
            Message::Hello { version, .. } => Err(Error::Protocol(format!(
                "peer {addr} speaks protocol v{version}, this node v{PROTOCOL_VERSION}"
            ))),
            Message::Error { code, message } => {
                Err(Error::Protocol(format!("peer {addr} refused [{code}]: {message}")))
            }
            other => Err(Error::Protocol(format!(
                "peer {addr}: expected `hello`, got `{}`",
                other.type_name()
            ))),
        }
    }

    /// One request/response exchange on an open connection. Safe to
    /// wrap the stream in a fresh `BufReader` per call: the protocol is
    /// strictly request/response on this connection, so the reader
    /// never buffers past the reply frame.
    fn exchange(stream: &TcpStream, msg: &Message) -> Result<Message> {
        let mut writer = BufWriter::new(stream);
        write_frame(&mut writer, msg)?;
        writer.flush().map_err(Error::Io)?;
        drop(writer);
        let mut reader = BufReader::new(stream);
        match read_frame(&mut reader)? {
            Some(reply) => Ok(reply),
            None => Err(Error::Protocol("peer closed the connection".into())),
        }
    }

    /// Admission check against a peer's breaker; flips an expired-open
    /// breaker to half-open (the caller becomes the probe).
    fn breaker_admits(&self, addr: &str) -> bool {
        let mut map = self.breakers.lock().unwrap();
        let b = map.entry(addr.to_string()).or_insert(BreakerState::Closed { failures: 0 });
        match *b {
            BreakerState::Closed { .. } => true,
            BreakerState::Open { since } if since.elapsed() >= BREAKER_COOLDOWN => {
                *b = BreakerState::HalfOpen;
                true
            }
            BreakerState::Open { .. } => false,
            // a probe is already in flight; don't pile on
            BreakerState::HalfOpen => false,
        }
    }

    /// Record a successful call: reset the failure streak; a successful
    /// half-open probe re-closes the breaker.
    fn note_success(&self, addr: &str) {
        let mut map = self.breakers.lock().unwrap();
        let b = map.entry(addr.to_string()).or_insert(BreakerState::Closed { failures: 0 });
        if matches!(*b, BreakerState::HalfOpen) {
            self.breaker_closes.fetch_add(1, Ordering::Relaxed);
        }
        *b = BreakerState::Closed { failures: 0 };
    }

    /// Record a failed call: extend the streak; at the threshold (or on
    /// a failed half-open probe) trip the breaker open.
    fn note_failure(&self, addr: &str) {
        let mut map = self.breakers.lock().unwrap();
        let b = map.entry(addr.to_string()).or_insert(BreakerState::Closed { failures: 0 });
        match *b {
            BreakerState::Closed { failures } if failures + 1 >= BREAKER_THRESHOLD => {
                *b = BreakerState::Open { since: Instant::now() };
                self.breaker_opens.fetch_add(1, Ordering::Relaxed);
            }
            BreakerState::Closed { failures } => {
                *b = BreakerState::Closed { failures: failures + 1 };
            }
            BreakerState::HalfOpen => {
                *b = BreakerState::Open { since: Instant::now() };
                self.breaker_opens.fetch_add(1, Ordering::Relaxed);
            }
            BreakerState::Open { .. } => {}
        }
    }

    fn pool_pop(&self, addr: &str) -> Option<TcpStream> {
        self.pools.lock().unwrap().get_mut(addr).and_then(|v| v.pop())
    }

    fn pool_push(&self, addr: &str, stream: TcpStream) {
        self.pools.lock().unwrap().entry(addr.to_string()).or_default().push(stream);
    }

    /// Send `msg` to the peer at `addr` through its breaker and the
    /// fault hook; every outcome feeds the breaker.
    fn call(&self, addr: &str, msg: &Message) -> Result<Message> {
        if !self.breaker_admits(addr) {
            return Err(Error::Protocol(format!("peer {addr}: circuit breaker open")));
        }
        if let Some(fault) = self.faults.get().and_then(|h| h.on_peer_call(addr)) {
            match fault {
                PeerFault::Refuse => {
                    self.note_failure(addr);
                    return Err(Error::Protocol(format!(
                        "peer {addr}: fault injection: connection refused"
                    )));
                }
                PeerFault::Drop => {
                    // the connection died mid-exchange: whatever was
                    // pooled is gone too
                    self.pools.lock().unwrap().remove(addr);
                    self.note_failure(addr);
                    return Err(Error::Protocol(format!(
                        "peer {addr}: fault injection: connection dropped mid-exchange"
                    )));
                }
                PeerFault::Delay(latency) => std::thread::sleep(latency),
            }
        }
        let started = self.obs.is_active().then(Instant::now);
        let result = self.call_raw(addr, msg);
        if let Some(t) = started {
            // RTT is a fabric property, not a tenant's doing: global only
            self.obs.observe(HistId::PeerRtt, None, t.elapsed());
        }
        match result {
            Ok(_) => self.note_success(addr),
            Err(_) => self.note_failure(addr),
        }
        result
    }

    /// The unguarded exchange: reuse a pooled connection when one is
    /// idle; a stale pooled connection is dropped and the call retried
    /// once on a fresh dial. A connection that errors (including a read
    /// timeout or an unparsable reply) is never returned to the pool.
    fn call_raw(&self, addr: &str, msg: &Message) -> Result<Message> {
        if let Some(stream) = self.pool_pop(addr) {
            if let Ok(reply) = Self::exchange(&stream, msg) {
                self.pool_push(addr, stream);
                return Ok(reply);
            }
        }
        let stream = self.connect(addr)?;
        let reply = Self::exchange(&stream, msg)?;
        self.pool_push(addr, stream);
        Ok(reply)
    }

    /// Decode a `found` cache-state payload into a cached state.
    fn decode_hit(&self, h: u64, w: u64, planes: &str) -> Option<CachedState> {
        let planes = planes_from_hex(h, w, planes).ok()?;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(Arc::new(planes))
    }

    /// The trace context to stamp onto outgoing fabric frames: the
    /// caller's trace id plus its lookup/publish span id, which the
    /// owner's `serve-get`/`serve-put` span parents under. `None` (no
    /// fields on the wire) when untraced.
    fn wire_trace(ctx: &CacheCtx) -> Option<WireTrace> {
        ctx.span().map(|sc| WireTrace { trace: sc.trace, span: sc.parent })
    }
}

impl CacheTier for RemoteTier {
    fn name(&self) -> &'static str {
        REMOTE_TIER
    }

    fn lookup(&self, key: Key, ctx: &CacheCtx) -> Option<CachedState> {
        let (owner, replica) = {
            let ring = self.ring.read().unwrap();
            if ring.is_local(key) {
                return None;
            }
            let owner = ring.addr(ring.owner_of(key)).to_string();
            let replica = (self.replicas >= 1)
                .then(|| ring.replica_of(key).map(|i| ring.addr(i).to_string()))
                .flatten();
            (owner, replica)
        };
        let trace = Self::wire_trace(ctx);
        match self.call(&owner, &Message::CacheGet { key, peek: false, trace }) {
            Ok(Message::CacheState(state)) if state.found => {
                self.decode_hit(state.h, state.w, &state.planes)
            }
            // `claimed` (or anything unexpected): this node now holds
            // the cross-node claim and must compute locally and publish
            // through `store` — peeking a replica here would break
            // single-flight.
            Ok(_) => None,
            // The owner is unreachable (or its breaker is open):
            // degrade to a claim-free peek at the replica. When the
            // replica position is this node the peek is pointless —
            // our own tiers already missed.
            Err(_) => {
                let replica = replica.filter(|r| *r != self.self_addr)?;
                match self.call(&replica, &Message::CacheGet { key, peek: true, trace }).ok()? {
                    Message::CacheState(state) if state.found => {
                        self.replica_hits.fetch_add(1, Ordering::Relaxed);
                        self.decode_hit(state.h, state.w, &state.planes)
                    }
                    _ => None,
                }
            }
        }
    }

    fn store(&self, key: Key, state: &CachedState, ctx: &CacheCtx) -> bool {
        let Some(owner) = self.owner_addr(key) else {
            return false;
        };
        let mut put = WireCachePut::new(key, state);
        put.trace = Self::wire_trace(ctx);
        let put = Message::CachePut(Box::new(put));
        match self.call(&owner, &put) {
            Ok(Message::CacheOk { stored: true, .. }) => {
                self.stores.fetch_add(1, Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    fn evict_scope(&self, _scope: &Arc<ScopedCounters>) -> bool {
        false
    }

    fn stats(&self) -> TierStats {
        TierStats {
            hits: self.hits.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            resident_bytes: 0,
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_closes: self.breaker_closes.load(Ordering::Relaxed),
            replica_hits: self.replica_hits.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Plane;
    use crate::faults::FaultPlan;
    use crate::serve::protocol::WireCacheState;
    use std::net::TcpListener;

    fn state() -> CachedState {
        Arc::new([Plane::filled(1.0, 2, 2), Plane::filled(0.5, 2, 2), Plane::filled(2.0, 2, 2)])
    }

    #[test]
    fn ring_is_order_insensitive_and_covers_every_peer() {
        let a = vec!["h1:1".to_string(), "h2:2".to_string(), "h3:3".to_string()];
        let b = vec!["h3:3".to_string(), "h1:1".to_string(), "h2:2".to_string()];
        let ra = PeerRing::new(&a, "h1:1").unwrap();
        let rb = PeerRing::new(&b, "h2:2").unwrap();
        let mut owned = [0usize; 3];
        for i in 0..512u64 {
            let key = Key::from(i);
            let owner = ra.owner_of(key);
            assert_eq!(
                ra.peers()[owner],
                rb.peers()[rb.owner_of(key)],
                "same owner from any list order"
            );
            owned[owner] += 1;
        }
        assert!(owned.iter().all(|&n| n > 0), "every peer owns a shard: {owned:?}");
    }

    #[test]
    fn ring_requires_self_membership_and_a_nonempty_list() {
        let peers = vec!["h1:1".to_string(), "h2:2".to_string()];
        let err = PeerRing::new(&peers, "h9:9").unwrap_err();
        assert!(err.to_string().contains("h9:9"), "error names the missing address: {err}");
        assert!(PeerRing::new(&[], "h1:1").is_err());
        // duplicates collapse
        let dup = vec!["h1:1".to_string(), "h1:1".to_string(), "h2:2".to_string()];
        assert_eq!(PeerRing::new(&dup, "h1:1").unwrap().peers().len(), 2);
    }

    /// A key owned by the given address under this tier's ring.
    fn key_owned_by(tier: &RemoteTier, addr: &str) -> Key {
        (0..u64::MAX)
            .map(Key::from)
            .find(|k| tier.ring().peers()[tier.ring().owner_of(*k)] == addr)
            .unwrap()
    }

    #[test]
    fn self_owned_keys_are_inert_and_dead_peers_degrade_to_misses() {
        // Port 1 on loopback refuses immediately: the fabric must turn
        // that into a plain miss, not an error or a hang.
        let peers = vec!["127.0.0.1:1".to_string(), "127.0.0.1:9".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:9").unwrap();
        let ctx = CacheCtx::unscoped();
        let (mut local, mut remote) = (0, 0);
        for i in 0..64u64 {
            let key = Key::from(i);
            if tier.ring().is_local(key) {
                local += 1;
            } else {
                remote += 1;
            }
            assert!(tier.lookup(key, &ctx).is_none());
            assert!(!tier.store(key, &state(), &ctx));
            if local > 0 && remote > 1 {
                break;
            }
        }
        assert!(local > 0 && remote > 0, "sampled both shards ({local} local, {remote} remote)");
        let st = tier.stats();
        assert_eq!((st.hits, st.stores), (0, 0), "failed calls never count");
    }

    /// A mini peer: handshakes each accepted connection, then answers
    /// `cache-get` with `found` and `cache-put` with `stored`. Exits
    /// after `conns` connections close; returns total frames served.
    fn spawn_mini_peer(listener: TcpListener, conns: usize) -> std::thread::JoinHandle<u32> {
        std::thread::spawn(move || {
            let mut served = 0;
            for _ in 0..conns {
                let Ok((stream, _)) = listener.accept() else {
                    break;
                };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream);
                while let Ok(Some(msg)) = read_frame(&mut reader) {
                    let reply = match msg {
                        Message::Hello { .. } => {
                            Message::Hello { version: PROTOCOL_VERSION, role: "server".into() }
                        }
                        Message::CacheGet { key, .. } => {
                            served += 1;
                            Message::CacheState(Box::new(WireCacheState::found(key, &state())))
                        }
                        Message::CachePut(put) => {
                            served += 1;
                            Message::CacheOk { key: put.key, stored: true }
                        }
                        other => panic!("mini peer got {}", other.type_name()),
                    };
                    write_frame(&mut writer, &reply).unwrap();
                    writer.flush().unwrap();
                }
            }
            served
        })
    }

    #[test]
    fn fetches_and_publishes_through_a_live_peer_on_one_pooled_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = spawn_mini_peer(listener, 1);

        let peers = vec![addr.clone(), "127.0.0.1:1".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:1").unwrap();
        let ctx = CacheCtx::unscoped();
        let key = key_owned_by(&tier, &addr);

        let got = tier.lookup(key, &ctx).expect("peer holds the state");
        assert_eq!(got[0].data(), state()[0].data(), "payload survives the wire");
        assert!(tier.store(key, &state(), &ctx), "publish acknowledges");
        let st = tier.stats();
        assert_eq!((st.hits, st.stores), (1, 1));
        assert_eq!((st.breaker_opens, st.breaker_closes), (0, 0), "healthy peer: no trips");

        drop(tier); // closes the pooled connection; the peer thread exits
        assert_eq!(handle.join().unwrap(), 2, "both calls reused one connection");
    }

    /// A peer that handshakes correctly, then stalls forever on the
    /// first real request (reads it, answers nothing).
    fn spawn_stalling_peer(listener: TcpListener) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut held = Vec::new();
            while let Ok((stream, _)) = listener.accept() {
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream.try_clone().unwrap());
                if let Ok(Some(Message::Hello { .. })) = read_frame(&mut reader) {
                    let hello = Message::Hello { version: PROTOCOL_VERSION, role: "server".into() };
                    write_frame(&mut writer, &hello).unwrap();
                    writer.flush().unwrap();
                }
                let _ = read_frame(&mut reader); // swallow the request, reply never comes
                held.push(stream); // keep the socket open so the client must time out
                if held.len() >= 4 {
                    break;
                }
            }
        })
    }

    #[test]
    fn mid_frame_read_timeout_degrades_to_a_miss_and_trips_the_breaker() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let _peer = spawn_stalling_peer(listener);

        let peers = vec![addr.clone(), "127.0.0.1:1".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:1")
            .unwrap()
            .with_timeouts(CONNECT_TIMEOUT, Duration::from_millis(50), WRITE_TIMEOUT);
        let ctx = CacheCtx::unscoped();
        let key = key_owned_by(&tier, &addr);

        // three stalled exchanges: each degrades to a miss, never panics
        for _ in 0..BREAKER_THRESHOLD {
            assert!(tier.lookup(key, &ctx).is_none(), "stalled reply reads as a miss");
        }
        let st = tier.stats();
        assert_eq!(st.breaker_opens, 1, "three consecutive timeouts trip the breaker");
        // breaker open: the next call fails fast — no dial, no 50 ms wait
        let t0 = Instant::now();
        assert!(tier.lookup(key, &ctx).is_none());
        assert!(
            t0.elapsed() < Duration::from_millis(40),
            "open breaker must fail fast, took {:?}",
            t0.elapsed()
        );
    }

    /// A peer that handshakes, then answers the first `cache-get` with
    /// a poison frame (valid header, garbage JSON body) and every later
    /// one honestly.
    fn spawn_poison_peer(listener: TcpListener) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut first = true;
            for _ in 0..2 {
                let Ok((stream, _)) = listener.accept() else {
                    return;
                };
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = BufWriter::new(stream);
                while let Ok(Some(msg)) = read_frame(&mut reader) {
                    match msg {
                        Message::Hello { .. } => {
                            let hello =
                                Message::Hello { version: PROTOCOL_VERSION, role: "server".into() };
                            write_frame(&mut writer, &hello).unwrap();
                        }
                        Message::CacheGet { key, .. } => {
                            if std::mem::take(&mut first) {
                                writer.write_all(b"rtfp1 9\nnot-json!\n").unwrap();
                            } else {
                                let found = Message::CacheState(Box::new(WireCacheState::found(
                                    key,
                                    &state(),
                                )));
                                write_frame(&mut writer, &found).unwrap();
                            }
                        }
                        other => panic!("poison peer got {}", other.type_name()),
                    }
                    writer.flush().unwrap();
                }
            }
        })
    }

    #[test]
    fn poison_cache_state_frame_misses_without_poisoning_the_pool() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let _peer = spawn_poison_peer(listener);

        let peers = vec![addr.clone(), "127.0.0.1:1".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:1").unwrap();
        let ctx = CacheCtx::unscoped();
        let key = key_owned_by(&tier, &addr);

        assert!(tier.lookup(key, &ctx).is_none(), "poison frame degrades to a miss");
        // the poisoned connection was dropped, not pooled: the next
        // lookup dials fresh and succeeds
        let got = tier.lookup(key, &ctx).expect("recovered on a fresh connection");
        assert_eq!(got[0].data(), state()[0].data());
        let st = tier.stats();
        assert_eq!(st.hits, 1);
        assert_eq!(st.breaker_opens, 0, "one failure is below the breaker threshold");
    }

    #[test]
    fn scripted_peer_flap_opens_the_breaker_and_a_probe_recovers_it() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let handle = spawn_mini_peer(listener, 1);

        let plan = Arc::new({
            let mut p = FaultPlan::new();
            for n in 1..=u64::from(BREAKER_THRESHOLD) {
                p = p.peer_fault(n, PeerFault::Refuse);
            }
            p
        });
        let peers = vec![addr.clone(), "127.0.0.1:1".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:1")
            .unwrap()
            .with_faults(Faults::hooked(plan.clone()));
        let ctx = CacheCtx::unscoped();
        let key = key_owned_by(&tier, &addr);

        // the flap: three scripted refusals trip the breaker
        for _ in 0..BREAKER_THRESHOLD {
            assert!(tier.lookup(key, &ctx).is_none());
        }
        assert_eq!(tier.stats().breaker_opens, 1);
        assert_eq!(plan.fired().peer_faults, u64::from(BREAKER_THRESHOLD));

        // while open, calls fail fast and do NOT advance the fault
        // ordinal (the call never happens)
        assert!(tier.lookup(key, &ctx).is_none());
        assert_eq!(plan.seen().peer_faults, u64::from(BREAKER_THRESHOLD));

        // after the cooldown, one probe goes through, succeeds against
        // the (healthy) live peer, and re-closes the breaker
        std::thread::sleep(BREAKER_COOLDOWN + Duration::from_millis(50));
        let got = tier.lookup(key, &ctx).expect("half-open probe succeeds");
        assert_eq!(got[0].data(), state()[0].data());
        let st = tier.stats();
        assert_eq!((st.breaker_opens, st.breaker_closes), (1, 1), "tripped once, recovered once");
        assert_eq!(st.hits, 1);

        drop(tier);
        assert_eq!(handle.join().unwrap(), 1, "only the probe reached the peer");
    }

    #[test]
    fn ring_join_and_leave_are_idempotent_and_keep_self() {
        let peers = vec!["h1:1".to_string(), "h2:2".to_string()];
        let ring = PeerRing::new(&peers, "h1:1").unwrap();
        let grown = ring.join("h3:3").unwrap();
        assert_eq!(grown.peers(), ["h1:1", "h2:2", "h3:3"]);
        assert_eq!(grown.join("h3:3").unwrap().peers().len(), 3, "re-join is a no-op");
        let shrunk = grown.leave("h2:2");
        assert_eq!(shrunk.peers(), ["h1:1", "h3:3"]);
        assert_eq!(shrunk.leave("h9:9").peers().len(), 2, "unknown leave is a no-op");
        // excluded from its own ring: collapse to single-node, keep serving
        let alone = shrunk.leave("h1:1");
        assert_eq!(alone.peers(), ["h1:1"]);
        assert_eq!(alone.self_addr(), "h1:1");
        // owner + replica are the top-2 distinct rendezvous scores
        let key = Key::from(42u64);
        let top = grown.owners_of(key, 2);
        assert_eq!(top[0], grown.owner_of(key));
        assert_eq!(Some(top[1]), grown.replica_of(key));
        assert_ne!(top[0], top[1]);
        let solo = PeerRing::new(&["h1:1".to_string()], "h1:1").unwrap();
        assert!(solo.replica_of(key).is_none(), "single-node ring has no replica");
    }

    /// A peer that answers `cache-get` only when it carries `peek` —
    /// the replica read path must never send a claiming get.
    fn spawn_peek_only_peer(listener: TcpListener) -> std::thread::JoinHandle<u32> {
        std::thread::spawn(move || {
            let mut served = 0;
            let Ok((stream, _)) = listener.accept() else {
                return served;
            };
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            while let Ok(Some(msg)) = read_frame(&mut reader) {
                let reply = match msg {
                    Message::Hello { .. } => {
                        Message::Hello { version: PROTOCOL_VERSION, role: "server".into() }
                    }
                    Message::CacheGet { key, peek, .. } => {
                        assert!(peek, "replica reads must be claim-free peeks");
                        served += 1;
                        Message::CacheState(Box::new(WireCacheState::found(key, &state())))
                    }
                    other => panic!("peek peer got {}", other.type_name()),
                };
                write_frame(&mut writer, &reply).unwrap();
                writer.flush().unwrap();
            }
            served
        })
    }

    #[test]
    fn a_dead_owner_degrades_to_a_claim_free_replica_peek() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let replica_addr = listener.local_addr().unwrap().to_string();
        let handle = spawn_peek_only_peer(listener);

        let dead = "127.0.0.1:1".to_string();
        let peers = vec![dead.clone(), replica_addr.clone(), "127.0.0.1:9".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:9").unwrap();
        let ring = tier.ring();
        // a key the dead peer owns whose replica is the live peer
        let key = (0..u64::MAX)
            .map(Key::from)
            .find(|k| {
                ring.peers()[ring.owner_of(*k)] == dead
                    && ring.replica_of(*k).map(|i| ring.peers()[i].as_str())
                        == Some(replica_addr.as_str())
            })
            .unwrap();
        let ctx = CacheCtx::unscoped();
        let got = tier.lookup(key, &ctx).expect("replica serves the peek");
        assert_eq!(got[0].data(), state()[0].data());
        assert_eq!(tier.stats().hits, 1, "a replica hit is still a remote hit");

        // replicas=0 turns the fallback off: the same lookup is a miss
        let tier0 = RemoteTier::new(&peers, "127.0.0.1:9").unwrap().with_replicas(0);
        assert!(tier0.lookup(key, &ctx).is_none());
        assert_eq!(tier0.stats().hits, 0);
        drop(tier);
        assert_eq!(handle.join().unwrap(), 1, "only the replicated lookup peeked");
    }

    #[test]
    fn per_address_breaker_survives_a_live_ring_rebuild() {
        let dead = "127.0.0.1:1".to_string();
        let peers = vec![dead.clone(), "127.0.0.1:9".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:9").unwrap().with_replicas(0);
        let ctx = CacheCtx::unscoped();
        let key = key_owned_by(&tier, &dead);
        for _ in 0..BREAKER_THRESHOLD {
            assert!(tier.lookup(key, &ctx).is_none());
        }
        assert_eq!(tier.stats().breaker_opens, 1, "dead peer tripped once");

        // a join rebuilds the ring; the dead peer's breaker must stay
        // open — health is per address, not per ring slot
        assert_eq!(tier.add_peer("127.0.0.1:7").unwrap(), 3);
        let key = key_owned_by(&tier, &dead);
        for _ in 0..BREAKER_THRESHOLD {
            assert!(tier.lookup(key, &ctx).is_none());
        }
        assert_eq!(
            tier.stats().breaker_opens,
            1,
            "open breaker survived the rebuild: the peer is not rediscovered key by key"
        );

        // leaving drops the dead peer's state; its keys get new owners
        assert_eq!(tier.remove_peer(&dead), 2);
        assert!(!tier.ring().peers().contains(&dead));
    }

    #[test]
    fn hot_keys_cross_the_watermark_once_and_publish_to_the_replica() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let replica_addr = listener.local_addr().unwrap().to_string();
        let handle = spawn_mini_peer(listener, 1);

        let peers = vec![replica_addr.clone(), "127.0.0.1:9".to_string()];
        let tier = RemoteTier::new(&peers, "127.0.0.1:9").unwrap();
        // a key this node owns: its replica is the other peer
        let key = key_owned_by(&tier, "127.0.0.1:9");
        assert_eq!(tier.replica_addr(key).as_deref(), Some(replica_addr.as_str()));

        let crossings = (0..4).filter(|_| tier.note_served(key)).count();
        assert_eq!(crossings, 1, "the watermark fires exactly once per key");
        assert!(tier.publish_to(&replica_addr, key, &state()));
        assert!(!tier.publish_to(tier.self_addr(), key, &state()), "self-publish is inert");
        assert_eq!(tier.stats().stores, 1);
        drop(tier);
        assert_eq!(handle.join().unwrap(), 1, "one cache-put reached the replica");
    }
}
