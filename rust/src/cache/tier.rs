//! The cache-tier abstraction: one trait every storage tier implements,
//! and the per-call accounting context the tiers share.
//!
//! [`crate::cache::ReuseCache`] is a *stack* of tiers: a resident memory
//! LRU on top, then any number of lower tiers consulted in order on a
//! memory miss — today the persistent RTC3 disk tier
//! ([`super::disk::DiskTier`]) and the cluster fabric
//! ([`super::remote::RemoteTier`]), which fetches and publishes entries
//! on the peer that owns the key. The stack owns everything that is
//! *not* storage: single-flight claims, the metrics side map, scoped
//! accounting and the global [`super::store::CacheStats`]. Tiers only
//! answer "do you hold this state" ([`CacheTier::lookup`]) and "keep
//! this state" ([`CacheTier::store`]); a lower-tier hit is promoted into
//! the memory tier by the stack, charged to the requesting scope.
//!
//! [`CacheCtx`] is the collapsed accounting context: where the pre-tier
//! API threaded an `Option<&Arc<ScopedCounters>>` through every lookup,
//! store and quota path, callers now build one context per logical
//! caller (a tenant's engine, a test, a bench) and pass it to every
//! cache call. Unscoped traffic is [`CacheCtx::unscoped`]; the
//! multi-tenant service builds one [`CacheCtx::scoped`] per tenant.
//!
//! Cluster phase 2 (rtfp v6) rides entirely on this abstraction: a
//! hot-prefix replica is published with an ordinary
//! [`CacheTier::store`] on the replica's node, and a replica read is an
//! ordinary [`CacheTier::lookup`] answered by the remote tier's
//! claim-free `peek` path — no new tier kind, no new counters, and the
//! stack cannot tell a replicated entry from a locally computed one.
//! Replication never changes a result, only where it's served from.

use std::sync::Arc;

use super::key::Key;
use super::store::{CachedState, ScopedCounters};
use crate::obs::{Obs, SpanCtx};

/// Canonical tier names. The stack maps a lower tier's hits and stores
/// onto the global counters by name: [`DISK_TIER`] feeds
/// `disk_hits`/`spilled`, every other lower tier feeds `remote_hits`.
pub const MEMORY_TIER: &str = "memory";
pub const DISK_TIER: &str = "disk";
pub const REMOTE_TIER: &str = "remote";

/// The accounting context of one cache call: which tenant scope (if
/// any) the operation is counted under and which scope owns entries it
/// admits. Cheap to clone (an `Arc` bump); build it once per logical
/// caller and pass it by reference to every cache operation.
#[derive(Clone, Debug, Default)]
pub struct CacheCtx {
    scope: Option<Arc<ScopedCounters>>,
    obs: Obs,
    span: Option<SpanCtx>,
}

impl CacheCtx {
    /// Unscoped traffic: only the global counters are bumped, admitted
    /// entries are unowned (exempt from every quota).
    pub fn unscoped() -> Self {
        Self::default()
    }

    /// Tenant-scoped traffic: every counted operation mirrors into
    /// `scope`, and admitted entries are owned by (charged to) it.
    pub fn scoped(scope: Arc<ScopedCounters>) -> Self {
        Self { scope: Some(scope), ..Self::default() }
    }

    /// The scope this context counts under, if any.
    pub fn scope(&self) -> Option<&Arc<ScopedCounters>> {
        self.scope.as_ref()
    }

    /// Attach (or detach) the telemetry handle and the span context
    /// cache operations should parent under. With the handle off this
    /// context behaves exactly as before — telemetry off is zero-cost.
    pub fn set_obs(&mut self, obs: Obs, span: Option<SpanCtx>) {
        self.obs = obs;
        self.span = span;
    }

    /// The telemetry handle (off by default).
    pub fn obs(&self) -> &Obs {
        &self.obs
    }

    /// The span context this call chain runs under, if tracing.
    pub fn span(&self) -> Option<&SpanCtx> {
        self.span.as_ref()
    }

    /// A child of this context whose operations parent under `span`
    /// (same scope, same handle) — how a per-tier lookup hands the tier
    /// its own span id so wire frames can carry it.
    pub fn with_span(&self, span: SpanCtx) -> Self {
        Self { scope: self.scope.clone(), obs: self.obs.clone(), span: Some(span) }
    }
}

/// A point-in-time snapshot of one tier's own counters (diagnostics;
/// the billing-grade counters live in the stack's
/// [`super::store::CacheStats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Lookups this tier answered.
    pub hits: u64,
    /// Entries this tier newly stored.
    pub stores: u64,
    /// Bytes resident in this tier (0 for tiers that do not account
    /// bytes, e.g. the remote fabric).
    pub resident_bytes: u64,
    /// Circuit-breaker transitions into Open (0 for tiers without a
    /// breaker — today only the remote fabric trips one; see
    /// [`super::remote::RemoteTier`]).
    pub breaker_opens: u64,
    /// Circuit-breaker recoveries: HalfOpen probes that succeeded and
    /// re-closed a peer's breaker.
    pub breaker_closes: u64,
    /// Lookups served from a hot-prefix *replica* rather than the key's
    /// owner (rtfp v6 failover reads; 0 for tiers without replicas).
    pub replica_hits: u64,
}

/// One storage tier of the reuse cache. Implementations must be cheap
/// to consult on a miss (a lookup that cannot answer returns `None`
/// fast) and infallible from the stack's point of view: a tier that
/// cannot reach its backing store (unreadable file, dead peer) reports
/// a miss or a failed store, never an error — the cache is an
/// accelerator, not a source of truth.
pub trait CacheTier: Send + Sync {
    /// The tier's canonical name (see [`MEMORY_TIER`], [`DISK_TIER`],
    /// [`REMOTE_TIER`]); the stack keys its counter mapping on this.
    fn name(&self) -> &'static str;

    /// Fetch the state stored under `key`, if this tier holds it.
    fn lookup(&self, key: Key, ctx: &CacheCtx) -> Option<CachedState>;

    /// Offer a state for storage under `key`. Returns true when the
    /// tier newly stored it (false: already present, not admitted, or
    /// the backing store is unreachable).
    fn store(&self, key: Key, state: &CachedState, ctx: &CacheCtx) -> bool;

    /// Evict one entry owned by `scope` (quota enforcement). Returns
    /// false when the tier holds nothing evictable for that scope;
    /// tiers without scoped residency (disk, remote) always return
    /// false.
    fn evict_scope(&self, scope: &Arc<ScopedCounters>) -> bool;

    /// This tier's own counters.
    fn stats(&self) -> TierStats;
}
