//! The multi-tenant study service: one long-lived process serving many
//! concurrent SA studies from ONE shared reuse cache — in-process or
//! over TCP.
//!
//! Everything below this module runs *per study*; this module is the
//! layer that makes the per-study machinery multi-tenant. A
//! [`StudyService`] owns, for the lifetime of the process:
//!
//! * one [`crate::cache::ReuseCache`] — every tenant's studies read and
//!   populate the same content-addressed store, so one tenant's Morris
//!   screen warms the next tenant's VBD refinement (the run-time
//!   cross-study reuse of arXiv:1910.14548, lifted across tenants).
//!   Tenants are byte-bounded: each tenant's counter scope may carry a
//!   **memory-tier quota** ([`crate::cache::ScopedCounters::with_quota`])
//!   that its owned entries cannot exceed, and at boot the cache can be
//!   **warm-started** from the persistent disk tier
//!   ([`crate::cache::ReuseCache::warm_start`]) so the first tenant of
//!   the day already finds memory hits;
//! * one *leader* [`crate::runtime::PjrtEngine`] — loaded and compiled
//!   once, it builds the memoized per-workload [`StudyInputs`]
//!   (synthetic tiles + reference masks), so concurrent tenants running
//!   the same workload never duplicate the reference-chain launches;
//! * a bounded pool of service workers pulling [`StudyJob`]s from a
//!   submission queue with **weighted-fair admission** — a stride
//!   scheduler serves tenants proportionally to their configured
//!   priority weights (starvation-free; FIFO within a tenant) under a
//!   per-tenant in-flight cap — and **graceful drain** (no new
//!   submissions, queued work completes, workers join).
//!
//! The service runs two job kinds against that one cache: plain SA
//! **studies** ([`StudyService::submit`]) and **tuning runs**
//! ([`StudyService::submit_tune`], [`crate::tune`]) — optimizer loops
//! whose candidate generations execute as batched studies under the
//! tenant's account. Tuning is the highest-reuse workload of all
//! (optimizers revisit quantized points constantly), so concurrent
//! tuning tenants lean on the shared cache hardest.
//!
//! Two run-time adaptivity layers ride on top (protocol v5): studies
//! submitted with `adaptive=on` run through [`crate::adaptive`] — the
//! incremental estimator prunes not-yet-launched work once a
//! parameter's confidence interval drops below threshold, billed as
//! `pruned` — and with `speculate=on`, idle workers pre-execute a
//! tuning job's *predicted* next generation through the single-flight
//! cache path under the [`SPECULATIVE_TENANT`] pseudo-scope.
//! Speculation can only ever warm the cache; it never changes a result.
//!
//! The network layer on top ([`protocol`], [`server`], [`client`])
//! turns the in-process queue into a service remote clients drive over
//! TCP: `rtf-reuse serve listen=ADDR` accepts length-delimited JSONL
//! frames (`submit` / `submit-tune` / `status` / `result` / `drain`),
//! and `rtf-reuse serve submit=ADDR jobs=FILE` is the in-tree client.
//! With `peers=ADDR,...` the same process joins a **cluster**: the
//! 128-bit key space is rendezvous-partitioned across peers, each node
//! attaches a [`crate::cache::RemoteTier`] below its local tiers, and
//! misses on keys another node owns are resolved over the protocol-v3
//! `cache-get` / `cache-put` messages — with single-flight claims that
//! hold across the remote boundary. Protocol v6 grows the cluster three
//! ways: **front-door routing** (`route=on` — any node accepts a submit
//! and forwards it to the peer owning most of the study's predicted
//! chain keys, proxying the result back), **hot-prefix replication**
//! (`replicas=N` — keys served to peers past a hit watermark are pushed
//! to the ring's next peer, so a dead owner degrades to replica hits
//! instead of local launches), and **live membership** (`peer-join` /
//! `peer-leave` wire messages and `peers add=/remove=` jobs-file admin
//! lines rebuild every node's ring without a restart, with owned-key
//! handoff as a background drain). Replication and routing never change
//! a result, only where it's computed or served from. Protocol v7 adds
//! the **telemetry surface** ([`crate::obs`]): `trace=FILE` streams
//! structured JSONL spans (admit → queue → schedule → per-level
//! execution → per-tier lookups → launches → retries → drain),
//! `stats=on` keeps a live metrics registry and logs a one-line digest,
//! a `stats` wire message returns the full snapshot (rendered as a
//! Prometheus-style dump by [`render_prometheus`]), and `route` /
//! `cache-get` / `cache-put` frames carry an optional trace context so
//! a routed job's spans stitch into one cross-node tree.
//! Telemetry off is zero-cost; telemetry on never changes a result.
//! `docs/SERVING.md` is the operator's guide and the normative
//! protocol spec; `docs/OBSERVABILITY.md` covers the telemetry surface.
//!
//! Correctness under tenancy rests on the cache properties of
//! [`crate::cache`]: 128-bit content keys (collision margin for a
//! process-lifetime key population), single-flight miss claims (two
//! tenants missing the same key execute it once), per-tenant
//! [`crate::cache::ScopedCounters`] whose sums equal the global
//! counters — the accounting the per-tenant bill is built from — and
//! quota eviction that charges the entry's *owning* scope.
//!
//! `benches/multi_tenant.rs` (N identical tenants ⇒ aggregate backend
//! launches ≤ 1.25× one cold tenant) and `benches/serve_warm.rs`
//! (restart ⇒ first job already hits) are the acceptance benchmarks;
//! `tests/serve_wire.rs` drives a loopback client/server end to end.
//!
//! Backend note: the leader engine is held in a `Mutex` across service
//! threads, which requires the engine to be `Send`. The in-tree native
//! backend satisfies this; substituting the published `xla` binding
//! (whose PJRT handles are thread-bound) would need a
//! load-per-build fallback here.
//!
//! [`StudyInputs`]: crate::driver::StudyInputs

pub mod client;
pub mod protocol;
pub mod server;
mod service;

pub use client::{
    parse_job_lines, parse_jobs_file, render_prometheus, run_jobs, run_lines, ClientOutcome,
    JobLine, JobSpec,
};
pub use protocol::{
    WireBill, WireJobReport, WireStats, WireTenantBill, WireTierStats, WireTrace,
    PROTOCOL_VERSION,
};
pub use server::WireServer;
pub use service::{
    stats_digest, JobReport, ServeOptions, ServiceReport, StudyJob, StudyService, TenantReport,
    SPECULATIVE_TENANT,
};
