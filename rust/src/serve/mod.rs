//! The multi-tenant study service: one long-lived process serving many
//! concurrent SA studies from ONE shared reuse cache.
//!
//! Everything below this module runs *per study*; this module is the
//! layer that makes the per-study machinery multi-tenant. A
//! [`StudyService`] owns, for the lifetime of the process:
//!
//! * one [`crate::cache::ReuseCache`] — every tenant's studies read and
//!   populate the same content-addressed store, so one tenant's Morris
//!   screen warms the next tenant's VBD refinement (the run-time
//!   cross-study reuse of arXiv:1910.14548, lifted across tenants);
//! * one *leader* [`crate::runtime::PjrtEngine`] — loaded and compiled
//!   once, it builds the memoized per-workload [`StudyInputs`]
//!   (synthetic tiles + reference masks), so concurrent tenants running
//!   the same workload never duplicate the reference-chain launches;
//! * a bounded pool of service workers pulling [`StudyJob`]s from a
//!   submission queue, with **fair admission** (a per-tenant in-flight
//!   cap keeps one noisy tenant from monopolizing the pool) and
//!   **graceful drain** (no new submissions, queued work completes,
//!   workers join).
//!
//! Correctness under tenancy rests on three cache properties
//! (see [`crate::cache`]): 128-bit content keys (collision margin for a
//! process-lifetime key population), single-flight miss claims (two
//! tenants missing the same key execute it once), and per-tenant
//! [`crate::cache::ScopedCounters`] whose sums equal the global
//! counters — the accounting the per-tenant bill is built from.
//!
//! `rtf-reuse serve` is the CLI entry; `benches/multi_tenant.rs` is the
//! acceptance benchmark (N identical tenants ⇒ aggregate backend
//! launches ≤ 1.25× one cold tenant).
//!
//! Backend note: the leader engine is held in a `Mutex` across service
//! threads, which requires the engine to be `Send`. The in-tree native
//! backend satisfies this; substituting the published `xla` binding
//! (whose PJRT handles are thread-bound) would need a
//! load-per-build fallback here.
//!
//! [`StudyInputs`]: crate::driver::StudyInputs

mod service;

pub use service::{JobReport, ServeOptions, ServiceReport, StudyJob, StudyService, TenantReport};
