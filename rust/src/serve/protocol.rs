//! The serve wire protocol: versioned, length-delimited JSONL frames
//! over TCP.
//!
//! `docs/SERVING.md` is the normative spec (frame layout, message
//! grammar, error codes, version negotiation); this module is its
//! implementation, shared by the server ([`crate::serve::server`]) and
//! the in-tree client ([`crate::serve::client`]).
//!
//! # Frames
//!
//! Every message travels in one frame:
//!
//! ```text
//! rtfp1 <len>\n<body>\n
//! ```
//!
//! where `rtfp1` is the frame tag (protocol name + frame-format
//! version), `<len>` is the decimal byte length of `<body>`, and
//! `<body>` is exactly `len` bytes of UTF-8 JSON — one JSON object per
//! frame (JSONL with an explicit length, so readers never have to scan
//! for unescaped newlines). Frames larger than [`MAX_FRAME_BYTES`] are
//! rejected. An incompatible frame format bumps the tag (`rtfp2`), so
//! old readers fail fast at the header instead of misparsing bodies.
//!
//! # Messages
//!
//! Each body is an object with a `"type"` field. Clients send `hello`,
//! `submit`, `submit-tune`, `status`, `result`, `drain`; servers reply
//! `hello`, `accepted`, `status-report`, `job-report`, `bill`, `error`.
//! Peer *nodes* of a serve cluster additionally exchange the cache
//! fabric pair (protocol v3): `cache-get` → `cache-state` fetches the
//! state a peer owns (or hands the requester a cross-node claim), and
//! `cache-put` → `cache-ok` publishes a computed state to the key's
//! owner. The conversation starts with a `hello`/`hello` version
//! handshake ([`PROTOCOL_VERSION`]); a server that cannot speak the
//! client's version answers `error` with code
//! [`codes::VERSION_MISMATCH`] and closes.
//!
//! Protocol v6 adds the cluster *control plane*: `route` → `routed`
//! carries a front-door-forwarded job to the node owning most of its
//! predicted chain keys (a routed job is executed where it lands, never
//! re-routed), `peer-join`/`peer-leave` rebuild every node's
//! [`crate::cache::PeerRing`] without a restart, and `cache-get` grows
//! an optional `peek` flag — a claim-free probe used for replica reads,
//! tolerated as absent by v5-era receivers.
//!
//! Protocol v7 adds the telemetry surface: `stats` → `stats-report`
//! returns a point-in-time snapshot (the metrics registry, per-tier
//! cache stats, queue and span-ring state), the bill and
//! `status-report` carry per-tier cache stats, and `route` /
//! `cache-get` / `cache-put` grow an optional trace context
//! (`trace` + `span`, hex) so a routed job's spans — and the
//! owner-side serves its cache traffic causes — stitch into one
//! cross-node trace tree ([`crate::obs`]). All v7 fields are optional
//! on parse: v6-era frames read as "no trace, no tiers".
//!
//! # Encode/decode
//!
//! ```
//! use rtf_reuse::serve::protocol::{decode_frame, encode_frame, Message};
//!
//! let msg = Message::Accepted { job: 7 };
//! let bytes = encode_frame(&msg);
//! assert_eq!(bytes, b"rtfp1 27\n{\"job\":7,\"type\":\"accepted\"}\n");
//! let (back, consumed) = decode_frame(&bytes).unwrap();
//! assert_eq!(back, msg);
//! assert_eq!(consumed, bytes.len());
//! ```

use std::io::{BufRead, Write};

use crate::cache::{CacheStats, Key, TierStats};
use crate::data::Plane;
use crate::jsonx::{obj, Json};
use crate::obs::{HistSnapshot, MetricsSnapshot, ObsSnapshot};
use crate::tune::TuneSummary;
use crate::{Error, Result};

use super::service::{JobReport, ServiceReport};

/// Version negotiated by the `hello` handshake. Bump on any message-set
/// or semantics change; the frame tag ([`FRAME_TAG`]) only bumps when
/// the *frame layout* changes.
///
/// History: v1 — the original study message set; v2 — adds the
/// `submit-tune` job kind and the optional `tune` block on
/// `job-report`; v3 — adds the cluster cache fabric (`cache-get`,
/// `cache-state`, `cache-put`, `cache-ok`) and the `remote_hits` field
/// on every wire `cache` object; v4 — adds the `retries` field to
/// `job-report`, the per-tenant bill rows and the bill (retried
/// attempts billed distinctly), the `warm_swept`/`warm_metrics` fields
/// to the bill's warm-start block, and the `over-window` error code
/// (per-connection submit backpressure); v5 — adds the adaptive-run
/// `pruned` and speculative-execution `speculative` fields to
/// `job-report` and the per-tenant bill rows, and the bill-level
/// `pruned` total and `speculative_launches` global (speculation is
/// billed like input building: globally, to no tenant); v6 — adds the
/// cluster control plane: front-door job forwarding (`route` →
/// `routed`), live membership (`peer-join` / `peer-leave`, each acked
/// by an echo carrying the receiver's new ring size), and the optional
/// `peek` flag on `cache-get` (a claim-free probe for replica reads);
/// v7 — adds the telemetry surface: the `stats` → `stats-report`
/// exchange (point-in-time metrics + per-tier cache stats), the
/// `tiers` block on the bill and on `status-report`, and the optional
/// `trace`/`span` context on `route`, `cache-get` and `cache-put`
/// (cross-node span stitching; absent fields parse as no-trace, so
/// v6-era frames stay readable).
pub const PROTOCOL_VERSION: u32 = 7;

/// Frame tag: protocol name plus frame-format version.
pub const FRAME_TAG: &str = "rtfp1";

/// Upper bound on one frame's JSON body. A `job-report` for a large
/// study carries its full `y` vector; 16 MiB bounds that at ~2M
/// evaluations while keeping a malicious header harmless.
pub const MAX_FRAME_BYTES: usize = 16 * 1024 * 1024;

/// Error codes carried by [`Message::Error`] (spelled out in
/// `docs/SERVING.md`).
pub mod codes {
    /// The frame header or body could not be parsed.
    pub const BAD_FRAME: &str = "bad-frame";
    /// A well-formed frame carried an unknown or out-of-place message.
    pub const BAD_MESSAGE: &str = "bad-message";
    /// The `hello` versions do not match.
    pub const VERSION_MISMATCH: &str = "version-mismatch";
    /// A `submit`'s study options did not parse.
    pub const BAD_STUDY: &str = "bad-study";
    /// The service is draining and admits no new work.
    pub const DRAINING: &str = "draining";
    /// A `result` asked for a job id the service never issued.
    pub const UNKNOWN_JOB: &str = "unknown-job";
    /// The connection has too many unanswered submits in flight
    /// (protocol v4); collect some `result`s, then submit again. The
    /// connection stays usable.
    pub const OVER_WINDOW: &str = "over-window";
    /// Unexpected server-side failure.
    pub const INTERNAL: &str = "internal";
}

/// One wire message (see the module docs for who sends what).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Version handshake, first frame in each direction. `role` is
    /// `"client"` or `"server"` (informational).
    Hello { version: u32, role: String },
    /// Submit one study under a tenant's account. `study` is the
    /// `key=value` option list a job line would carry (parsed
    /// server-side by `StudyConfig::from_args`; execution-environment
    /// fields are pinned by the service).
    Submit { tenant: String, study: Vec<String> },
    /// Submit one *tuning* job (protocol v2): `tune` is the `key=value`
    /// option list of a `tune` CLI invocation (parsed server-side by
    /// `TuneConfig::from_args`). Replied to with [`Message::Accepted`];
    /// the finished job's `job-report` carries a `tune` block.
    SubmitTune { tenant: String, tune: Vec<String> },
    /// The job was queued under this service-assigned id.
    Accepted { job: u64 },
    /// Ask for service-level queue counts.
    Status,
    /// Reply to [`Message::Status`]; `tiers` (protocol v7) carries the
    /// node's per-tier cache counters, empty from v6-era servers.
    StatusReport { queued: u64, running: u64, done: u64, tiers: Vec<WireTierStats> },
    /// Ask for the node's point-in-time telemetry snapshot
    /// (protocol v7). Answered by [`Message::StatsReport`]; valid even
    /// with telemetry off (the snapshot is then empty but the per-tier
    /// cache stats and queue counts are still live).
    Stats,
    /// Reply to [`Message::Stats`] (protocol v7).
    StatsReport(Box<WireStats>),
    /// Block until the job finishes, then receive its report.
    Result { job: u64 },
    /// Reply to [`Message::Result`]: the finished job's outcome.
    JobDone(Box<WireJobReport>),
    /// Drain the service: no new admissions, queued work completes, the
    /// final bill comes back and the server exits.
    Drain,
    /// Reply to [`Message::Drain`]: the full per-tenant bill.
    Bill(Box<WireBill>),
    /// Cluster control plane (protocol v6): a front-door node forwards
    /// a submitted job to the peer owning the largest share of its
    /// predicted chain keys. The receiver executes the job *here* —
    /// a routed job is never re-routed — and replies
    /// [`Message::Routed`]. `trace` (protocol v7) carries the front
    /// door's trace context so the executing node's spans stitch under
    /// the front door's `route` span; absent from v6-era senders.
    Route { tenant: String, study: Vec<String>, trace: Option<WireTrace> },
    /// Reply to [`Message::Route`]: the executing node's local job id
    /// (`result` on the same connection collects it) and its cluster
    /// address (informational).
    Routed { job: u64, node: String },
    /// Cluster control plane (protocol v6): add `addr` to the
    /// receiver's peer ring without a restart. `peers = 0` marks an
    /// admin-originated request — the receiver applies it and relays it
    /// to every other ring member (with `peers` set to its new ring
    /// size, so relays are applied but never re-relayed). The ack is an
    /// echo with `peers` = the receiver's ring size after the change.
    PeerJoin { addr: String, peers: u64 },
    /// Cluster control plane (protocol v6): remove `addr` from the
    /// receiver's peer ring. Same `peers` relay/ack convention as
    /// [`Message::PeerJoin`]; owned-key handoff runs as a background
    /// drain on each node, never blocking job traffic.
    PeerLeave { addr: String, peers: u64 },
    /// Cluster fabric (protocol v3): a peer node asks the key's owner
    /// for the cached state. The owner replies [`Message::CacheState`] —
    /// blocking while another node holds the cross-node claim on the
    /// key, so two nodes never duplicate a launch. With `peek` (v6) the
    /// request is a claim-free probe: the receiver answers from its
    /// local tiers or replies a plain miss (`found=false`,
    /// `claimed=false`) — replica reads use this so a failover never
    /// registers a claim on a node that does not own the key. `trace`
    /// (protocol v7) parents the owner's `serve-get` span under the
    /// requester's lookup span; absent from v6-era senders.
    CacheGet { key: Key, peek: bool, trace: Option<WireTrace> },
    /// Reply to [`Message::CacheGet`]: the state if the owner holds it
    /// (`found`), else a cross-node claim grant (`claimed`) telling the
    /// requester to compute locally and publish with
    /// [`Message::CachePut`].
    CacheState(Box<WireCacheState>),
    /// Cluster fabric (protocol v3): publish a computed state to the
    /// key's owning node (settles the requester's cross-node claim).
    CachePut(Box<WireCachePut>),
    /// Reply to [`Message::CachePut`]; `stored` is true when the owner
    /// newly stored the state in any local tier.
    CacheOk { key: Key, stored: bool },
    /// Any failure; `code` is one of [`codes`].
    Error { code: String, message: String },
}

/// The trace context a frame can carry (protocol v7): the 128-bit
/// trace id and the sender-side span id the receiver's spans should
/// parent under. Encoded as two lowercase-hex string fields (`trace`,
/// `span`); both absent on untraced traffic and from v6-era senders.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireTrace {
    pub trace: u128,
    pub span: u64,
}

/// One cache tier's counters as reported over the wire (protocol v7):
/// the tier's canonical name plus its [`TierStats`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WireTierStats {
    pub tier: String,
    pub stats: TierStats,
}

/// Reply to a `stats` request (protocol v7): the node's telemetry
/// snapshot (counters, histograms, span-ring state — empty with
/// telemetry off), its per-tier cache counters, and its queue counts.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireStats {
    /// True when the node runs with telemetry on.
    pub enabled: bool,
    /// The metrics registry + span-ring snapshot ([`crate::obs`]).
    pub snapshot: ObsSnapshot,
    /// Per-tier cache counters (live even with telemetry off).
    pub tiers: Vec<WireTierStats>,
    pub queued: u64,
    pub running: u64,
    pub done: u64,
}

/// Reply to a `cache-get` (see [`Message::CacheState`]). Exactly one of
/// `found`/`claimed` is true; with `found`, `h`/`w`/`planes` carry the
/// payload ([`planes_to_hex`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireCacheState {
    pub key: Key,
    pub found: bool,
    pub claimed: bool,
    pub h: u64,
    pub w: u64,
    /// Hex of the three planes' little-endian f32 data, concatenated
    /// (empty unless `found`).
    pub planes: String,
}

impl WireCacheState {
    /// A `found` reply carrying the state.
    pub fn found(key: Key, state: &[Plane; 3]) -> Self {
        let (h, w, planes) = planes_to_hex(state);
        Self { key, found: true, claimed: false, h, w, planes }
    }

    /// A `claimed` reply: the requester owns the cross-node claim.
    pub fn claimed(key: Key) -> Self {
        Self { key, found: false, claimed: true, ..Self::default() }
    }
}

/// Body of a `cache-put` (see [`Message::CachePut`]): one 3-plane state
/// published to the key's owning node.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireCachePut {
    pub key: Key,
    pub h: u64,
    pub w: u64,
    /// Hex of the three planes' little-endian f32 data, concatenated.
    pub planes: String,
    /// Trace context (protocol v7): parents the owner's `serve-put`
    /// span under the publisher's span; absent from v6-era senders.
    pub trace: Option<WireTrace>,
}

impl WireCachePut {
    pub fn new(key: Key, state: &[Plane; 3]) -> Self {
        let (h, w, planes) = planes_to_hex(state);
        Self { key, h, w, planes, trace: None }
    }
}

/// Encode a 3-plane state as `(height, width, hex)` — two lowercase hex
/// digits per byte of each plane's little-endian f32 data, the three
/// planes concatenated in order. A 128×128 tile is ~384 KiB of hex,
/// comfortably inside [`MAX_FRAME_BYTES`].
pub fn planes_to_hex(state: &[Plane; 3]) -> (u64, u64, String) {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let (h, w) = (state[0].height(), state[0].width());
    let mut out = String::with_capacity(3 * h * w * 8);
    for plane in state.iter() {
        for v in plane.data() {
            for b in v.to_le_bytes() {
                out.push(HEX[(b >> 4) as usize] as char);
                out.push(HEX[(b & 0xf) as usize] as char);
            }
        }
    }
    (h as u64, w as u64, out)
}

/// Decode [`planes_to_hex`] output back into a 3-plane state,
/// validating the dimensions against the hex length.
pub fn planes_from_hex(h: u64, w: u64, hex: &str) -> Result<[Plane; 3]> {
    let (h, w) = (h as usize, w as usize);
    let plane_chars = h * w * 8;
    if hex.len() != 3 * plane_chars {
        return Err(Error::Protocol(format!(
            "cache state payload: {} hex chars for 3 planes of {h}x{w}",
            hex.len()
        )));
    }
    let nibble = |c: u8| -> Result<u8> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(Error::Protocol(format!("cache state payload: bad hex byte {c:#x}"))),
        }
    };
    let bytes = hex.as_bytes();
    let mut planes = Vec::with_capacity(3);
    for p in 0..3 {
        let mut data = Vec::with_capacity(h * w);
        let base = p * plane_chars;
        for px in 0..h * w {
            let mut le = [0u8; 4];
            for (i, b) in le.iter_mut().enumerate() {
                let at = base + px * 8 + i * 2;
                *b = (nibble(bytes[at])? << 4) | nibble(bytes[at + 1])?;
            }
            data.push(f32::from_le_bytes(le));
        }
        planes.push(Plane::new(data, h, w)?);
    }
    let mut it = planes.into_iter();
    Ok([it.next().unwrap(), it.next().unwrap(), it.next().unwrap()])
}

/// A finished job as reported over the wire (mirror of the in-process
/// `JobReport`, durations flattened to seconds).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireJobReport {
    pub job: u64,
    pub tenant: String,
    /// `None` on success, the failure message otherwise.
    pub error: Option<String>,
    pub n_evals: u64,
    /// Backend launches this job paid for.
    pub launches: u64,
    /// Task executions served from the shared cache.
    pub cached_tasks: u64,
    /// Retried attempts this job consumed (protocol v4).
    pub retries: u64,
    /// Evaluations the adaptive pruner cancelled before launch
    /// (protocol v5; 0 for non-adaptive jobs).
    pub pruned: u64,
    /// Speculative launches completed on this job's behalf by report
    /// time (protocol v5; a lower bound — the authoritative global is
    /// the bill's `speculative_launches`).
    pub speculative: u64,
    pub queue_wait_secs: f64,
    pub exec_wall_secs: f64,
    /// Per-evaluation scalar outputs (the SA estimator inputs). For a
    /// tuning job: the per-generation best objective scores.
    pub y: Vec<f64>,
    /// Tuning jobs only (protocol v2): what the optimizer found.
    pub tune: Option<TuneSummary>,
}

impl WireJobReport {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

impl From<&JobReport> for WireJobReport {
    fn from(j: &JobReport) -> Self {
        WireJobReport {
            job: j.job,
            tenant: j.tenant.clone(),
            error: j.error.clone(),
            n_evals: j.n_evals as u64,
            launches: j.launches,
            cached_tasks: j.cached_tasks,
            retries: j.retries,
            pruned: j.pruned,
            speculative: j.speculative,
            queue_wait_secs: j.queue_wait.as_secs_f64(),
            exec_wall_secs: j.exec_wall.as_secs_f64(),
            y: j.y.clone(),
            tune: j.tune.clone(),
        }
    }
}

/// One tenant's row of the drain bill. `cache` carries the tenant's
/// scoped counters (hits/misses/inserts/evictions/resident bytes);
/// `quota_bytes` is its memory-tier allowance (0 = unlimited).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireTenantBill {
    pub tenant: String,
    pub jobs: u64,
    pub failed: u64,
    pub launches: u64,
    pub cached_tasks: u64,
    /// Retried attempts across this tenant's jobs (protocol v4).
    pub retries: u64,
    /// Pruned evaluations across this tenant's adaptive jobs
    /// (protocol v5).
    pub pruned: u64,
    /// Speculative launches performed on this tenant's jobs' behalf
    /// (protocol v5; informational — billed globally, not to the
    /// tenant).
    pub speculative: u64,
    pub bytes_served: u64,
    pub quota_bytes: u64,
    pub queue_wait_secs: f64,
    pub exec_wall_secs: f64,
    pub cache: CacheStats,
}

/// The drained service's full bill: per-tenant rows plus the shared
/// cache's global counters and the boot warm-start summary.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WireBill {
    pub jobs: u64,
    pub failed: u64,
    /// Retried attempts across every job (protocol v4).
    pub retries: u64,
    /// Pruned evaluations across every adaptive job (protocol v5).
    pub pruned: u64,
    /// Launches spent building shared study inputs (not billed to any
    /// tenant).
    pub input_launches: u64,
    /// Launches spent on speculative pre-execution over the service
    /// lifetime (protocol v5) — the authoritative global count, billed
    /// like input building: to no tenant.
    pub speculative_launches: u64,
    /// Input launches plus speculative launches plus every job's
    /// launches — THE service-wide cost.
    pub total_launches: u64,
    pub wall_secs: f64,
    pub tenants: Vec<WireTenantBill>,
    /// The shared cache's global counters at drain time.
    pub cache: CacheStats,
    /// What the boot-time warm start scanned/admitted (zeros when off).
    pub warm_scanned: u64,
    pub warm_admitted: u64,
    pub warm_admitted_bytes: u64,
    /// Crash debris (orphaned temp files, quarantined entries) the boot
    /// warm start swept from the disk tier (protocol v4).
    pub warm_swept: u64,
    /// Persisted comparison-metric rows the warm start reloaded
    /// (protocol v4) — comparisons a warm restart will not relaunch.
    pub warm_metrics: u64,
    /// Per-tier cache counters at drain time (protocol v7), including
    /// breaker transitions and replica-served reads; empty from v6-era
    /// servers.
    pub tiers: Vec<WireTierStats>,
}

impl From<&ServiceReport> for WireBill {
    fn from(r: &ServiceReport) -> Self {
        WireBill {
            jobs: r.jobs.len() as u64,
            failed: r.jobs.iter().filter(|j| !j.ok()).count() as u64,
            retries: r.jobs.iter().map(|j| j.retries).sum(),
            pruned: r.jobs.iter().map(|j| j.pruned).sum(),
            input_launches: r.input_launches,
            speculative_launches: r.speculative_launches,
            total_launches: r.total_launches(),
            wall_secs: r.wall.as_secs_f64(),
            tenants: r
                .tenants
                .iter()
                .map(|t| WireTenantBill {
                    tenant: t.tenant.clone(),
                    jobs: t.jobs,
                    failed: t.failed,
                    launches: t.launches,
                    cached_tasks: t.cached_tasks,
                    retries: t.retries,
                    pruned: t.pruned,
                    speculative: t.speculative,
                    bytes_served: t.bytes_served,
                    quota_bytes: t.quota_bytes,
                    queue_wait_secs: t.queue_wait.as_secs_f64(),
                    exec_wall_secs: t.exec_wall.as_secs_f64(),
                    cache: t.cache,
                })
                .collect(),
            cache: r.cache,
            warm_scanned: r.warm.scanned,
            warm_admitted: r.warm.admitted,
            warm_admitted_bytes: r.warm.admitted_bytes,
            warm_swept: r.warm.swept,
            warm_metrics: r.warm.metrics_loaded,
            tiers: r
                .tiers
                .iter()
                .map(|(tier, stats)| WireTierStats { tier: tier.clone(), stats: *stats })
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

/// Serialize one message into its complete frame
/// (`rtfp1 <len>\n<body>\n`).
pub fn encode_frame(msg: &Message) -> Vec<u8> {
    let body = msg.to_json().to_string_compact();
    let mut out = Vec::with_capacity(FRAME_TAG.len() + body.len() + 16);
    out.extend_from_slice(FRAME_TAG.as_bytes());
    out.push(b' ');
    out.extend_from_slice(body.len().to_string().as_bytes());
    out.push(b'\n');
    out.extend_from_slice(body.as_bytes());
    out.push(b'\n');
    out
}

/// Decode one frame from the front of `bytes`, returning the message
/// and the number of bytes consumed. Errors on a bad tag, an oversized
/// or unparsable length, a truncated body, or an invalid message.
///
/// ```
/// use rtf_reuse::serve::protocol::{decode_frame, encode_frame, Message};
///
/// let mut stream = encode_frame(&Message::Drain);
/// stream.extend_from_slice(&encode_frame(&Message::Status));
/// let (first, used) = decode_frame(&stream).unwrap();
/// assert_eq!(first, Message::Drain);
/// let (second, _) = decode_frame(&stream[used..]).unwrap();
/// assert_eq!(second, Message::Status);
/// assert!(decode_frame(b"rtfp9 2\n{}\n").is_err(), "wrong frame version");
/// ```
pub fn decode_frame(bytes: &[u8]) -> Result<(Message, usize)> {
    let nl = bytes
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| Error::Protocol("frame header not terminated".into()))?;
    let header = std::str::from_utf8(&bytes[..nl])
        .map_err(|_| Error::Protocol("frame header is not UTF-8".into()))?;
    // same CRLF tolerance as the stream reader (`read_frame`)
    let len = parse_header(header.trim_end_matches('\r'))?;
    let body_start = nl + 1;
    let end = body_start + len + 1;
    if bytes.len() < end {
        return Err(Error::Protocol(format!(
            "truncated frame: need {end} bytes, have {}",
            bytes.len()
        )));
    }
    if bytes[end - 1] != b'\n' {
        return Err(Error::Protocol("frame body not newline-terminated".into()));
    }
    let msg = parse_body(&bytes[body_start..end - 1])?;
    Ok((msg, end))
}

/// Write one message as a frame. Does not flush — callers flush once
/// per logical round trip.
pub fn write_frame<W: Write>(w: &mut W, msg: &Message) -> Result<()> {
    w.write_all(&encode_frame(msg)).map_err(Error::Io)
}

/// Read one frame; `Ok(None)` on clean EOF at a frame boundary. I/O
/// errors surface as [`Error::Io`], malformed frames as
/// [`Error::Protocol`].
pub fn read_frame<R: BufRead>(r: &mut R) -> Result<Option<Message>> {
    let mut header = String::new();
    let n = r.read_line(&mut header).map_err(Error::Io)?;
    if n == 0 {
        return Ok(None);
    }
    let len = parse_header(header.trim_end_matches(['\r', '\n']))?;
    let mut body = vec![0u8; len + 1];
    r.read_exact(&mut body).map_err(Error::Io)?;
    if body[len] != b'\n' {
        return Err(Error::Protocol("frame body not newline-terminated".into()));
    }
    parse_body(&body[..len]).map(Some)
}

fn parse_header(header: &str) -> Result<usize> {
    let rest = header.strip_prefix(FRAME_TAG).ok_or_else(|| {
        Error::Protocol(format!("bad frame tag (expected `{FRAME_TAG}`): `{header}`"))
    })?;
    let rest = rest
        .strip_prefix(' ')
        .ok_or_else(|| Error::Protocol(format!("bad frame header: `{header}`")))?;
    let len: usize = rest
        .parse()
        .map_err(|_| Error::Protocol(format!("bad frame length: `{rest}`")))?;
    if len > MAX_FRAME_BYTES {
        return Err(Error::Protocol(format!(
            "frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte limit"
        )));
    }
    Ok(len)
}

fn parse_body(body: &[u8]) -> Result<Message> {
    let text = std::str::from_utf8(body)
        .map_err(|_| Error::Protocol("frame body is not UTF-8".into()))?;
    let json = Json::parse(text).map_err(|e| Error::Protocol(format!("frame body: {e}")))?;
    Message::from_json(&json)
}

// ---------------------------------------------------------------------
// message <-> json
// ---------------------------------------------------------------------

fn ju(v: u64) -> Json {
    Json::Num(v as f64)
}

fn jf(v: f64) -> Json {
    Json::Num(v)
}

fn js(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn jb(v: bool) -> Json {
    Json::Bool(v)
}

fn jkey(key: Key) -> Json {
    Json::Str(format!("{:032x}", key.as_u128()))
}

fn field<'a>(o: &'a Json, key: &str) -> Result<&'a Json> {
    o.get(key).ok_or_else(|| Error::Protocol(format!("missing field `{key}`")))
}

fn str_field(o: &Json, key: &str) -> Result<String> {
    match field(o, key)?.as_str() {
        Some(s) => Ok(s.to_string()),
        None => Err(Error::Protocol(format!("field `{key}` must be a string"))),
    }
}

fn u64_field(o: &Json, key: &str) -> Result<u64> {
    match field(o, key)?.as_f64() {
        Some(n) if n >= 0.0 => Ok(n as u64),
        _ => Err(Error::Protocol(format!("field `{key}` must be a non-negative number"))),
    }
}

fn f64_field(o: &Json, key: &str) -> Result<f64> {
    field(o, key)?
        .as_f64()
        .ok_or_else(|| Error::Protocol(format!("field `{key}` must be a number")))
}

fn arr_field<'a>(o: &'a Json, key: &str) -> Result<&'a [Json]> {
    field(o, key)?
        .as_arr()
        .ok_or_else(|| Error::Protocol(format!("field `{key}` must be an array")))
}

fn str_arr(o: &Json, key: &str) -> Result<Vec<String>> {
    let mut out = Vec::new();
    for v in arr_field(o, key)? {
        match v.as_str() {
            Some(s) => out.push(s.to_string()),
            None => return Err(Error::Protocol(format!("field `{key}` must hold strings"))),
        }
    }
    Ok(out)
}

fn f64_arr(o: &Json, key: &str) -> Result<Vec<f64>> {
    let mut out = Vec::new();
    for v in arr_field(o, key)? {
        match v.as_f64() {
            Some(n) => out.push(n),
            None => return Err(Error::Protocol(format!("field `{key}` must hold numbers"))),
        }
    }
    Ok(out)
}

fn bool_field(o: &Json, key: &str) -> Result<bool> {
    field(o, key)?
        .as_bool()
        .ok_or_else(|| Error::Protocol(format!("field `{key}` must be a boolean")))
}

/// An optional boolean field, absent (or null) meaning `false` — how v6
/// extends `cache-get` with `peek` without breaking v5-era senders.
fn opt_bool_field(o: &Json, key: &str) -> Result<bool> {
    match o.get(key) {
        None | Some(Json::Null) => Ok(false),
        Some(v) => v
            .as_bool()
            .ok_or_else(|| Error::Protocol(format!("field `{key}` must be a boolean"))),
    }
}

fn key_field(o: &Json, key: &str) -> Result<Key> {
    let s = str_field(o, key)?;
    let raw = u128::from_str_radix(&s, 16)
        .map_err(|_| Error::Protocol(format!("field `{key}` must be a 128-bit hex key")))?;
    Ok(Key::from_parts((raw >> 64) as u64, raw as u64))
}

fn opt_str_field(o: &Json, key: &str) -> Result<Option<String>> {
    match o.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => match v.as_str() {
            Some(s) => Ok(Some(s.to_string())),
            None => Err(Error::Protocol(format!("field `{key}` must be a string"))),
        },
    }
}

/// The optional trace context (protocol v7): two hex string fields,
/// `trace` (128-bit) and `span` (64-bit). Absent (or null) `trace`
/// means untraced — how v6-era frames keep parsing.
fn opt_trace_field(o: &Json) -> Result<Option<WireTrace>> {
    let Some(t) = opt_str_field(o, "trace")? else { return Ok(None) };
    let trace = u128::from_str_radix(&t, 16)
        .map_err(|_| Error::Protocol("field `trace` must be a 128-bit hex trace id".into()))?;
    let span = match opt_str_field(o, "span")? {
        Some(s) => u64::from_str_radix(&s, 16)
            .map_err(|_| Error::Protocol("field `span` must be a 64-bit hex span id".into()))?,
        None => 0,
    };
    Ok(Some(WireTrace { trace, span }))
}

fn push_trace(fields: &mut Vec<(&str, Json)>, trace: &Option<WireTrace>) {
    if let Some(t) = trace {
        fields.push(("trace", Json::Str(format!("{:032x}", t.trace))));
        fields.push(("span", Json::Str(format!("{:016x}", t.span))));
    }
}

fn tier_stats_json(t: &WireTierStats) -> Json {
    obj(vec![
        ("tier", js(&t.tier)),
        ("hits", ju(t.stats.hits)),
        ("stores", ju(t.stats.stores)),
        ("resident_bytes", ju(t.stats.resident_bytes)),
        ("breaker_opens", ju(t.stats.breaker_opens)),
        ("breaker_closes", ju(t.stats.breaker_closes)),
        ("replica_hits", ju(t.stats.replica_hits)),
    ])
}

fn tier_stats_from_json(o: &Json) -> Result<WireTierStats> {
    Ok(WireTierStats {
        tier: str_field(o, "tier")?,
        stats: TierStats {
            hits: u64_field(o, "hits")?,
            stores: u64_field(o, "stores")?,
            resident_bytes: u64_field(o, "resident_bytes")?,
            breaker_opens: u64_field(o, "breaker_opens")?,
            breaker_closes: u64_field(o, "breaker_closes")?,
            replica_hits: u64_field(o, "replica_hits")?,
        },
    })
}

fn tiers_json(tiers: &[WireTierStats]) -> Json {
    Json::Arr(tiers.iter().map(tier_stats_json).collect())
}

/// The optional `tiers` array (protocol v7); absent (or null) means
/// empty — how v6-era `bill` and `status-report` frames keep parsing.
fn opt_tiers_field(o: &Json) -> Result<Vec<WireTierStats>> {
    match o.get("tiers") {
        None | Some(Json::Null) => Ok(Vec::new()),
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| Error::Protocol("field `tiers` must be an array".into()))?;
            arr.iter().map(tier_stats_from_json).collect()
        }
    }
}

fn u64_arr(o: &Json, key: &str) -> Result<Vec<u64>> {
    let mut out = Vec::new();
    for v in arr_field(o, key)? {
        match v.as_f64() {
            Some(n) if n >= 0.0 => out.push(n as u64),
            _ => {
                return Err(Error::Protocol(format!(
                    "field `{key}` must hold non-negative numbers"
                )))
            }
        }
    }
    Ok(out)
}

fn hist_json(h: &HistSnapshot) -> Json {
    obj(vec![
        ("name", js(&h.name)),
        ("counts", Json::Arr(h.counts.iter().map(|&c| ju(c)).collect())),
        ("sum_us", ju(h.sum_us)),
        ("count", ju(h.count)),
    ])
}

fn hist_from_json(o: &Json) -> Result<HistSnapshot> {
    Ok(HistSnapshot {
        name: str_field(o, "name")?,
        counts: u64_arr(o, "counts")?,
        sum_us: u64_field(o, "sum_us")?,
        count: u64_field(o, "count")?,
    })
}

fn metrics_json(m: &MetricsSnapshot) -> Json {
    obj(vec![
        (
            "counters",
            Json::Arr(
                m.counters
                    .iter()
                    .map(|(name, value)| obj(vec![("name", js(name)), ("value", ju(*value))]))
                    .collect(),
            ),
        ),
        ("hists", Json::Arr(m.hists.iter().map(hist_json).collect())),
    ])
}

fn metrics_from_json(o: &Json) -> Result<MetricsSnapshot> {
    let mut counters = Vec::new();
    for c in arr_field(o, "counters")? {
        counters.push((str_field(c, "name")?, u64_field(c, "value")?));
    }
    let mut hists = Vec::new();
    for h in arr_field(o, "hists")? {
        hists.push(hist_from_json(h)?);
    }
    Ok(MetricsSnapshot { counters, hists })
}

fn cache_stats_json(s: &CacheStats) -> Json {
    obj(vec![
        ("hits", ju(s.hits)),
        ("disk_hits", ju(s.disk_hits)),
        ("remote_hits", ju(s.remote_hits)),
        ("misses", ju(s.misses)),
        ("inserts", ju(s.inserts)),
        ("evictions", ju(s.evictions)),
        ("spilled", ju(s.spilled)),
        ("metric_hits", ju(s.metric_hits)),
        ("metric_misses", ju(s.metric_misses)),
        ("resident_bytes", ju(s.resident_bytes)),
        ("peak_bytes", ju(s.peak_bytes)),
    ])
}

fn cache_stats_from_json(o: &Json) -> Result<CacheStats> {
    Ok(CacheStats {
        hits: u64_field(o, "hits")?,
        disk_hits: u64_field(o, "disk_hits")?,
        remote_hits: u64_field(o, "remote_hits")?,
        misses: u64_field(o, "misses")?,
        inserts: u64_field(o, "inserts")?,
        evictions: u64_field(o, "evictions")?,
        spilled: u64_field(o, "spilled")?,
        metric_hits: u64_field(o, "metric_hits")?,
        metric_misses: u64_field(o, "metric_misses")?,
        resident_bytes: u64_field(o, "resident_bytes")?,
        peak_bytes: u64_field(o, "peak_bytes")?,
    })
}

fn tune_summary_json(t: &TuneSummary) -> Json {
    obj(vec![
        ("method", js(&t.method)),
        ("best_score", jf(t.best_score)),
        ("initial_best_score", jf(t.initial_best_score)),
        ("best_params", Json::Arr(t.best_params.iter().map(|&v| Json::Num(v)).collect())),
        ("evaluated", ju(t.evaluated)),
        ("memo_hits", ju(t.memo_hits)),
        ("generations", ju(t.generations)),
    ])
}

fn tune_summary_from_json(o: &Json) -> Result<TuneSummary> {
    Ok(TuneSummary {
        method: str_field(o, "method")?,
        best_score: f64_field(o, "best_score")?,
        initial_best_score: f64_field(o, "initial_best_score")?,
        best_params: f64_arr(o, "best_params")?,
        evaluated: u64_field(o, "evaluated")?,
        memo_hits: u64_field(o, "memo_hits")?,
        generations: u64_field(o, "generations")?,
    })
}

impl WireJobReport {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("type", js("job-report")),
            ("job", ju(self.job)),
            ("tenant", js(&self.tenant)),
            ("n_evals", ju(self.n_evals)),
            ("launches", ju(self.launches)),
            ("cached_tasks", ju(self.cached_tasks)),
            ("retries", ju(self.retries)),
            ("pruned", ju(self.pruned)),
            ("speculative", ju(self.speculative)),
            ("queue_wait_secs", jf(self.queue_wait_secs)),
            ("exec_wall_secs", jf(self.exec_wall_secs)),
            ("y", Json::Arr(self.y.iter().map(|&v| Json::Num(v)).collect())),
        ];
        if let Some(e) = &self.error {
            fields.push(("error", js(e)));
        }
        if let Some(t) = &self.tune {
            fields.push(("tune", tune_summary_json(t)));
        }
        obj(fields)
    }

    fn from_json(o: &Json) -> Result<WireJobReport> {
        let tune = match o.get("tune") {
            None | Some(Json::Null) => None,
            Some(t) => Some(tune_summary_from_json(t)?),
        };
        Ok(WireJobReport {
            job: u64_field(o, "job")?,
            tenant: str_field(o, "tenant")?,
            error: opt_str_field(o, "error")?,
            n_evals: u64_field(o, "n_evals")?,
            launches: u64_field(o, "launches")?,
            cached_tasks: u64_field(o, "cached_tasks")?,
            retries: u64_field(o, "retries")?,
            pruned: u64_field(o, "pruned")?,
            speculative: u64_field(o, "speculative")?,
            queue_wait_secs: f64_field(o, "queue_wait_secs")?,
            exec_wall_secs: f64_field(o, "exec_wall_secs")?,
            y: f64_arr(o, "y")?,
            tune,
        })
    }
}

impl WireTenantBill {
    fn to_json(&self) -> Json {
        obj(vec![
            ("tenant", js(&self.tenant)),
            ("jobs", ju(self.jobs)),
            ("failed", ju(self.failed)),
            ("launches", ju(self.launches)),
            ("cached_tasks", ju(self.cached_tasks)),
            ("retries", ju(self.retries)),
            ("pruned", ju(self.pruned)),
            ("speculative", ju(self.speculative)),
            ("bytes_served", ju(self.bytes_served)),
            ("quota_bytes", ju(self.quota_bytes)),
            ("queue_wait_secs", jf(self.queue_wait_secs)),
            ("exec_wall_secs", jf(self.exec_wall_secs)),
            ("cache", cache_stats_json(&self.cache)),
        ])
    }

    fn from_json(o: &Json) -> Result<WireTenantBill> {
        Ok(WireTenantBill {
            tenant: str_field(o, "tenant")?,
            jobs: u64_field(o, "jobs")?,
            failed: u64_field(o, "failed")?,
            launches: u64_field(o, "launches")?,
            cached_tasks: u64_field(o, "cached_tasks")?,
            retries: u64_field(o, "retries")?,
            pruned: u64_field(o, "pruned")?,
            speculative: u64_field(o, "speculative")?,
            bytes_served: u64_field(o, "bytes_served")?,
            quota_bytes: u64_field(o, "quota_bytes")?,
            queue_wait_secs: f64_field(o, "queue_wait_secs")?,
            exec_wall_secs: f64_field(o, "exec_wall_secs")?,
            cache: cache_stats_from_json(field(o, "cache")?)?,
        })
    }
}

impl WireBill {
    fn to_json(&self) -> Json {
        obj(vec![
            ("type", js("bill")),
            ("jobs", ju(self.jobs)),
            ("failed", ju(self.failed)),
            ("retries", ju(self.retries)),
            ("pruned", ju(self.pruned)),
            ("input_launches", ju(self.input_launches)),
            ("speculative_launches", ju(self.speculative_launches)),
            ("total_launches", ju(self.total_launches)),
            ("wall_secs", jf(self.wall_secs)),
            ("tenants", Json::Arr(self.tenants.iter().map(WireTenantBill::to_json).collect())),
            ("cache", cache_stats_json(&self.cache)),
            ("warm_scanned", ju(self.warm_scanned)),
            ("warm_admitted", ju(self.warm_admitted)),
            ("warm_admitted_bytes", ju(self.warm_admitted_bytes)),
            ("warm_swept", ju(self.warm_swept)),
            ("warm_metrics", ju(self.warm_metrics)),
            ("tiers", tiers_json(&self.tiers)),
        ])
    }

    fn from_json(o: &Json) -> Result<WireBill> {
        let mut tenants = Vec::new();
        for t in arr_field(o, "tenants")? {
            tenants.push(WireTenantBill::from_json(t)?);
        }
        Ok(WireBill {
            jobs: u64_field(o, "jobs")?,
            failed: u64_field(o, "failed")?,
            retries: u64_field(o, "retries")?,
            pruned: u64_field(o, "pruned")?,
            input_launches: u64_field(o, "input_launches")?,
            speculative_launches: u64_field(o, "speculative_launches")?,
            total_launches: u64_field(o, "total_launches")?,
            wall_secs: f64_field(o, "wall_secs")?,
            tenants,
            cache: cache_stats_from_json(field(o, "cache")?)?,
            warm_scanned: u64_field(o, "warm_scanned")?,
            warm_admitted: u64_field(o, "warm_admitted")?,
            warm_admitted_bytes: u64_field(o, "warm_admitted_bytes")?,
            warm_swept: u64_field(o, "warm_swept")?,
            warm_metrics: u64_field(o, "warm_metrics")?,
            tiers: opt_tiers_field(o)?,
        })
    }
}

impl WireStats {
    fn to_json(&self) -> Json {
        obj(vec![
            ("type", js("stats-report")),
            ("enabled", jb(self.enabled)),
            ("node", js(&self.snapshot.node)),
            ("global", metrics_json(&self.snapshot.global)),
            (
                "tenants",
                Json::Arr(
                    self.snapshot
                        .tenants
                        .iter()
                        .map(|(tenant, m)| {
                            obj(vec![("tenant", js(tenant)), ("metrics", metrics_json(m))])
                        })
                        .collect(),
                ),
            ),
            ("ring_len", ju(self.snapshot.ring_len)),
            ("ring_cap", ju(self.snapshot.ring_cap)),
            ("ring_dropped", ju(self.snapshot.ring_dropped)),
            ("tiers", tiers_json(&self.tiers)),
            ("queued", ju(self.queued)),
            ("running", ju(self.running)),
            ("done", ju(self.done)),
        ])
    }

    fn from_json(o: &Json) -> Result<WireStats> {
        let mut tenants = Vec::new();
        for t in arr_field(o, "tenants")? {
            tenants.push((str_field(t, "tenant")?, metrics_from_json(field(t, "metrics")?)?));
        }
        Ok(WireStats {
            enabled: bool_field(o, "enabled")?,
            snapshot: ObsSnapshot {
                node: str_field(o, "node")?,
                global: metrics_from_json(field(o, "global")?)?,
                tenants,
                ring_len: u64_field(o, "ring_len")?,
                ring_cap: u64_field(o, "ring_cap")?,
                ring_dropped: u64_field(o, "ring_dropped")?,
            },
            tiers: opt_tiers_field(o)?,
            queued: u64_field(o, "queued")?,
            running: u64_field(o, "running")?,
            done: u64_field(o, "done")?,
        })
    }
}

impl Message {
    /// The wire `"type"` string of this message.
    pub fn type_name(&self) -> &'static str {
        match self {
            Message::Hello { .. } => "hello",
            Message::Submit { .. } => "submit",
            Message::SubmitTune { .. } => "submit-tune",
            Message::Accepted { .. } => "accepted",
            Message::Status => "status",
            Message::StatusReport { .. } => "status-report",
            Message::Stats => "stats",
            Message::StatsReport(_) => "stats-report",
            Message::Result { .. } => "result",
            Message::JobDone(_) => "job-report",
            Message::Drain => "drain",
            Message::Bill(_) => "bill",
            Message::Route { .. } => "route",
            Message::Routed { .. } => "routed",
            Message::PeerJoin { .. } => "peer-join",
            Message::PeerLeave { .. } => "peer-leave",
            Message::CacheGet { .. } => "cache-get",
            Message::CacheState(_) => "cache-state",
            Message::CachePut(_) => "cache-put",
            Message::CacheOk { .. } => "cache-ok",
            Message::Error { .. } => "error",
        }
    }

    /// Serialize as the frame-body JSON object.
    pub fn to_json(&self) -> Json {
        match self {
            Message::Hello { version, role } => obj(vec![
                ("type", js("hello")),
                ("version", ju(u64::from(*version))),
                ("role", js(role)),
            ]),
            Message::Submit { tenant, study } => obj(vec![
                ("type", js("submit")),
                ("tenant", js(tenant)),
                ("study", Json::Arr(study.iter().map(|s| js(s.as_str())).collect())),
            ]),
            Message::SubmitTune { tenant, tune } => obj(vec![
                ("type", js("submit-tune")),
                ("tenant", js(tenant)),
                ("tune", Json::Arr(tune.iter().map(|s| js(s.as_str())).collect())),
            ]),
            Message::Accepted { job } => {
                obj(vec![("type", js("accepted")), ("job", ju(*job))])
            }
            Message::Status => obj(vec![("type", js("status"))]),
            Message::StatusReport { queued, running, done, tiers } => obj(vec![
                ("type", js("status-report")),
                ("queued", ju(*queued)),
                ("running", ju(*running)),
                ("done", ju(*done)),
                ("tiers", tiers_json(tiers)),
            ]),
            Message::Stats => obj(vec![("type", js("stats"))]),
            Message::StatsReport(stats) => stats.to_json(),
            Message::Result { job } => obj(vec![("type", js("result")), ("job", ju(*job))]),
            Message::JobDone(report) => report.to_json(),
            Message::Drain => obj(vec![("type", js("drain"))]),
            Message::Bill(bill) => bill.to_json(),
            Message::Route { tenant, study, trace } => {
                let mut fields = vec![
                    ("type", js("route")),
                    ("tenant", js(tenant)),
                    ("study", Json::Arr(study.iter().map(|s| js(s.as_str())).collect())),
                ];
                push_trace(&mut fields, trace);
                obj(fields)
            }
            Message::Routed { job, node } => obj(vec![
                ("type", js("routed")),
                ("job", ju(*job)),
                ("node", js(node)),
            ]),
            Message::PeerJoin { addr, peers } => obj(vec![
                ("type", js("peer-join")),
                ("addr", js(addr)),
                ("peers", ju(*peers)),
            ]),
            Message::PeerLeave { addr, peers } => obj(vec![
                ("type", js("peer-leave")),
                ("addr", js(addr)),
                ("peers", ju(*peers)),
            ]),
            Message::CacheGet { key, peek, trace } => {
                let mut fields = vec![("type", js("cache-get")), ("key", jkey(*key))];
                if *peek {
                    fields.push(("peek", jb(true)));
                }
                push_trace(&mut fields, trace);
                obj(fields)
            }
            Message::CacheState(state) => obj(vec![
                ("type", js("cache-state")),
                ("key", jkey(state.key)),
                ("found", jb(state.found)),
                ("claimed", jb(state.claimed)),
                ("h", ju(state.h)),
                ("w", ju(state.w)),
                ("planes", js(&state.planes)),
            ]),
            Message::CachePut(put) => {
                let mut fields = vec![
                    ("type", js("cache-put")),
                    ("key", jkey(put.key)),
                    ("h", ju(put.h)),
                    ("w", ju(put.w)),
                    ("planes", js(&put.planes)),
                ];
                push_trace(&mut fields, &put.trace);
                obj(fields)
            }
            Message::CacheOk { key, stored } => obj(vec![
                ("type", js("cache-ok")),
                ("key", jkey(*key)),
                ("stored", jb(*stored)),
            ]),
            Message::Error { code, message } => obj(vec![
                ("type", js("error")),
                ("code", js(code)),
                ("message", js(message)),
            ]),
        }
    }

    /// Parse a frame-body JSON object back into a message.
    pub fn from_json(o: &Json) -> Result<Message> {
        match str_field(o, "type")?.as_str() {
            "hello" => Ok(Message::Hello {
                version: u64_field(o, "version")? as u32,
                role: str_field(o, "role").unwrap_or_default(),
            }),
            "submit" => Ok(Message::Submit {
                tenant: str_field(o, "tenant")?,
                study: str_arr(o, "study")?,
            }),
            "submit-tune" => Ok(Message::SubmitTune {
                tenant: str_field(o, "tenant")?,
                tune: str_arr(o, "tune")?,
            }),
            "accepted" => Ok(Message::Accepted { job: u64_field(o, "job")? }),
            "status" => Ok(Message::Status),
            "status-report" => Ok(Message::StatusReport {
                queued: u64_field(o, "queued")?,
                running: u64_field(o, "running")?,
                done: u64_field(o, "done")?,
                tiers: opt_tiers_field(o)?,
            }),
            "stats" => Ok(Message::Stats),
            "stats-report" => Ok(Message::StatsReport(Box::new(WireStats::from_json(o)?))),
            "result" => Ok(Message::Result { job: u64_field(o, "job")? }),
            "job-report" => Ok(Message::JobDone(Box::new(WireJobReport::from_json(o)?))),
            "drain" => Ok(Message::Drain),
            "bill" => Ok(Message::Bill(Box::new(WireBill::from_json(o)?))),
            "route" => Ok(Message::Route {
                tenant: str_field(o, "tenant")?,
                study: str_arr(o, "study")?,
                trace: opt_trace_field(o)?,
            }),
            "routed" => Ok(Message::Routed {
                job: u64_field(o, "job")?,
                node: str_field(o, "node")?,
            }),
            "peer-join" => Ok(Message::PeerJoin {
                addr: str_field(o, "addr")?,
                peers: u64_field(o, "peers")?,
            }),
            "peer-leave" => Ok(Message::PeerLeave {
                addr: str_field(o, "addr")?,
                peers: u64_field(o, "peers")?,
            }),
            "cache-get" => Ok(Message::CacheGet {
                key: key_field(o, "key")?,
                peek: opt_bool_field(o, "peek")?,
                trace: opt_trace_field(o)?,
            }),
            "cache-state" => Ok(Message::CacheState(Box::new(WireCacheState {
                key: key_field(o, "key")?,
                found: bool_field(o, "found")?,
                claimed: bool_field(o, "claimed")?,
                h: u64_field(o, "h")?,
                w: u64_field(o, "w")?,
                planes: str_field(o, "planes")?,
            }))),
            "cache-put" => Ok(Message::CachePut(Box::new(WireCachePut {
                key: key_field(o, "key")?,
                h: u64_field(o, "h")?,
                w: u64_field(o, "w")?,
                planes: str_field(o, "planes")?,
                trace: opt_trace_field(o)?,
            }))),
            "cache-ok" => Ok(Message::CacheOk {
                key: key_field(o, "key")?,
                stored: bool_field(o, "stored")?,
            }),
            "error" => Ok(Message::Error {
                code: str_field(o, "code")?,
                message: str_field(o, "message")?,
            }),
            other => Err(Error::Protocol(format!("unknown message type `{other}`"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: Message) {
        let bytes = encode_frame(&msg);
        let (back, used) = decode_frame(&bytes).expect("frame decodes");
        assert_eq!(used, bytes.len(), "whole frame consumed");
        assert_eq!(back, msg);
        // and through the streaming reader
        let mut r = std::io::BufReader::new(&bytes[..]);
        assert_eq!(read_frame(&mut r).unwrap(), Some(msg));
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF after the frame");
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip(Message::Hello { version: PROTOCOL_VERSION, role: "client".into() });
        roundtrip(Message::Submit {
            tenant: "alice".into(),
            study: vec!["method=moat".into(), "r=2".into()],
        });
        roundtrip(Message::SubmitTune {
            tenant: "alice".into(),
            tune: vec!["tuner=ga".into(), "budget=32".into()],
        });
        roundtrip(Message::Accepted { job: 42 });
        roundtrip(Message::Status);
        roundtrip(Message::StatusReport { queued: 3, running: 2, done: 7, tiers: vec![] });
        roundtrip(Message::StatusReport {
            queued: 3,
            running: 2,
            done: 7,
            tiers: vec![WireTierStats {
                tier: "memory".into(),
                stats: TierStats { hits: 9, stores: 4, ..TierStats::default() },
            }],
        });
        roundtrip(Message::Stats);
        roundtrip(Message::StatsReport(Box::new(WireStats {
            enabled: true,
            snapshot: ObsSnapshot {
                node: "127.0.0.1:4101".into(),
                global: MetricsSnapshot {
                    counters: vec![("jobs_admitted".into(), 5), ("launches".into(), 80)],
                    hists: vec![HistSnapshot {
                        name: "launch_us".into(),
                        counts: vec![0, 3, 77, 0],
                        sum_us: 12_345,
                        count: 80,
                    }],
                },
                tenants: vec![(
                    "alice".into(),
                    MetricsSnapshot {
                        counters: vec![("jobs_admitted".into(), 5)],
                        hists: vec![],
                    },
                )],
                ring_len: 100,
                ring_cap: 8192,
                ring_dropped: 0,
            },
            tiers: vec![WireTierStats {
                tier: "remote".into(),
                stats: TierStats {
                    hits: 7,
                    stores: 3,
                    breaker_opens: 1,
                    breaker_closes: 1,
                    replica_hits: 2,
                    ..TierStats::default()
                },
            }],
            queued: 1,
            running: 2,
            done: 3,
        })));
        roundtrip(Message::Result { job: 42 });
        roundtrip(Message::JobDone(Box::new(WireJobReport {
            job: 42,
            tenant: "alice".into(),
            error: None,
            n_evals: 16,
            launches: 120,
            cached_tasks: 40,
            retries: 1,
            pruned: 6,
            speculative: 9,
            queue_wait_secs: 0.25,
            exec_wall_secs: 1.5,
            y: vec![0.5, 0.25],
            tune: None,
        })));
        roundtrip(Message::JobDone(Box::new(WireJobReport {
            job: 43,
            tenant: "alice".into(),
            y: vec![0.8, 0.9],
            tune: Some(TuneSummary {
                method: "genetic".into(),
                best_score: 0.9,
                initial_best_score: 0.8,
                best_params: vec![45.0, 22.0],
                evaluated: 20,
                memo_hits: 12,
                generations: 4,
            }),
            ..WireJobReport::default()
        })));
        roundtrip(Message::JobDone(Box::new(WireJobReport {
            error: Some("panic: boom".into()),
            ..WireJobReport::default()
        })));
        roundtrip(Message::Drain);
        roundtrip(Message::Bill(Box::new(WireBill {
            jobs: 2,
            retries: 3,
            pruned: 6,
            speculative_launches: 11,
            total_launches: 99,
            tenants: vec![WireTenantBill {
                tenant: "alice".into(),
                jobs: 1,
                launches: 90,
                retries: 3,
                pruned: 6,
                speculative: 9,
                quota_bytes: 1 << 20,
                cache: CacheStats { hits: 5, misses: 4, ..CacheStats::default() },
                ..WireTenantBill::default()
            }],
            warm_admitted: 12,
            warm_swept: 2,
            warm_metrics: 7,
            tiers: vec![
                WireTierStats {
                    tier: "memory".into(),
                    stats: TierStats { hits: 40, stores: 9, ..TierStats::default() },
                },
                WireTierStats {
                    tier: "remote".into(),
                    stats: TierStats { breaker_opens: 2, replica_hits: 5, ..TierStats::default() },
                },
            ],
            ..WireBill::default()
        })));
        roundtrip(Message::Error { code: codes::DRAINING.into(), message: "late".into() });
        roundtrip(Message::Route {
            tenant: "alice".into(),
            study: vec!["method=moat".into(), "r=2".into()],
            trace: None,
        });
        roundtrip(Message::Route {
            tenant: "alice".into(),
            study: vec!["method=moat".into(), "r=2".into()],
            trace: Some(WireTrace { trace: 0xfeed_beef, span: 0x1234 }),
        });
        roundtrip(Message::Routed { job: 7, node: "127.0.0.1:4101".into() });
        roundtrip(Message::PeerJoin { addr: "127.0.0.1:4103".into(), peers: 0 });
        roundtrip(Message::PeerJoin { addr: "127.0.0.1:4103".into(), peers: 3 });
        roundtrip(Message::PeerLeave { addr: "127.0.0.1:4102".into(), peers: 2 });
        let key = Key::from_parts(0xdead_beef, 42);
        let state =
            [Plane::filled(1.0, 2, 2), Plane::filled(0.5, 2, 2), Plane::filled(-3.25, 2, 2)];
        roundtrip(Message::CacheGet { key, peek: false, trace: None });
        roundtrip(Message::CacheGet { key, peek: true, trace: None });
        roundtrip(Message::CacheGet {
            key,
            peek: true,
            trace: Some(WireTrace { trace: u128::MAX, span: u64::MAX }),
        });
        roundtrip(Message::CacheState(Box::new(WireCacheState::found(key, &state))));
        roundtrip(Message::CacheState(Box::new(WireCacheState::claimed(key))));
        roundtrip(Message::CachePut(Box::new(WireCachePut::new(key, &state))));
        roundtrip(Message::CachePut(Box::new(WireCachePut {
            trace: Some(WireTrace { trace: 7, span: 9 }),
            ..WireCachePut::new(key, &state)
        })));
        roundtrip(Message::CacheOk { key, stored: true });
    }

    #[test]
    fn planes_survive_the_hex_codec_bit_exactly() {
        let state = [
            Plane::new(vec![0.0, -0.0, 1.5, f32::MIN_POSITIVE], 2, 2).unwrap(),
            Plane::new(vec![f32::MAX, f32::MIN, 1e-30, -7.125], 2, 2).unwrap(),
            Plane::filled(0.333, 2, 2),
        ];
        let (h, w, hex) = planes_to_hex(&state);
        assert_eq!((h, w), (2, 2));
        assert_eq!(hex.len(), 3 * 4 * 8, "8 hex chars per f32, 3 planes of 4");
        let back = planes_from_hex(h, w, &hex).unwrap();
        for (orig, dec) in state.iter().zip(back.iter()) {
            for (a, b) in orig.data().iter().zip(dec.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "bit-exact through hex");
            }
        }
        assert!(planes_from_hex(h, w, &hex[1..]).is_err(), "length mismatch rejected");
        let mut bad = hex.clone();
        bad.replace_range(0..1, "z");
        assert!(planes_from_hex(h, w, &bad).is_err(), "non-hex byte rejected");
    }

    #[test]
    fn malformed_frames_are_rejected() {
        assert!(decode_frame(b"").is_err(), "empty input has no header");
        assert!(decode_frame(b"rtfp1 5").is_err(), "unterminated header");
        assert!(decode_frame(b"http1 2\n{}\n").is_err(), "foreign tag");
        assert!(decode_frame(b"rtfp2 2\n{}\n").is_err(), "future frame version");
        assert!(decode_frame(b"rtfp1 xx\n{}\n").is_err(), "non-numeric length");
        assert!(decode_frame(b"rtfp1 999\n{}\n").is_err(), "truncated body");
        assert!(decode_frame(b"rtfp1 2\n{}X").is_err(), "missing body terminator");
        assert!(decode_frame(b"rtfp1 2\n[]\n").is_err(), "body must be a typed object");
        let huge = format!("rtfp1 {}\n", MAX_FRAME_BYTES + 1);
        assert!(decode_frame(huge.as_bytes()).is_err(), "oversized length rejected early");
    }

    #[test]
    fn crlf_after_the_header_is_tolerated_by_both_decoders() {
        let frame = b"rtfp1 16\r\n{\"type\":\"drain\"}\n";
        let (msg, used) = decode_frame(frame).unwrap();
        assert_eq!(msg, Message::Drain);
        assert_eq!(used, frame.len());
        let mut r = std::io::BufReader::new(&frame[..]);
        assert_eq!(read_frame(&mut r).unwrap(), Some(Message::Drain));
    }

    #[test]
    fn unknown_fields_are_tolerated_unknown_types_are_not() {
        let (msg, _) =
            decode_frame(b"rtfp1 38\n{\"type\":\"accepted\",\"job\":1,\"new\":true}\n").unwrap();
        assert_eq!(msg, Message::Accepted { job: 1 });
        assert!(decode_frame(b"rtfp1 17\n{\"type\":\"gossip\"}\n").is_err());
    }

    #[test]
    fn cache_get_without_peek_parses_as_a_claiming_get() {
        // a v5-era peer sends no `peek` field; v6 must read it as false
        let body = format!(
            "{{\"type\":\"cache-get\",\"key\":\"{:032x}\"}}",
            Key::from_parts(1, 2).as_u128()
        );
        let frame = format!("rtfp1 {}\n{}\n", body.len(), body);
        let (msg, _) = decode_frame(frame.as_bytes()).unwrap();
        assert_eq!(
            msg,
            Message::CacheGet { key: Key::from_parts(1, 2), peek: false, trace: None }
        );
    }

    #[test]
    fn v6_frames_without_trace_or_tiers_still_parse() {
        // a v6-era route carries no `trace`/`span`; v7 reads it as
        // untraced
        let body = "{\"type\":\"route\",\"tenant\":\"a\",\"study\":[\"r=2\"]}";
        let frame = format!("rtfp1 {}\n{}\n", body.len(), body);
        let (msg, _) = decode_frame(frame.as_bytes()).unwrap();
        assert_eq!(
            msg,
            Message::Route { tenant: "a".into(), study: vec!["r=2".into()], trace: None }
        );
        // a v6-era cache-put carries no trace either
        let body = format!(
            "{{\"type\":\"cache-put\",\"key\":\"{:032x}\",\"h\":0,\"w\":0,\"planes\":\"\"}}",
            Key::from_parts(3, 4).as_u128()
        );
        let frame = format!("rtfp1 {}\n{}\n", body.len(), body);
        let (msg, _) = decode_frame(frame.as_bytes()).unwrap();
        assert_eq!(
            msg,
            Message::CachePut(Box::new(WireCachePut {
                key: Key::from_parts(3, 4),
                ..WireCachePut::default()
            }))
        );
        // a v6-era status-report carries no `tiers`; v7 reads it empty
        let body = "{\"type\":\"status-report\",\"queued\":1,\"running\":2,\"done\":3}";
        let frame = format!("rtfp1 {}\n{}\n", body.len(), body);
        let (msg, _) = decode_frame(frame.as_bytes()).unwrap();
        assert_eq!(
            msg,
            Message::StatusReport { queued: 1, running: 2, done: 3, tiers: vec![] }
        );
    }

    #[test]
    fn a_malformed_trace_context_is_rejected() {
        let body = "{\"type\":\"route\",\"tenant\":\"a\",\"study\":[],\"trace\":\"xyz\"}";
        let frame = format!("rtfp1 {}\n{}\n", body.len(), body);
        assert!(decode_frame(frame.as_bytes()).is_err(), "non-hex trace id rejected");
    }

    #[test]
    fn type_names_match_the_spec() {
        for (msg, name) in [
            (Message::Status, "status"),
            (Message::Drain, "drain"),
            (Message::Accepted { job: 0 }, "accepted"),
            (Message::Route { tenant: String::new(), study: vec![], trace: None }, "route"),
            (Message::Routed { job: 0, node: String::new() }, "routed"),
            (Message::PeerJoin { addr: String::new(), peers: 0 }, "peer-join"),
            (Message::PeerLeave { addr: String::new(), peers: 0 }, "peer-leave"),
            (Message::Stats, "stats"),
            (Message::StatsReport(Box::default()), "stats-report"),
        ] {
            assert_eq!(msg.type_name(), name);
            assert_eq!(msg.to_json().get("type").and_then(|t| t.as_str()), Some(name));
        }
    }
}
