//! The TCP side of the service: a [`WireServer`] accepts connections,
//! speaks the frame protocol of [`super::protocol`], and translates
//! messages into [`StudyService`] calls.
//!
//! One handler thread per connection; the service itself is shared
//! behind an `Arc`, so any number of clients can submit and wait
//! concurrently — admission fairness, quotas and accounting all happen
//! in the service layer, exactly as for in-process submission. A
//! `drain` message from any client drains the service (queued work
//! completes), answers with the final `bill`, and shuts the listener
//! down; [`WireServer::run`] then returns the same [`ServiceReport`]
//! the in-process path gets, so the operator's exit report is identical
//! either way.
//!
//! # Backpressure
//!
//! Each connection has a submit window (`window=N`, rtfp v4): the
//! number of jobs it has submitted but not yet collected with `result`.
//! A `submit` past the window is answered with an `over-window` error
//! frame and the connection stays usable — collect a result, submit
//! again. This bounds the queue growth any one client can cause without
//! touching tenant quotas (which meter bytes, not queue depth).

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::cache::RemoteServe;
use crate::config::{StudyConfig, TuneConfig};
use crate::{Error, Result};

use super::protocol::{
    codes, encode_frame, planes_from_hex, read_frame, write_frame, Message, WireBill,
    WireCacheState, WireJobReport, PROTOCOL_VERSION,
};
use super::service::{ServiceReport, StudyJob, StudyService};

/// A bound-but-not-yet-serving wire server. [`WireServer::bind`] then
/// [`WireServer::run`]; [`WireServer::local_addr`] in between is how
/// callers learn an OS-assigned port (`listen=127.0.0.1:0`).
pub struct WireServer {
    svc: Arc<StudyService>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    report: Arc<Mutex<Option<ServiceReport>>>,
}

impl WireServer {
    /// Bind the listening socket (the service keeps running either way;
    /// binding only fails on address errors).
    pub fn bind(svc: StudyService, addr: &str) -> Result<WireServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Protocol(format!("cannot listen on {addr}: {e}")))?;
        Ok(WireServer {
            svc: Arc::new(svc),
            listener,
            shutdown: Arc::new(AtomicBool::new(false)),
            report: Arc::new(Mutex::new(None)),
        })
    }

    /// The actually bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(Error::Io)
    }

    /// The shared service (diagnostics; submission still works through
    /// it while the server runs).
    pub fn service(&self) -> &Arc<StudyService> {
        &self.svc
    }

    /// Serve connections until a client drains the service, then return
    /// the drained [`ServiceReport`]. Handler threads for connections
    /// that are still open when the drain completes are left to exit on
    /// their own (they can only observe a drained service); the process
    /// typically exits right after this returns.
    pub fn run(self) -> Result<ServiceReport> {
        let self_addr = self.local_addr()?;
        for conn in self.listener.incoming() {
            if self.shutdown.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let svc = Arc::clone(&self.svc);
            let shutdown = Arc::clone(&self.shutdown);
            let report = Arc::clone(&self.report);
            std::thread::spawn(move || {
                let _ = handle_conn(stream, svc, shutdown, report, self_addr);
            });
        }
        let report = self.report.lock().unwrap().take();
        report.ok_or_else(|| Error::Protocol("listener stopped without a drain".into()))
    }
}

/// Serve one connection to completion. I/O errors end the connection
/// silently (the peer is gone); protocol errors are answered with an
/// `error` frame first when the socket still writes.
fn handle_conn(
    stream: TcpStream,
    svc: Arc<StudyService>,
    shutdown: Arc<AtomicBool>,
    report: Arc<Mutex<Option<ServiceReport>>>,
    self_addr: SocketAddr,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(stream);

    // hello/hello version handshake, first frame in each direction
    match read_frame(&mut reader) {
        Ok(Some(Message::Hello { version, .. })) if version == PROTOCOL_VERSION => {
            let hello = Message::Hello { version: PROTOCOL_VERSION, role: "server".into() };
            write_frame(&mut writer, &hello)?;
            writer.flush().map_err(Error::Io)?;
        }
        Ok(Some(Message::Hello { version, .. })) => {
            let msg = format!("server speaks v{PROTOCOL_VERSION}, client sent v{version}");
            return refuse(&mut writer, codes::VERSION_MISMATCH, &msg);
        }
        Ok(Some(other)) => {
            let msg = format!("expected hello, got {}", other.type_name());
            return refuse(&mut writer, codes::BAD_MESSAGE, &msg);
        }
        Ok(None) => return Ok(()), // connected and left
        Err(e) => return refuse(&mut writer, codes::BAD_FRAME, &e.to_string()),
    }

    // submit window: jobs this connection accepted but has not yet
    // collected; a submit past the cap gets `over-window`, not a queue
    // slot (the connection itself stays fine)
    let window = svc.submit_window();
    let mut undelivered: std::collections::HashSet<u64> = std::collections::HashSet::new();

    loop {
        let msg = match read_frame(&mut reader) {
            Ok(Some(m)) => m,
            Ok(None) => return Ok(()), // clean close
            Err(Error::Io(_)) => return Ok(()),
            Err(e) => return refuse(&mut writer, codes::BAD_FRAME, &e.to_string()),
        };
        let reply = match msg {
            Message::Submit { .. } | Message::SubmitTune { .. }
                if undelivered.len() >= window =>
            {
                let msg = format!(
                    "connection holds {} undelivered jobs (window={window}); \
                     collect a result before submitting more",
                    undelivered.len()
                );
                error_msg(codes::OVER_WINDOW, &msg)
            }
            Message::Submit { tenant, study } => match StudyConfig::from_args(&study) {
                Ok(cfg) => match svc.submit(StudyJob { tenant, cfg }) {
                    Ok(job) => {
                        undelivered.insert(job);
                        Message::Accepted { job }
                    }
                    Err(e) => error_msg(codes::DRAINING, &e.to_string()),
                },
                Err(e) => error_msg(codes::BAD_STUDY, &e.to_string()),
            },
            Message::SubmitTune { tenant, tune } => match TuneConfig::from_args(&tune) {
                Ok(tc) => match svc.submit_tune(tenant, tc.study, tc.options) {
                    Ok(job) => {
                        undelivered.insert(job);
                        Message::Accepted { job }
                    }
                    Err(e) => error_msg(codes::DRAINING, &e.to_string()),
                },
                Err(e) => error_msg(codes::BAD_STUDY, &e.to_string()),
            },
            Message::Status => Message::StatusReport {
                queued: svc.queued() as u64,
                running: svc.in_flight() as u64,
                done: svc.completed() as u64,
            },
            Message::Result { job } => match svc.wait_job(job) {
                Some(done) => {
                    undelivered.remove(&job);
                    Message::JobDone(Box::new(WireJobReport::from(&done)))
                }
                None => error_msg(codes::UNKNOWN_JOB, &format!("no job with id {job}")),
            },
            Message::Drain => {
                // drain blocks until every queued/in-flight study is
                // done, then the bill goes out before the listener stops
                let service_report = svc.drain();
                let bill = Message::Bill(Box::new(WireBill::from(&service_report)));
                *report.lock().unwrap() = Some(service_report);
                // best-effort bill delivery: the listener must stop even
                // if this client went away while the drain ran
                let sent = write_frame(&mut writer, &bill)
                    .and_then(|()| writer.flush().map_err(Error::Io));
                shutdown.store(true, Ordering::Release);
                // wake the accept loop so it observes the flag
                let _ = TcpStream::connect(self_addr);
                return sent;
            }
            Message::CacheGet { key } => {
                // blocks while another node holds the cross-node claim
                // on this key — cluster single-flight (rtfp v3)
                match svc.cache().serve_remote_get(key) {
                    RemoteServe::Found(state) => {
                        Message::CacheState(Box::new(WireCacheState::found(key, &state)))
                    }
                    RemoteServe::Claimed => {
                        Message::CacheState(Box::new(WireCacheState::claimed(key)))
                    }
                }
            }
            Message::CachePut(put) => match planes_from_hex(put.h, put.w, &put.planes) {
                Ok(planes) => {
                    let stored = svc.cache().serve_remote_put(put.key, planes);
                    Message::CacheOk { key: put.key, stored }
                }
                Err(e) => error_msg(codes::BAD_MESSAGE, &e.to_string()),
            },
            other => {
                let msg = format!("unexpected message `{}` from a client", other.type_name());
                error_msg(codes::BAD_MESSAGE, &msg)
            }
        };
        // fault injection: a scripted hook can mangle an outbound
        // cache-state frame — exercises the *peer's* recovery path (it
        // must treat the garbage as a miss, not wedge). Only peer
        // traffic is eligible; client-facing frames have no scripted
        // reader on the other end
        let corrupt = matches!(reply, Message::CacheState(_))
            && svc.faults().get().is_some_and(|h| h.on_frame_out());
        if corrupt {
            let mut bytes = encode_frame(&reply);
            // flip the first body byte (`{` becomes `[`): the frame
            // header still parses, the body fails JSON decoding
            let body = bytes.iter().position(|&b| b == b'\n').map_or(0, |p| p + 1);
            if body < bytes.len() {
                bytes[body] ^= 0x20;
            }
            writer.write_all(&bytes).map_err(Error::Io)?;
        } else {
            write_frame(&mut writer, &reply)?;
        }
        writer.flush().map_err(Error::Io)?;
    }
}

fn error_msg(code: &str, message: &str) -> Message {
    Message::Error { code: code.into(), message: message.into() }
}

/// Send one `error` frame and end the connection.
fn refuse<W: Write>(writer: &mut W, code: &str, message: &str) -> Result<()> {
    write_frame(writer, &error_msg(code, message))?;
    writer.flush().map_err(Error::Io)
}
