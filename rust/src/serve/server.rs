//! The TCP side of the service: a [`WireServer`] accepts connections,
//! speaks the frame protocol of [`super::protocol`], and translates
//! messages into [`StudyService`] calls.
//!
//! One handler thread per connection; the service itself is shared
//! behind an `Arc`, so any number of clients can submit and wait
//! concurrently — admission fairness, quotas and accounting all happen
//! in the service layer, exactly as for in-process submission. A
//! `drain` message from any client drains the service (queued work
//! completes), answers with the final `bill`, and shuts the listener
//! down; [`WireServer::run`] then returns the same [`ServiceReport`]
//! the in-process path gets, so the operator's exit report is identical
//! either way.
//!
//! # Backpressure
//!
//! Each connection has a submit window (`window=N`, rtfp v4): the
//! number of jobs it has submitted but not yet collected with `result`.
//! A `submit` past the window is answered with an `over-window` error
//! frame and the connection stays usable — collect a result, submit
//! again. This bounds the queue growth any one client can cause without
//! touching tenant quotas (which meter bytes, not queue depth).
//!
//! # Front door (rtfp v6)
//!
//! With `route=on`, a `submit` may be *routed*: the server predicts
//! which peer owns the largest share of the study's chain keys
//! ([`StudyService::predict_route`]) and, when another node wins,
//! forwards the study there as a `route` frame over a dedicated
//! connection. The client sees a normal `accepted` carrying a local
//! proxy handle; a later `result` for that handle is relayed to the
//! owning peer and the `job-done` report comes back rewritten to the
//! handle the client knows. Any routing failure falls back to local
//! execution — routing is an optimization, never a correctness
//! dependency. A received `route` is always executed locally
//! (loop-free by construction).

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::cache::RemoteServe;
use crate::config::{StudyConfig, TuneConfig};
use crate::obs::{span, CounterId, SpanCtx};
use crate::{Error, Result};

use super::protocol::{
    codes, encode_frame, planes_from_hex, read_frame, write_frame, Message, WireBill,
    WireCacheState, WireJobReport, WireTierStats, WireTrace, PROTOCOL_VERSION,
};
use super::service::{ServiceReport, StudyJob, StudyService};

/// A bound-but-not-yet-serving wire server. [`WireServer::bind`] then
/// [`WireServer::run`]; [`WireServer::local_addr`] in between is how
/// callers learn an OS-assigned port (`listen=127.0.0.1:0`).
pub struct WireServer {
    svc: Arc<StudyService>,
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    report: Arc<Mutex<Option<ServiceReport>>>,
}

impl WireServer {
    /// Bind the listening socket (the service keeps running either way;
    /// binding only fails on address errors).
    pub fn bind(svc: StudyService, addr: &str) -> Result<WireServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Protocol(format!("cannot listen on {addr}: {e}")))?;
        Ok(WireServer {
            svc: Arc::new(svc),
            listener,
            shutdown: Arc::new(AtomicBool::new(false)),
            report: Arc::new(Mutex::new(None)),
        })
    }

    /// The actually bound address (resolves port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(Error::Io)
    }

    /// The shared service (diagnostics; submission still works through
    /// it while the server runs).
    pub fn service(&self) -> &Arc<StudyService> {
        &self.svc
    }

    /// Serve connections until a client drains the service, then return
    /// the drained [`ServiceReport`]. Handler threads for connections
    /// that are still open when the drain completes are left to exit on
    /// their own (they can only observe a drained service); the process
    /// typically exits right after this returns.
    pub fn run(self) -> Result<ServiceReport> {
        let self_addr = self.local_addr()?;
        for conn in self.listener.incoming() {
            if self.shutdown.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let svc = Arc::clone(&self.svc);
            let shutdown = Arc::clone(&self.shutdown);
            let report = Arc::clone(&self.report);
            std::thread::spawn(move || {
                let _ = handle_conn(stream, svc, shutdown, report, self_addr);
            });
        }
        let report = self.report.lock().unwrap().take();
        report.ok_or_else(|| Error::Protocol("listener stopped without a drain".into()))
    }
}

/// Serve one connection to completion. I/O errors end the connection
/// silently (the peer is gone); protocol errors are answered with an
/// `error` frame first when the socket still writes.
fn handle_conn(
    stream: TcpStream,
    svc: Arc<StudyService>,
    shutdown: Arc<AtomicBool>,
    report: Arc<Mutex<Option<ServiceReport>>>,
    self_addr: SocketAddr,
) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(stream);

    // hello/hello version handshake, first frame in each direction
    match read_frame(&mut reader) {
        Ok(Some(Message::Hello { version, .. })) if version == PROTOCOL_VERSION => {
            let hello = Message::Hello { version: PROTOCOL_VERSION, role: "server".into() };
            write_frame(&mut writer, &hello)?;
            writer.flush().map_err(Error::Io)?;
        }
        Ok(Some(Message::Hello { version, .. })) => {
            let msg = format!("server speaks v{PROTOCOL_VERSION}, client sent v{version}");
            return refuse(&mut writer, codes::VERSION_MISMATCH, &msg);
        }
        Ok(Some(other)) => {
            let msg = format!("expected hello, got {}", other.type_name());
            return refuse(&mut writer, codes::BAD_MESSAGE, &msg);
        }
        Ok(None) => return Ok(()), // connected and left
        Err(e) => return refuse(&mut writer, codes::BAD_FRAME, &e.to_string()),
    }

    // submit window: jobs this connection accepted but has not yet
    // collected; a submit past the cap gets `over-window`, not a queue
    // slot (the connection itself stays fine). Routed proxy handles
    // count toward the window like local jobs.
    let window = svc.submit_window();
    let mut undelivered: std::collections::HashSet<u64> = std::collections::HashSet::new();
    // front-door state: proxy handle -> the peer connection holding the
    // routed job (handles start at ROUTE_BASE; local ids never collide)
    let mut proxied: std::collections::HashMap<u64, ProxiedJob> = std::collections::HashMap::new();
    let mut next_handle: u64 = ROUTE_BASE;

    loop {
        let msg = match read_frame(&mut reader) {
            Ok(Some(m)) => m,
            Ok(None) => return Ok(()), // clean close
            Err(Error::Io(_)) => return Ok(()),
            Err(e) => return refuse(&mut writer, codes::BAD_FRAME, &e.to_string()),
        };
        let reply = match msg {
            Message::Submit { .. } | Message::SubmitTune { .. } | Message::Route { .. }
                if undelivered.len() >= window =>
            {
                let msg = format!(
                    "connection holds {} undelivered jobs (window={window}); \
                     collect a result before submitting more",
                    undelivered.len()
                );
                error_msg(codes::OVER_WINDOW, &msg)
            }
            Message::Submit { tenant, study } => match StudyConfig::from_args(&study) {
                Ok(cfg) => {
                    // front door: when another peer owns most of this
                    // study's predicted chain keys, hand the job there
                    // and give the client a proxy handle. Every failure
                    // on this path falls through to local execution.
                    let routed = if svc.route_enabled() {
                        // with tracing on, the route span is the trace
                        // ROOT of a routed job: the peer parents its
                        // whole job tree under the `trace` we stamp here
                        let traced = svc.obs().get().map(|o| {
                            let trace = o.new_trace();
                            let route_span = o.next_span();
                            (WireTrace { trace, span: route_span }, Instant::now())
                        });
                        svc.predict_route(&cfg).and_then(|peer| {
                            let job =
                                open_route(&peer, &tenant, &study, traced.map(|(w, _)| w))?;
                            if let (Some(o), Some((w, started))) = (svc.obs().get(), traced) {
                                let ctx = SpanCtx {
                                    trace: w.trace,
                                    parent: 0,
                                    tenant: Arc::from(tenant.as_str()),
                                    job: next_handle,
                                };
                                let dur = started.elapsed();
                                o.emit_timed(
                                    &ctx,
                                    span::ROUTE,
                                    w.span,
                                    started,
                                    dur,
                                    format!("to {peer}"),
                                );
                                o.add(CounterId::JobsRouted, Some(&tenant), 1);
                            }
                            Some(job)
                        })
                    } else {
                        None
                    };
                    match routed {
                        Some(job) => {
                            let handle = next_handle;
                            next_handle += 1;
                            proxied.insert(handle, job);
                            undelivered.insert(handle);
                            Message::Accepted { job: handle }
                        }
                        None => match svc.submit(StudyJob { tenant, cfg }) {
                            Ok(job) => {
                                undelivered.insert(job);
                                Message::Accepted { job }
                            }
                            Err(e) => error_msg(codes::DRAINING, &e.to_string()),
                        },
                    }
                }
                Err(e) => error_msg(codes::BAD_STUDY, &e.to_string()),
            },
            Message::Route { tenant, study, trace } => match StudyConfig::from_args(&study) {
                // a routed submit from a peer's front door: execute
                // HERE, unconditionally — a route is never re-routed,
                // so no membership disagreement can form a cycle. Any
                // `trace` context makes this job's spans children of the
                // front door's route span (same trace id, cross-node).
                Ok(cfg) => match svc.submit_with_trace(StudyJob { tenant, cfg }, trace) {
                    Ok(job) => {
                        undelivered.insert(job);
                        let node = svc
                            .cluster_addr()
                            .unwrap_or_else(|| self_addr.to_string());
                        Message::Routed { job, node }
                    }
                    Err(e) => error_msg(codes::DRAINING, &e.to_string()),
                },
                Err(e) => error_msg(codes::BAD_STUDY, &e.to_string()),
            },
            Message::SubmitTune { tenant, tune } => match TuneConfig::from_args(&tune) {
                Ok(tc) => match svc.submit_tune(tenant, tc.study, tc.options) {
                    Ok(job) => {
                        undelivered.insert(job);
                        Message::Accepted { job }
                    }
                    Err(e) => error_msg(codes::DRAINING, &e.to_string()),
                },
                Err(e) => error_msg(codes::BAD_STUDY, &e.to_string()),
            },
            Message::Status => Message::StatusReport {
                queued: svc.queued() as u64,
                running: svc.in_flight() as u64,
                done: svc.completed() as u64,
                tiers: svc
                    .tier_stats()
                    .into_iter()
                    .map(|(tier, stats)| WireTierStats { tier, stats })
                    .collect(),
            },
            Message::Stats => Message::StatsReport(Box::new(svc.stats_snapshot())),
            Message::Result { job } if proxied.contains_key(&job) => {
                let reply = proxy_result(&proxied[&job], job);
                if matches!(reply, Message::JobDone(_)) {
                    proxied.remove(&job);
                    undelivered.remove(&job);
                }
                reply
            }
            Message::Result { job } => match svc.wait_job(job) {
                Some(done) => {
                    undelivered.remove(&job);
                    Message::JobDone(Box::new(WireJobReport::from(&done)))
                }
                None => error_msg(codes::UNKNOWN_JOB, &format!("no job with id {job}")),
            },
            Message::Drain => {
                // drain blocks until every queued/in-flight study is
                // done, then the bill goes out before the listener stops
                let service_report = svc.drain();
                let bill = Message::Bill(Box::new(WireBill::from(&service_report)));
                *report.lock().unwrap() = Some(service_report);
                // best-effort bill delivery: the listener must stop even
                // if this client went away while the drain ran
                let sent = write_frame(&mut writer, &bill)
                    .and_then(|()| writer.flush().map_err(Error::Io));
                shutdown.store(true, Ordering::Release);
                // wake the accept loop so it observes the flag
                let _ = TcpStream::connect(self_addr);
                return sent;
            }
            Message::CacheGet { key, peek: true, trace } => {
                // claim-free read (rtfp v6): replica fallbacks use this
                // so a degraded read can never wedge a requester behind
                // a claim TTL — worst case is one duplicated launch
                let started = Instant::now();
                let (reply, outcome) = match svc.cache().peek_state(key) {
                    Some(state) => {
                        (Message::CacheState(Box::new(WireCacheState::found(key, &state))), "hit")
                    }
                    // wire shape of a miss is found=false, same frame a
                    // claimed key gets — a peeker treats both as a miss
                    None => {
                        (Message::CacheState(Box::new(WireCacheState::claimed(key))), "miss")
                    }
                };
                emit_serve_span(&svc, trace, span::SERVE_GET, started, format!("peek {outcome}"));
                reply
            }
            Message::CacheGet { key, peek: false, trace } => {
                // blocks while another node holds the cross-node claim
                // on this key — cluster single-flight (rtfp v3)
                let started = Instant::now();
                let (reply, outcome) = match svc.cache().serve_remote_get(key) {
                    RemoteServe::Found(state) => {
                        // replication hook: the serve that crosses the
                        // hot watermark pushes this key to its replica
                        svc.note_remote_served(key);
                        (Message::CacheState(Box::new(WireCacheState::found(key, &state))), "hit")
                    }
                    RemoteServe::Claimed => {
                        (Message::CacheState(Box::new(WireCacheState::claimed(key))), "claimed")
                    }
                };
                emit_serve_span(&svc, trace, span::SERVE_GET, started, outcome.to_string());
                reply
            }
            Message::CachePut(put) => match planes_from_hex(put.h, put.w, &put.planes) {
                Ok(planes) => {
                    let started = Instant::now();
                    let stored = svc.cache().serve_remote_put(put.key, planes);
                    emit_serve_span(
                        &svc,
                        put.trace,
                        span::SERVE_PUT,
                        started,
                        format!("stored={stored}"),
                    );
                    Message::CacheOk { key: put.key, stored }
                }
                Err(e) => error_msg(codes::BAD_MESSAGE, &e.to_string()),
            },
            // live membership (rtfp v6): peers=0 marks an
            // admin-originated change — apply AND relay it (with our
            // new ring size, so receivers don't relay again); nonzero
            // means a peer already relayed — apply only. The ack echoes
            // the message with this node's new ring size.
            Message::PeerJoin { addr, peers } => match svc.peer_join(&addr, peers == 0) {
                Ok(size) => Message::PeerJoin { addr, peers: size },
                Err(e) => error_msg(codes::BAD_MESSAGE, &e.to_string()),
            },
            Message::PeerLeave { addr, peers } => match svc.peer_leave(&addr, peers == 0) {
                Ok(size) => Message::PeerLeave { addr, peers: size },
                Err(e) => error_msg(codes::BAD_MESSAGE, &e.to_string()),
            },
            other => {
                let msg = format!("unexpected message `{}` from a client", other.type_name());
                error_msg(codes::BAD_MESSAGE, &msg)
            }
        };
        // fault injection: a scripted hook can mangle an outbound
        // cache-state frame — exercises the *peer's* recovery path (it
        // must treat the garbage as a miss, not wedge). Only peer
        // traffic is eligible; client-facing frames have no scripted
        // reader on the other end
        let corrupt = matches!(reply, Message::CacheState(_))
            && svc.faults().get().is_some_and(|h| h.on_frame_out());
        if corrupt {
            let mut bytes = encode_frame(&reply);
            // flip the first body byte (`{` becomes `[`): the frame
            // header still parses, the body fails JSON decoding
            let body = bytes.iter().position(|&b| b == b'\n').map_or(0, |p| p + 1);
            if body < bytes.len() {
                bytes[body] ^= 0x20;
            }
            writer.write_all(&bytes).map_err(Error::Io)?;
        } else {
            write_frame(&mut writer, &reply)?;
        }
        writer.flush().map_err(Error::Io)?;
    }
}

/// Proxy handles start here — far above any id the service will ever
/// assign locally, so a client can't confuse the two spaces.
const ROUTE_BASE: u64 = 1 << 32;

/// A routed job: the dedicated peer connection carrying it, and the
/// job id the *peer* assigned (the client only ever sees the local
/// proxy handle).
struct ProxiedJob {
    stream: TcpStream,
    remote_id: u64,
}

/// Dial the winning peer and hand it the study as a `route` frame.
/// Returns the open connection + remote job id, or `None` on any
/// failure (the caller falls back to local execution). The connection
/// gets a bounded connect timeout but NO read timeout: the later
/// `result` relay blocks for as long as the job runs.
fn open_route(
    peer: &str,
    tenant: &str,
    study: &[String],
    trace: Option<WireTrace>,
) -> Option<ProxiedJob> {
    use std::net::ToSocketAddrs;
    let sock = peer.to_socket_addrs().ok()?.next()?;
    let stream =
        TcpStream::connect_timeout(&sock, std::time::Duration::from_secs(2)).ok()?;
    let mut w = BufWriter::new(stream.try_clone().ok()?);
    let mut r = BufReader::new(stream.try_clone().ok()?);
    let hello = Message::Hello { version: PROTOCOL_VERSION, role: "router".into() };
    write_frame(&mut w, &hello).ok()?;
    w.flush().ok()?;
    match read_frame(&mut r).ok()?? {
        Message::Hello { version, .. } if version == PROTOCOL_VERSION => {}
        _ => return None,
    }
    let route = Message::Route { tenant: tenant.to_string(), study: study.to_vec(), trace };
    write_frame(&mut w, &route).ok()?;
    w.flush().ok()?;
    match read_frame(&mut r).ok()?? {
        Message::Routed { job, .. } => Some(ProxiedJob { stream, remote_id: job }),
        _ => None,
    }
}

/// Relay a `result` wait to the peer owning a routed job and rewrite
/// the report's job id back to the proxy handle the client knows.
fn proxy_result(p: &ProxiedJob, handle: u64) -> Message {
    let exchange = || -> Option<Message> {
        let mut w = BufWriter::new(p.stream.try_clone().ok()?);
        write_frame(&mut w, &Message::Result { job: p.remote_id }).ok()?;
        w.flush().ok()?;
        let mut r = BufReader::new(p.stream.try_clone().ok()?);
        read_frame(&mut r).ok()?
    };
    match exchange() {
        Some(Message::JobDone(mut report)) => {
            report.job = handle;
            Message::JobDone(report)
        }
        Some(Message::Error { code, message }) => Message::Error { code, message },
        _ => error_msg(
            codes::UNKNOWN_JOB,
            &format!("routed peer went away holding proxy handle {handle}"),
        ),
    }
}

/// Emit a `serve-get`/`serve-put` span on the owner node, parented
/// under the requester's per-tier lookup span when the frame carried a
/// trace context (rtfp v7). No trace on the frame, or telemetry off on
/// this node: no event, no allocation. The pseudo-tenant `~peer` keeps
/// owner-side serve work out of every real tenant's metric scope.
fn emit_serve_span(
    svc: &StudyService,
    trace: Option<WireTrace>,
    kind: &'static str,
    started: Instant,
    detail: String,
) {
    if let (Some(o), Some(w)) = (svc.obs().get(), trace) {
        let ctx = SpanCtx { trace: w.trace, parent: w.span, tenant: Arc::from("~peer"), job: 0 };
        let id = o.next_span();
        o.emit_timed(&ctx, kind, id, started, started.elapsed(), detail);
    }
}

fn error_msg(code: &str, message: &str) -> Message {
    Message::Error { code: code.into(), message: message.into() }
}

/// Send one `error` frame and end the connection.
fn refuse<W: Write>(writer: &mut W, code: &str, message: &str) -> Result<()> {
    write_frame(writer, &error_msg(code, message))?;
    writer.flush().map_err(Error::Io)
}
