//! The in-tree wire client: submit a jobs file to a listening service,
//! collect every result, optionally drain and fetch the bill.
//!
//! This is the reference implementation of the client side of
//! `docs/SERVING.md` (and what `rtf-reuse serve submit=ADDR jobs=FILE`
//! runs): one TCP connection, a `hello` handshake, pipelined `submit`s,
//! then a blocking `result` per job in submission order. Third-party
//! clients only need the protocol module's frame layout to
//! interoperate.
//!
//! Jobs files may also carry *admin lines* (rtfp v6 live membership):
//! `peers add=ADDR` / `peers remove=ADDR` send a `peer-join` /
//! `peer-leave` (with `peers=0`, marking the change admin-originated so
//! the receiving node relays it) at that point of the submit sequence —
//! which is what lets a test or operator change membership mid-run. A
//! bare `stats` line (rtfp v7) fetches the server's telemetry snapshot
//! at that point; [`render_prometheus`] turns a snapshot into the
//! Prometheus-style text dump the CLI prints.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;

use crate::obs::{MetricsSnapshot, BUCKET_BOUNDS_US};
use crate::config::{StudyConfig, TuneConfig};
use crate::{Error, Result};

use super::protocol::{
    read_frame, write_frame, Message, WireBill, WireJobReport, WireStats, PROTOCOL_VERSION,
};

/// One job to submit: a tenant plus the job's `key=value` options
/// (already merged with any client-side defaults). `tune` selects the
/// tuning job kind (a `kind=tune` token on the job line).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobSpec {
    pub tenant: String,
    pub args: Vec<String>,
    pub tune: bool,
}

/// One line of a jobs file: a job to submit, or an admin action taken
/// at that point of the sequence.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobLine {
    /// `tenant=NAME ...` — submit a study/tune job.
    Job(JobSpec),
    /// `peers add=ADDR` — tell the service a node joined the ring.
    PeerAdd(String),
    /// `peers remove=ADDR` — tell the service a node left the ring.
    PeerRemove(String),
    /// `stats` — fetch the server's telemetry snapshot at this point
    /// of the sequence (rtfp v7).
    Stats,
}

/// What a client run brought back.
#[derive(Clone, Debug, Default)]
pub struct ClientOutcome {
    /// One report per submitted job, submission order.
    pub jobs: Vec<WireJobReport>,
    /// The service's final bill, when the run drained it.
    pub bill: Option<WireBill>,
    /// One snapshot per `stats` admin line, sequence order.
    pub stats: Vec<WireStats>,
}

/// Parse a jobs file: one job per line, `tenant=NAME [kind=study|tune]
/// [job options]`; blank lines and `#` comments are skipped. `defaults`
/// (the CLI's residual study options) are prepended to every line's
/// options, so a line's own `key=value` pairs override them. Each
/// merged option list is validated client-side —
/// [`StudyConfig::from_args`] for studies, [`TuneConfig::from_args`]
/// for `kind=tune` lines — so a typo fails fast here instead of
/// round-tripping to the server.
pub fn parse_jobs_file(text: &str, defaults: &[String]) -> Result<Vec<JobSpec>> {
    parse_job_lines(text, defaults)?
        .into_iter()
        .map(|l| match l {
            JobLine::Job(spec) => Ok(spec),
            JobLine::PeerAdd(_) | JobLine::PeerRemove(_) | JobLine::Stats => Err(Error::Config(
                "admin `peers`/`stats` lines need the line-mode client (run_lines)".into(),
            )),
        })
        .collect()
}

/// Like [`parse_jobs_file`], but admin lines (`peers add=ADDR`,
/// `peers remove=ADDR`) are first-class: they keep their position in
/// the sequence, so [`run_lines`] performs them between submissions.
pub fn parse_job_lines(text: &str, defaults: &[String]) -> Result<Vec<JobLine>> {
    let mut lines = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let bad = |e: Error| Error::Config(format!("jobs file line {}: {e}", lineno + 1));
        if let Some(rest) = line.strip_prefix("peers") {
            let rest = rest.trim();
            let parsed = match rest.split_once('=') {
                Some(("add", addr)) if addr.contains(':') => JobLine::PeerAdd(addr.into()),
                Some(("remove", addr)) if addr.contains(':') => JobLine::PeerRemove(addr.into()),
                _ => {
                    return Err(bad(Error::Config(format!(
                        "`peers` admin line wants add=ADDR:PORT or remove=ADDR:PORT, got `{rest}`"
                    ))));
                }
            };
            lines.push(parsed);
            continue;
        }
        if line == "stats" {
            lines.push(JobLine::Stats);
            continue;
        }
        let mut tenant = None;
        let mut tune = false;
        let mut args: Vec<String> = defaults.to_vec();
        for tok in line.split_whitespace() {
            match tok.split_once('=') {
                Some(("tenant", v)) => tenant = Some(v.to_string()),
                Some(("kind", "study")) => tune = false,
                Some(("kind", "tune")) => tune = true,
                Some(("kind", other)) => {
                    return Err(bad(Error::Config(format!(
                        "unknown job kind `{other}` (study|tune)"
                    ))));
                }
                _ => args.push(tok.to_string()),
            }
        }
        let tenant = tenant.ok_or_else(|| {
            Error::Config(format!("jobs file line {}: missing tenant=NAME", lineno + 1))
        })?;
        if tune {
            TuneConfig::from_args(&args).map_err(bad)?;
        } else {
            StudyConfig::from_args(&args).map_err(bad)?;
        }
        lines.push(JobLine::Job(JobSpec { tenant, args, tune }));
    }
    Ok(lines)
}

/// Submit `specs` to the service at `addr`, wait for every result, and
/// — when `drain` is set — drain the service and return its bill (the
/// server exits afterwards). Any protocol-level `error` reply aborts
/// the run as [`Error::Protocol`].
pub fn run_jobs(addr: &str, specs: &[JobSpec], drain: bool) -> Result<ClientOutcome> {
    let lines: Vec<JobLine> = specs.iter().cloned().map(JobLine::Job).collect();
    run_lines(addr, &lines, drain)
}

/// Like [`run_jobs`], but over [`JobLine`]s: admin lines execute *in
/// sequence position* — a `peers remove=` between two submits changes
/// membership while the first job may still be running, which is
/// exactly what the membership-chaos tests exercise.
pub fn run_lines(addr: &str, lines: &[JobLine], drain: bool) -> Result<ClientOutcome> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::Protocol(format!("cannot connect to {addr}: {e}")))?;
    let mut reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(stream);

    let hello = Message::Hello { version: PROTOCOL_VERSION, role: "client".into() };
    write_frame(&mut writer, &hello)?;
    writer.flush().map_err(Error::Io)?;
    match expect_reply(&mut reader)? {
        Message::Hello { version, .. } if version == PROTOCOL_VERSION => {}
        Message::Hello { version, .. } => {
            return Err(Error::Protocol(format!(
                "server speaks protocol v{version}, this client v{PROTOCOL_VERSION}"
            )));
        }
        other => return Err(unexpected("hello", &other)),
    }

    // `stats` lines at the END of the sequence snapshot after every
    // result is collected (stable counters: all submitted jobs have
    // finished); anywhere else they snapshot at that point of the
    // submit sequence (a live mid-run view).
    let trailing = lines.iter().rev().take_while(|l| matches!(l, JobLine::Stats)).count();
    let (head, tail) = lines.split_at(lines.len() - trailing);

    let mut ids = Vec::with_capacity(lines.len());
    let mut stats = Vec::new();
    for line in head {
        match line {
            JobLine::Job(spec) => {
                let submit = if spec.tune {
                    Message::SubmitTune { tenant: spec.tenant.clone(), tune: spec.args.clone() }
                } else {
                    Message::Submit { tenant: spec.tenant.clone(), study: spec.args.clone() }
                };
                write_frame(&mut writer, &submit)?;
                writer.flush().map_err(Error::Io)?;
                match expect_reply(&mut reader)? {
                    Message::Accepted { job } => ids.push(job),
                    other => return Err(unexpected("accepted", &other)),
                }
            }
            // peers=0 marks the change admin-originated: the receiving
            // node applies it AND relays it to the rest of the ring
            JobLine::PeerAdd(peer) => {
                let msg = Message::PeerJoin { addr: peer.clone(), peers: 0 };
                write_frame(&mut writer, &msg)?;
                writer.flush().map_err(Error::Io)?;
                match expect_reply(&mut reader)? {
                    Message::PeerJoin { .. } => {}
                    other => return Err(unexpected("peer-join", &other)),
                }
            }
            JobLine::PeerRemove(peer) => {
                let msg = Message::PeerLeave { addr: peer.clone(), peers: 0 };
                write_frame(&mut writer, &msg)?;
                writer.flush().map_err(Error::Io)?;
                match expect_reply(&mut reader)? {
                    Message::PeerLeave { .. } => {}
                    other => return Err(unexpected("peer-leave", &other)),
                }
            }
            JobLine::Stats => {
                write_frame(&mut writer, &Message::Stats)?;
                writer.flush().map_err(Error::Io)?;
                match expect_reply(&mut reader)? {
                    Message::StatsReport(s) => stats.push(*s),
                    other => return Err(unexpected("stats-report", &other)),
                }
            }
        }
    }

    let mut jobs = Vec::with_capacity(ids.len());
    for id in ids {
        write_frame(&mut writer, &Message::Result { job: id })?;
        writer.flush().map_err(Error::Io)?;
        match expect_reply(&mut reader)? {
            Message::JobDone(report) => jobs.push(*report),
            other => return Err(unexpected("job-report", &other)),
        }
    }

    for _ in tail {
        write_frame(&mut writer, &Message::Stats)?;
        writer.flush().map_err(Error::Io)?;
        match expect_reply(&mut reader)? {
            Message::StatsReport(s) => stats.push(*s),
            other => return Err(unexpected("stats-report", &other)),
        }
    }

    let bill = if drain {
        write_frame(&mut writer, &Message::Drain)?;
        writer.flush().map_err(Error::Io)?;
        match expect_reply(&mut reader)? {
            Message::Bill(bill) => Some(*bill),
            other => return Err(unexpected("bill", &other)),
        }
    } else {
        None
    };
    Ok(ClientOutcome { jobs, bill, stats })
}

/// Render a [`WireStats`] snapshot as a Prometheus-style text dump:
/// `rtf_`-prefixed counter samples (global, then `tenant`-labelled),
/// cumulative `_bucket`/`_sum`/`_count` histogram rows over the fixed
/// [`BUCKET_BOUNDS_US`] boundaries, per-tier cache counters under a
/// `tier` label, and queue/span-ring gauges. With telemetry off the
/// registry rows are absent; tier and queue rows are always live.
pub fn render_prometheus(stats: &WireStats) -> String {
    use std::fmt::Write as _;
    let snap = &stats.snapshot;
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# rtf-reuse stats: node {} telemetry={}",
        snap.node,
        if stats.enabled { "on" } else { "off" }
    );
    push_metrics(&mut out, &snap.global, None);
    for (tenant, m) in &snap.tenants {
        push_metrics(&mut out, m, Some(tenant));
    }
    for t in &stats.tiers {
        let rows = [
            ("hits", t.stats.hits),
            ("stores", t.stats.stores),
            ("resident_bytes", t.stats.resident_bytes),
            ("breaker_opens", t.stats.breaker_opens),
            ("breaker_closes", t.stats.breaker_closes),
            ("replica_hits", t.stats.replica_hits),
        ];
        for (name, v) in rows {
            let _ = writeln!(out, "rtf_tier_{name}{{tier=\"{}\"}} {v}", t.tier);
        }
    }
    let _ = writeln!(out, "rtf_jobs_queued {}", stats.queued);
    let _ = writeln!(out, "rtf_jobs_running {}", stats.running);
    let _ = writeln!(out, "rtf_jobs_done {}", stats.done);
    let _ = writeln!(out, "rtf_span_ring_len {}", snap.ring_len);
    let _ = writeln!(out, "rtf_span_ring_dropped {}", snap.ring_dropped);
    out
}

/// One metric scope (global or one tenant) of the Prometheus dump.
fn push_metrics(out: &mut String, m: &MetricsSnapshot, tenant: Option<&str>) {
    use std::fmt::Write as _;
    let scope = tenant.map(|t| format!("tenant=\"{t}\"")).unwrap_or_default();
    let braced = if scope.is_empty() { String::new() } else { format!("{{{scope}}}") };
    for (name, v) in &m.counters {
        let _ = writeln!(out, "rtf_{name}{braced} {v}");
    }
    for h in &m.hists {
        let mut cum = 0u64;
        for (i, c) in h.counts.iter().enumerate() {
            cum += c;
            let le = BUCKET_BOUNDS_US
                .get(i)
                .map_or_else(|| "+Inf".to_string(), |b| b.to_string());
            let sep = if scope.is_empty() { String::new() } else { format!("{scope},") };
            let _ = writeln!(out, "rtf_{}_bucket{{{sep}le=\"{le}\"}} {cum}", h.name);
        }
        let _ = writeln!(out, "rtf_{}_sum{braced} {}", h.name, h.sum_us);
        let _ = writeln!(out, "rtf_{}_count{braced} {}", h.name, h.count);
    }
}

/// Read the next frame, turning EOF and `error` replies into errors.
fn expect_reply<R: std::io::BufRead>(reader: &mut R) -> Result<Message> {
    match read_frame(reader)? {
        Some(Message::Error { code, message }) => {
            Err(Error::Protocol(format!("server refused [{code}]: {message}")))
        }
        Some(msg) => Ok(msg),
        None => Err(Error::Protocol("server closed the connection".into())),
    }
}

fn unexpected(wanted: &str, got: &Message) -> Error {
    Error::Protocol(format!("expected `{wanted}`, got `{}`", got.type_name()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_file_parses_defaults_and_overrides() {
        let text = "\n# comment\ntenant=alice method=moat r=2\ntenant=bob seed=7\n";
        let defaults = vec!["workers=2".to_string()];
        let specs = parse_jobs_file(text, &defaults).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].tenant, "alice");
        assert_eq!(specs[0].args, vec!["workers=2", "method=moat", "r=2"]);
        assert!(!specs[0].tune, "study is the default job kind");
        assert_eq!(specs[1].tenant, "bob");
        assert_eq!(specs[1].args, vec!["workers=2", "seed=7"]);
    }

    #[test]
    fn jobs_file_parses_tune_lines() {
        let text = "tenant=alice kind=tune tuner=ga budget=8\ntenant=bob kind=study r=1\n";
        let specs = parse_jobs_file(text, &[]).unwrap();
        assert!(specs[0].tune);
        assert_eq!(specs[0].args, vec!["tuner=ga", "budget=8"]);
        assert!(!specs[1].tune);
        // tune knobs on a study line are rejected client-side
        assert!(parse_jobs_file("tenant=a tuner=ga\n", &[]).is_err());
        assert!(parse_jobs_file("tenant=a kind=sweep\n", &[]).is_err(), "unknown kind");
        // study defaults merge into tune lines too
        let specs =
            parse_jobs_file("tenant=a kind=tune budget=4\n", &["seed=9".to_string()]).unwrap();
        assert_eq!(specs[0].args, vec!["seed=9", "budget=4"]);
    }

    #[test]
    fn jobs_file_parses_admin_lines_in_sequence_position() {
        let text = "tenant=a r=1\npeers remove=127.0.0.1:9\ntenant=b r=1\npeers add=127.0.0.1:9\n";
        let lines = parse_job_lines(text, &[]).unwrap();
        assert_eq!(lines.len(), 4);
        assert!(matches!(lines[0], JobLine::Job(_)));
        assert_eq!(lines[1], JobLine::PeerRemove("127.0.0.1:9".into()));
        assert!(matches!(lines[2], JobLine::Job(_)));
        assert_eq!(lines[3], JobLine::PeerAdd("127.0.0.1:9".into()));
        // malformed admin lines name the expected shape
        for bad in ["peers", "peers add=", "peers add=noport", "peers drop=h:1"] {
            let err = parse_job_lines(bad, &[]).unwrap_err();
            assert!(err.to_string().contains("add=ADDR:PORT"), "`{bad}`: {err}");
        }
        // the strict jobs-file API refuses admin lines rather than
        // silently dropping a membership change
        assert!(parse_jobs_file(text, &[]).is_err());
    }

    #[test]
    fn jobs_file_parses_stats_lines() {
        let text = "tenant=a r=1\nstats\n";
        let lines = parse_job_lines(text, &[]).unwrap();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[1], JobLine::Stats);
        // strict jobs-file API refuses admin lines, stats included
        assert!(parse_jobs_file(text, &[]).is_err());
    }

    #[test]
    fn prometheus_dump_renders_scopes_buckets_and_tiers() {
        use crate::cache::TierStats;
        use crate::obs::{HistSnapshot, MetricsSnapshot, ObsSnapshot};
        use crate::serve::protocol::{WireStats, WireTierStats};
        let hist = HistSnapshot {
            name: "job_wall_us".into(),
            counts: {
                let mut c = vec![0u64; BUCKET_BOUNDS_US.len() + 1];
                c[0] = 2; // two samples in the first bucket
                c[BUCKET_BOUNDS_US.len()] = 1; // one overflow
                c
            },
            sum_us: 1234,
            count: 3,
        };
        let global = MetricsSnapshot {
            counters: vec![("jobs_admitted".into(), 3)],
            hists: vec![hist.clone()],
        };
        let alice =
            MetricsSnapshot { counters: vec![("jobs_admitted".into(), 3)], hists: vec![hist] };
        let stats = WireStats {
            enabled: true,
            snapshot: ObsSnapshot {
                node: "127.0.0.1:7071".into(),
                global,
                tenants: vec![("alice".into(), alice)],
                ring_len: 5,
                ring_cap: 8192,
                ring_dropped: 0,
            },
            tiers: vec![WireTierStats {
                tier: "memory".into(),
                stats: TierStats { hits: 7, ..TierStats::default() },
            }],
            queued: 1,
            running: 2,
            done: 3,
        };
        let dump = render_prometheus(&stats);
        assert!(dump.contains("rtf_jobs_admitted 3\n"), "{dump}");
        assert!(dump.contains("rtf_jobs_admitted{tenant=\"alice\"} 3\n"), "{dump}");
        // buckets are cumulative and close with +Inf == count
        let first = BUCKET_BOUNDS_US[0];
        assert!(dump.contains(&format!("rtf_job_wall_us_bucket{{le=\"{first}\"}} 2\n")), "{dump}");
        assert!(dump.contains("rtf_job_wall_us_bucket{le=\"+Inf\"} 3\n"), "{dump}");
        assert!(dump.contains("rtf_job_wall_us_bucket{tenant=\"alice\",le=\"+Inf\"} 3\n"));
        assert!(dump.contains("rtf_job_wall_us_sum 1234\n"), "{dump}");
        assert!(dump.contains("rtf_job_wall_us_count 3\n"), "{dump}");
        assert!(dump.contains("rtf_tier_hits{tier=\"memory\"} 7\n"), "{dump}");
        assert!(dump.contains("rtf_jobs_running 2\n"), "{dump}");
        assert!(dump.contains("rtf_span_ring_len 5\n"), "{dump}");
    }

    #[test]
    fn jobs_file_rejects_bad_lines() {
        assert!(parse_jobs_file("method=moat\n", &[]).is_err(), "missing tenant");
        assert!(parse_jobs_file("tenant=a bogus=1\n", &[]).is_err(), "bad study option");
        assert!(parse_jobs_file("tenant=a kind=tune bogus=1\n", &[]).is_err(), "bad tune option");
        let err = parse_jobs_file("tenant=a\ntenant=b frob=1\n", &[]).unwrap_err();
        assert!(err.to_string().contains("line 2"), "errors carry line numbers: {err}");
    }
}
