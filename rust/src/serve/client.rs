//! The in-tree wire client: submit a jobs file to a listening service,
//! collect every result, optionally drain and fetch the bill.
//!
//! This is the reference implementation of the client side of
//! `docs/SERVING.md` (and what `rtf-reuse serve submit=ADDR jobs=FILE`
//! runs): one TCP connection, a `hello` handshake, pipelined `submit`s,
//! then a blocking `result` per job in submission order. Third-party
//! clients only need the protocol module's frame layout to
//! interoperate.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;

use crate::config::{StudyConfig, TuneConfig};
use crate::{Error, Result};

use super::protocol::{
    read_frame, write_frame, Message, WireBill, WireJobReport, PROTOCOL_VERSION,
};

/// One job to submit: a tenant plus the job's `key=value` options
/// (already merged with any client-side defaults). `tune` selects the
/// tuning job kind (a `kind=tune` token on the job line).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JobSpec {
    pub tenant: String,
    pub args: Vec<String>,
    pub tune: bool,
}

/// What a client run brought back.
#[derive(Clone, Debug, Default)]
pub struct ClientOutcome {
    /// One report per submitted job, submission order.
    pub jobs: Vec<WireJobReport>,
    /// The service's final bill, when the run drained it.
    pub bill: Option<WireBill>,
}

/// Parse a jobs file: one job per line, `tenant=NAME [kind=study|tune]
/// [job options]`; blank lines and `#` comments are skipped. `defaults`
/// (the CLI's residual study options) are prepended to every line's
/// options, so a line's own `key=value` pairs override them. Each
/// merged option list is validated client-side —
/// [`StudyConfig::from_args`] for studies, [`TuneConfig::from_args`]
/// for `kind=tune` lines — so a typo fails fast here instead of
/// round-tripping to the server.
pub fn parse_jobs_file(text: &str, defaults: &[String]) -> Result<Vec<JobSpec>> {
    let mut specs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let bad = |e: Error| Error::Config(format!("jobs file line {}: {e}", lineno + 1));
        let mut tenant = None;
        let mut tune = false;
        let mut args: Vec<String> = defaults.to_vec();
        for tok in line.split_whitespace() {
            match tok.split_once('=') {
                Some(("tenant", v)) => tenant = Some(v.to_string()),
                Some(("kind", "study")) => tune = false,
                Some(("kind", "tune")) => tune = true,
                Some(("kind", other)) => {
                    return Err(bad(Error::Config(format!(
                        "unknown job kind `{other}` (study|tune)"
                    ))));
                }
                _ => args.push(tok.to_string()),
            }
        }
        let tenant = tenant.ok_or_else(|| {
            Error::Config(format!("jobs file line {}: missing tenant=NAME", lineno + 1))
        })?;
        if tune {
            TuneConfig::from_args(&args).map_err(bad)?;
        } else {
            StudyConfig::from_args(&args).map_err(bad)?;
        }
        specs.push(JobSpec { tenant, args, tune });
    }
    Ok(specs)
}

/// Submit `specs` to the service at `addr`, wait for every result, and
/// — when `drain` is set — drain the service and return its bill (the
/// server exits afterwards). Any protocol-level `error` reply aborts
/// the run as [`Error::Protocol`].
pub fn run_jobs(addr: &str, specs: &[JobSpec], drain: bool) -> Result<ClientOutcome> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| Error::Protocol(format!("cannot connect to {addr}: {e}")))?;
    let mut reader = BufReader::new(stream.try_clone().map_err(Error::Io)?);
    let mut writer = BufWriter::new(stream);

    let hello = Message::Hello { version: PROTOCOL_VERSION, role: "client".into() };
    write_frame(&mut writer, &hello)?;
    writer.flush().map_err(Error::Io)?;
    match expect_reply(&mut reader)? {
        Message::Hello { version, .. } if version == PROTOCOL_VERSION => {}
        Message::Hello { version, .. } => {
            return Err(Error::Protocol(format!(
                "server speaks protocol v{version}, this client v{PROTOCOL_VERSION}"
            )));
        }
        other => return Err(unexpected("hello", &other)),
    }

    let mut ids = Vec::with_capacity(specs.len());
    for spec in specs {
        let submit = if spec.tune {
            Message::SubmitTune { tenant: spec.tenant.clone(), tune: spec.args.clone() }
        } else {
            Message::Submit { tenant: spec.tenant.clone(), study: spec.args.clone() }
        };
        write_frame(&mut writer, &submit)?;
        writer.flush().map_err(Error::Io)?;
        match expect_reply(&mut reader)? {
            Message::Accepted { job } => ids.push(job),
            other => return Err(unexpected("accepted", &other)),
        }
    }

    let mut jobs = Vec::with_capacity(ids.len());
    for id in ids {
        write_frame(&mut writer, &Message::Result { job: id })?;
        writer.flush().map_err(Error::Io)?;
        match expect_reply(&mut reader)? {
            Message::JobDone(report) => jobs.push(*report),
            other => return Err(unexpected("job-report", &other)),
        }
    }

    let bill = if drain {
        write_frame(&mut writer, &Message::Drain)?;
        writer.flush().map_err(Error::Io)?;
        match expect_reply(&mut reader)? {
            Message::Bill(bill) => Some(*bill),
            other => return Err(unexpected("bill", &other)),
        }
    } else {
        None
    };
    Ok(ClientOutcome { jobs, bill })
}

/// Read the next frame, turning EOF and `error` replies into errors.
fn expect_reply<R: std::io::BufRead>(reader: &mut R) -> Result<Message> {
    match read_frame(reader)? {
        Some(Message::Error { code, message }) => {
            Err(Error::Protocol(format!("server refused [{code}]: {message}")))
        }
        Some(msg) => Ok(msg),
        None => Err(Error::Protocol("server closed the connection".into())),
    }
}

fn unexpected(wanted: &str, got: &Message) -> Error {
    Error::Protocol(format!("expected `{wanted}`, got `{}`", got.type_name()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jobs_file_parses_defaults_and_overrides() {
        let text = "\n# comment\ntenant=alice method=moat r=2\ntenant=bob seed=7\n";
        let defaults = vec!["workers=2".to_string()];
        let specs = parse_jobs_file(text, &defaults).unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].tenant, "alice");
        assert_eq!(specs[0].args, vec!["workers=2", "method=moat", "r=2"]);
        assert!(!specs[0].tune, "study is the default job kind");
        assert_eq!(specs[1].tenant, "bob");
        assert_eq!(specs[1].args, vec!["workers=2", "seed=7"]);
    }

    #[test]
    fn jobs_file_parses_tune_lines() {
        let text = "tenant=alice kind=tune tuner=ga budget=8\ntenant=bob kind=study r=1\n";
        let specs = parse_jobs_file(text, &[]).unwrap();
        assert!(specs[0].tune);
        assert_eq!(specs[0].args, vec!["tuner=ga", "budget=8"]);
        assert!(!specs[1].tune);
        // tune knobs on a study line are rejected client-side
        assert!(parse_jobs_file("tenant=a tuner=ga\n", &[]).is_err());
        assert!(parse_jobs_file("tenant=a kind=sweep\n", &[]).is_err(), "unknown kind");
        // study defaults merge into tune lines too
        let specs =
            parse_jobs_file("tenant=a kind=tune budget=4\n", &["seed=9".to_string()]).unwrap();
        assert_eq!(specs[0].args, vec!["seed=9", "budget=4"]);
    }

    #[test]
    fn jobs_file_rejects_bad_lines() {
        assert!(parse_jobs_file("method=moat\n", &[]).is_err(), "missing tenant");
        assert!(parse_jobs_file("tenant=a bogus=1\n", &[]).is_err(), "bad study option");
        assert!(parse_jobs_file("tenant=a kind=tune bogus=1\n", &[]).is_err(), "bad tune option");
        let err = parse_jobs_file("tenant=a\ntenant=b frob=1\n", &[]).unwrap_err();
        assert!(err.to_string().contains("line 2"), "errors carry line numbers: {err}");
    }
}
