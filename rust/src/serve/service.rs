//! The service proper: submission queue, weighted-fair admission,
//! worker pool, per-tenant quotas and accounting, warm start, graceful
//! drain.

use std::collections::{HashMap, HashSet, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::cache::{
    fold_keys, node_input_key, task_cache_sig, tile_fingerprints, CacheConfig, CacheStats, Key,
    RemoteTier, ReuseCache, ScopedCounters, TierStats, WarmStartReport,
};
use crate::config::{EngineMode, ServeConfig, StudyConfig};
use crate::driver::{
    make_inputs_with_engine, make_tiles, prepare, prepare_candidates, prune_plan_with_inputs,
    run_pjrt_with_inputs_scoped, PreparedStudy, StudyInputs,
};
use crate::faults::Faults;
use crate::merging::{reuse_tree::ReuseTree, unit_stages};
use crate::obs::{span, CounterId, HistId, MetricsSnapshot, Obs, ObsInner, ObsSnapshot, SpanCtx};
use crate::runtime::PjrtEngine;
use crate::adaptive::run_adaptive_scoped;
use crate::sampling::{default_space, ParamSet};
use crate::serve::protocol::{Message, WireStats, WireTierStats, WireTrace};
use crate::tune::{run_tune_with_hook, SpeculationHook, TuneOptions, TuneSummary};
use crate::{Error, Result};

/// Service shape. The service pins the execution-environment knobs
/// (artifacts, per-study worker count, batch width, cache); per-job
/// [`StudyConfig`]s choose the *study* (method, sampler, algorithm,
/// seed, tiles) and have their environment fields overridden.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Concurrent studies in flight (service worker threads).
    pub service_workers: usize,
    /// Fair-admission cap: studies one tenant may have in flight at
    /// once; excess jobs wait in the queue behind other tenants' work.
    pub tenant_inflight_cap: usize,
    /// PJRT worker threads each study executes with.
    pub study_workers: usize,
    /// Frontier batch width for study execution.
    pub batch_width: usize,
    /// Artifact directory the process serves (one artifact set per
    /// service; the leader engine compiles it once).
    pub artifacts_dir: String,
    /// The process-lifetime shared cache.
    pub cache: CacheConfig,
    /// Per-tenant admission weights for the weighted-fair scheduler
    /// (missing tenants weigh 1). A weight-4 tenant is handed ~4× the
    /// jobs of a weight-1 tenant while both have work queued; every
    /// weight is finite, so no tenant starves.
    pub tenant_weights: HashMap<String, u32>,
    /// Default memory-tier byte quota applied to every tenant's scope
    /// (`None`/0 = unlimited). See `ScopedCounters::with_quota`.
    pub tenant_quota_bytes: Option<u64>,
    /// Per-tenant quota overrides (win over the default).
    pub tenant_quota_overrides: HashMap<String, u64>,
    /// Pre-admit persisted disk-tier entries into memory at boot
    /// (`ReuseCache::warm_start`); meaningful only with a `spill_dir`.
    pub warm_start: bool,
    /// Cluster mode: the full peer list (`serve peers=ADDR,...`,
    /// including this node's own listen address). Non-empty attaches a
    /// [`RemoteTier`] below the local tiers, partitioning the key space
    /// across the listed nodes.
    pub peers: Vec<String>,
    /// This node's address as it appears in `peers` (the `listen=`
    /// address). Required when `peers` is non-empty.
    pub cluster_addr: Option<String>,
    /// Replication factor for hot reuse-tree prefixes (`replicas=N`,
    /// default 1): a key the owner has served at least twice is pushed
    /// to the peer with the key's next-highest rendezvous score, so a
    /// breaker-open owner degrades to replica hits instead of local
    /// launches. 0 disables replication. Cluster mode only.
    pub replicas: usize,
    /// Front-door routing (`route=on`): a `submit` landing on this node
    /// is forwarded to the peer owning the largest share of the study's
    /// predicted chain keys (`route`/`routed` wire messages), with the
    /// result proxied back on the submitting connection. Off by
    /// default. Cluster mode only.
    pub route: bool,
    /// Extra execution attempts a failed job is granted (total attempts
    /// = `job_retries + 1`; 0 disables retry). Retries back off
    /// exponentially with deterministic per-(job, attempt) jitter and
    /// are billed distinctly ([`JobReport::retries`]).
    pub job_retries: u32,
    /// Wall-clock budget per job across all of its attempts: once
    /// elapsed, a failed attempt is not retried. `None` = attempts are
    /// bounded only by `job_retries`.
    pub job_deadline: Option<Duration>,
    /// How long [`StudyService::drain`] waits for in-flight work before
    /// abandoning unfinished worker threads (they are detached, their
    /// jobs missing from the report — shutdown is never wedged by one
    /// stuck study). `None` waits forever.
    pub drain_deadline: Option<Duration>,
    /// Per-connection backpressure window for the wire server: the most
    /// submits one connection may have unanswered (neither `result`ed
    /// nor failed) before further submits are refused with an
    /// `over-window` error frame.
    pub submit_window: usize,
    /// Speculative execution (`speculate=on`): while a tuning job's
    /// generation is being scored, idle workers pre-execute the tuner's
    /// predicted next generation through the normal single-flight cache
    /// path. Speculation can only ever warm the cache — a wrong guess
    /// is a pre-warmed entry, never a changed result — and its launches
    /// are billed distinctly ([`ServiceReport::speculative_launches`]),
    /// never charged to a tenant. A tune job's own `speculate=on`
    /// enables it for that job even when this is off.
    pub speculate: bool,
    /// Fault-injection hook (see [`crate::faults`]) threaded into the
    /// shared cache's disk tier, the remote tier, the wire server's
    /// outbound frames, and every *study* worker engine. The leader
    /// engine (shared input building) deliberately never sees faults —
    /// a scripted panic there would poison the service-wide memo, which
    /// is not a failure mode the harness targets.
    pub faults: Faults,
    /// `trace=FILE`: activate telemetry ([`crate::obs`]) with FILE as
    /// the JSONL span sink. Every job gets a trace id and a span tree
    /// (admit → queue → schedule → frontier levels → lookups/launches →
    /// retries); see `docs/OBSERVABILITY.md`.
    pub trace: Option<String>,
    /// `stats=on`: activate telemetry (ring + metrics, no file sink
    /// unless `trace` is also set) and log a one-line digest whenever
    /// the counters move.
    pub stats: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        let cfg = StudyConfig::default();
        Self {
            service_workers: 2,
            tenant_inflight_cap: 1,
            study_workers: cfg.workers,
            batch_width: cfg.batch_width,
            artifacts_dir: cfg.artifacts_dir,
            cache: CacheConfig::default(),
            tenant_weights: HashMap::new(),
            tenant_quota_bytes: None,
            tenant_quota_overrides: HashMap::new(),
            warm_start: false,
            peers: Vec::new(),
            cluster_addr: None,
            replicas: 1,
            route: false,
            job_retries: DEFAULT_JOB_RETRIES,
            job_deadline: None,
            drain_deadline: Some(DEFAULT_DRAIN_DEADLINE),
            submit_window: DEFAULT_SUBMIT_WINDOW,
            speculate: false,
            faults: Faults::none(),
            trace: None,
            stats: false,
        }
    }
}

/// The pseudo-tenant speculative executions bill their cache traffic
/// under: a real scope (so tenant-scoped sums still equal the global
/// counters with speculation on) that no client tenant can collide with
/// (`~` never appears in real tenant names; jobs count 0).
pub const SPECULATIVE_TENANT: &str = "~speculative";

/// Default extra attempts per failed job (`retries=` flag).
pub const DEFAULT_JOB_RETRIES: u32 = 2;
/// Default per-connection submit window (`window=` flag).
pub const DEFAULT_SUBMIT_WINDOW: usize = 64;
/// Default drain patience before unfinished workers are abandoned.
pub const DEFAULT_DRAIN_DEADLINE: Duration = Duration::from_secs(600);

impl ServeOptions {
    /// Build the service options a parsed `serve` CLI invocation
    /// ([`ServeConfig`]) describes: MiB quotas become bytes, priority
    /// pairs become the weight table, and the study's environment
    /// fields pin the service environment.
    pub fn from_config(sc: &ServeConfig) -> ServeOptions {
        const MIB: u64 = 1024 * 1024;
        ServeOptions {
            service_workers: sc.serve_workers,
            tenant_inflight_cap: sc.tenant_cap,
            study_workers: sc.study.workers,
            batch_width: sc.study.batch_width,
            artifacts_dir: sc.study.artifacts_dir.clone(),
            cache: sc.study.cache.to_cache_config(),
            tenant_weights: sc.priorities.iter().cloned().collect(),
            tenant_quota_bytes: sc.quota_mb.map(|mb| mb as u64 * MIB),
            tenant_quota_overrides: sc
                .quota_overrides_mb
                .iter()
                .map(|(t, mb)| (t.clone(), *mb as u64 * MIB))
                .collect(),
            warm_start: sc.warm_start_effective(),
            peers: sc.peers.clone(),
            cluster_addr: if sc.peers.is_empty() { None } else { sc.listen.clone() },
            replicas: sc.replicas.unwrap_or(1),
            route: sc.route.unwrap_or(false),
            job_retries: sc.job_retries.unwrap_or(DEFAULT_JOB_RETRIES),
            submit_window: sc.submit_window.unwrap_or(DEFAULT_SUBMIT_WINDOW),
            speculate: sc.speculate.unwrap_or(false),
            trace: sc.trace.clone(),
            stats: sc.stats,
            ..ServeOptions::default()
        }
    }

    fn weight_of(&self, tenant: &str) -> u64 {
        u64::from(self.tenant_weights.get(tenant).copied().unwrap_or(1).max(1))
    }

    fn quota_of(&self, tenant: &str) -> u64 {
        self.tenant_quota_overrides
            .get(tenant)
            .copied()
            .or(self.tenant_quota_bytes)
            .unwrap_or(0)
    }
}

/// One unit of tenant work: a study to run under a tenant's account.
#[derive(Clone, Debug)]
pub struct StudyJob {
    pub tenant: String,
    pub cfg: StudyConfig,
}

/// What a queued job runs: a one-shot SA study, or an optimizer-driven
/// tuning loop of studies ([`crate::tune`]). Both kinds share the
/// worker pool, the fair-admission scheduler, the per-tenant scopes and
/// ONE reuse cache — a tenant's tuning run warms another tenant's SA
/// study and vice versa.
enum JobPayload {
    Study(StudyConfig),
    Tune(StudyConfig, TuneOptions),
}

/// What one job produced (returned inside [`ServiceReport::jobs`]).
#[derive(Clone, Debug)]
pub struct JobReport {
    pub job: u64,
    pub tenant: String,
    /// `None` on success, the failure message otherwise.
    pub error: Option<String>,
    pub n_evals: usize,
    /// Backend launches this job paid for (non-cached task executions,
    /// comparison included). Cache-served work is in `cached_tasks`.
    pub launches: u64,
    pub cached_tasks: u64,
    /// Per-evaluation scalar outputs (the SA estimator inputs). For a
    /// tuning job: the per-generation best objective scores.
    pub y: Vec<f64>,
    /// Tuning jobs only: what the optimizer found.
    pub tune: Option<TuneSummary>,
    /// Execution attempts beyond the first this job consumed (each a
    /// failed attempt that was retried). A job can succeed with
    /// `retries > 0`; a job that failed with `retries == job_retries`
    /// exhausted its budget.
    pub retries: u64,
    /// Adaptive studies only: evaluations the online pruner cancelled
    /// before launch (work a non-adaptive run would have paid for).
    /// Pruned output slots hold 0.0 in `y`; never silently dropped.
    pub pruned: u64,
    /// Speculative launches completed on this job's behalf by the time
    /// its report was assembled — a lower bound: speculation still in
    /// flight lands only in the global
    /// [`ServiceReport::speculative_launches`]. Billed to the
    /// [`SPECULATIVE_TENANT`] scope, never to this tenant.
    pub speculative: u64,
    /// Time spent queued before a worker picked the job up.
    pub queue_wait: Duration,
    /// Wall time of the study execution itself (the successful — or
    /// final — attempt).
    pub exec_wall: Duration,
}

impl JobReport {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }
}

/// A tenant's aggregate bill.
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub tenant: String,
    pub jobs: u64,
    pub failed: u64,
    pub launches: u64,
    pub cached_tasks: u64,
    /// Retried attempts across this tenant's jobs (sum of per-job
    /// [`JobReport::retries`]) — recovery work the service performed on
    /// the tenant's behalf, billed distinctly from first attempts.
    pub retries: u64,
    /// Pruned evaluations across this tenant's adaptive jobs (sum of
    /// per-job [`JobReport::pruned`]).
    pub pruned: u64,
    /// Speculative launches performed on this tenant's jobs' behalf
    /// (sum of per-job [`JobReport::speculative`]) — informational;
    /// the launches themselves are billed to [`SPECULATIVE_TENANT`].
    pub speculative: u64,
    /// This tenant's scoped cache counters (hits/misses/inserts/metric
    /// rows; global-only fields zero). Tenant scopes sum exactly to the
    /// service's global [`ServiceReport::cache`] on those fields.
    pub cache: CacheStats,
    /// Bytes of cached state served to this tenant (shared `Arc`
    /// payloads made available, not copies).
    pub bytes_served: u64,
    /// The tenant's memory-tier byte quota (0 = unlimited). Its
    /// current footprint and eviction count are in
    /// [`TenantReport::cache`] (`resident_bytes` / `evictions`).
    pub quota_bytes: u64,
    pub queue_wait: Duration,
    pub exec_wall: Duration,
}

/// Everything a drained service knows.
#[derive(Clone, Debug)]
pub struct ServiceReport {
    /// Per-job outcomes, submission order.
    pub jobs: Vec<JobReport>,
    /// Per-tenant aggregates, sorted by tenant name.
    pub tenants: Vec<TenantReport>,
    /// The shared cache's global counters at drain time.
    pub cache: CacheStats,
    /// Backend launches spent building memoized study inputs (reference
    /// chains) — shared across tenants, so accounted globally.
    pub input_launches: u64,
    /// Backend launches spent on speculative execution over the service
    /// lifetime (the authoritative global count; per-job `speculative`
    /// fields are point-in-time lower bounds). Speculation only warms
    /// the cache, so these launches are accounted globally — like input
    /// building — rather than charged to any tenant.
    pub speculative_launches: u64,
    /// What the boot-time disk warm start admitted (zeros when off).
    pub warm: WarmStartReport,
    /// Per-tier diagnostic counters at drain time, top of the stack
    /// first (memory, then every attached lower tier). The remote
    /// tier's row carries the circuit-breaker transitions and the
    /// replica-served count.
    pub tiers: Vec<(String, TierStats)>,
    /// Service lifetime, start to drain.
    pub wall: Duration,
}

impl ServiceReport {
    /// Total backend launches the whole service paid: every tenant's
    /// study launches plus the shared input building plus speculative
    /// pre-execution. THE multi-tenant acceptance metric — N warm
    /// tenants must keep this near one cold tenant's count.
    pub fn total_launches(&self) -> u64 {
        self.input_launches
            + self.speculative_launches
            + self.jobs.iter().map(|j| j.launches).sum::<u64>()
    }

    /// Sum of every tenant's scoped counters — equals [`Self::cache`] on
    /// the scoped fields (hits, disk hits, remote hits, misses, inserts,
    /// metric hits/misses) when all traffic ran under tenant scopes.
    /// Holds on every node of a cluster too: serving a peer is
    /// stat-invisible on the owner, and the requesting node bills the
    /// remote hit to the tenant that asked.
    pub fn scoped_totals(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for t in &self.tenants {
            total.hits += t.cache.hits;
            total.disk_hits += t.cache.disk_hits;
            total.remote_hits += t.cache.remote_hits;
            total.misses += t.cache.misses;
            total.inserts += t.cache.inserts;
            total.metric_hits += t.cache.metric_hits;
            total.metric_misses += t.cache.metric_misses;
        }
        total
    }

    pub fn tenant(&self, name: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.tenant == name)
    }
}

struct Queued {
    id: u64,
    tenant: String,
    payload: JobPayload,
    submitted: Instant,
    /// Telemetry handles allocated at admission (`None` with telemetry
    /// off or span-silent).
    trace: Option<JobTrace>,
}

/// What a traced job carries from admission to its final report: the
/// context the root `job` span is emitted under (for routed jobs, the
/// front door's `route` span of the same trace), the child context every
/// per-job span parents under, and the root span id itself.
struct JobTrace {
    root_ctx: SpanCtx,
    ctx: SpanCtx,
    root: u64,
}

/// A speculative unit: the tuner's *predicted* next generation, queued
/// for idle workers to pre-execute through the normal single-flight
/// cache path. Wrong guesses cost only their launches — results are
/// only ever written into the shared cache, never into any job report.
struct SpecJob {
    /// The tune job the prediction came from (per-job `speculative`
    /// accounting).
    job: u64,
    /// The *pinned* study config the tune loop executes with — using it
    /// verbatim guarantees speculative cache keys match the real ones.
    cfg: StudyConfig,
    /// The predicted candidate parameter sets.
    sets: Vec<ParamSet>,
}

#[derive(Default)]
struct ServiceState {
    queue: VecDeque<Queued>,
    /// Speculative work, strictly lower priority than `queue`: a worker
    /// only picks speculation up when no real job is eligible, and
    /// draining discards the whole backlog.
    spec: VecDeque<SpecJob>,
    inflight: HashMap<String, usize>,
    draining: bool,
    results: Vec<JobReport>,
    next_id: u64,
    /// Stride-scheduler pass value per tenant (persists across its
    /// jobs): the tenant with the smallest pass is served next, and
    /// serving advances its pass by `STRIDE / weight`.
    pass: HashMap<String, u64>,
    /// Pass value of the most recently served tenant — where a tenant
    /// that was idle (or is new) starts, so returning tenants cannot
    /// monopolize the pool by replaying banked virtual time.
    virtual_time: u64,
}

/// Numerator of the stride-scheduler increment: a pop advances the
/// popped tenant's pass by `STRIDE / weight`, so over any busy window
/// tenants are served proportionally to their weights. One pop always
/// advances the pass (weights are clamped ≥ 1), which is what makes the
/// scheduler starvation-free: a waiting tenant's pass is fixed while
/// every competitor's grows past it.
const STRIDE: u64 = 1 << 16;

/// Weighted-fair pop: among tenants that have queued work and a free
/// in-flight slot, pick the one with the smallest pass (ties: earliest
/// submission) and dequeue its oldest job — FIFO *within* a tenant,
/// stride-scheduled *across* tenants. Increments the winner's in-flight
/// count. `None` when nothing is eligible (empty queue or every queued
/// tenant at its cap).
fn pop_next(st: &mut ServiceState, opts: &ServeOptions) -> Option<Queued> {
    let cap = opts.tenant_inflight_cap.max(1);
    let mut seen: HashSet<&str> = HashSet::new();
    let mut best: Option<(u64, usize)> = None;
    for (pos, q) in st.queue.iter().enumerate() {
        let tenant = q.tenant.as_str();
        if !seen.insert(tenant) {
            continue; // only a tenant's oldest job is a candidate
        }
        if st.inflight.get(tenant).copied().unwrap_or(0) >= cap {
            continue;
        }
        let pass = st.pass.get(tenant).copied().unwrap_or(st.virtual_time);
        if best.is_none_or(|(b, _)| pass < b) {
            best = Some((pass, pos));
        }
    }
    let (pass, pos) = best?;
    let q = st.queue.remove(pos).expect("candidate position is in the queue");
    let tenant = q.tenant.clone();
    st.virtual_time = st.virtual_time.max(pass);
    st.pass.insert(tenant.clone(), pass + STRIDE / opts.weight_of(&tenant));
    *st.inflight.entry(tenant).or_insert(0) += 1;
    Some(q)
}

struct Inner {
    opts: ServeOptions,
    cache: Arc<ReuseCache>,
    state: Mutex<ServiceState>,
    cv: Condvar,
    /// One counter scope per tenant, service-lifetime.
    scopes: Mutex<HashMap<String, Arc<ScopedCounters>>>,
    /// Memoized per-workload study inputs (tiles + reference masks),
    /// keyed by the input-determining config fields.
    inputs: Mutex<HashMap<String, Arc<StudyInputs>>>,
    /// The process-lifetime leader engine (input building).
    leader: Mutex<PjrtEngine>,
    input_launches: AtomicU64,
    /// Backend launches spent on speculative pre-execution (global,
    /// authoritative — mirrors `input_launches`' treatment of shared
    /// work).
    speculative_launches: AtomicU64,
    /// Speculative launches completed per originating tune job, for the
    /// per-job `speculative` report field (a lower bound at report
    /// time).
    spec_launches: Mutex<HashMap<u64, u64>>,
    /// What the boot-time warm start admitted.
    warm: WarmStartReport,
    /// The cluster fabric tier, kept beyond [`ReuseCache::attach_tier`]
    /// so the service can reach the ring for routing, replication, and
    /// live membership. `None` outside cluster mode.
    remote: Option<Arc<RemoteTier>>,
    /// The process-wide telemetry handle (`trace=` / `stats=`; inactive
    /// by default — one never-taken branch per instrumented site).
    obs: Obs,
}

/// The long-lived multi-tenant study service (see the module docs).
pub struct StudyService {
    inner: Arc<Inner>,
    /// Behind a mutex so [`StudyService::drain`] can join through a
    /// shared reference (the wire server drains via an `Arc`).
    threads: Mutex<Vec<JoinHandle<()>>>,
    /// Serializes and memoizes the drain: the first caller performs it,
    /// concurrent callers block on this lock and receive the same
    /// report (remote clients may all send `drain`).
    drained: Mutex<Option<ServiceReport>>,
    started: Instant,
}

impl StudyService {
    /// Build the shared cache, warm-start it from the disk tier (when
    /// configured), load + compile the leader engine, and start the
    /// worker pool.
    pub fn start(opts: ServeOptions) -> Result<StudyService> {
        let leader = PjrtEngine::load(&opts.artifacts_dir)?;
        // one fault hook reaches every injectable site: the disk tier
        // (via the cache config), the remote tier, and — through
        // `execute_job` — the per-study worker engines
        let mut cache_cfg = opts.cache.clone();
        cache_cfg.faults = opts.faults.clone();
        let cache = Arc::new(ReuseCache::new(cache_cfg));
        let warm = if opts.warm_start { cache.warm_start() } else { WarmStartReport::default() };
        // either telemetry flag activates the registry; `trace=` adds
        // the file sink. The node label makes multi-node trace files
        // stitchable (every span event carries it).
        let node = opts.cluster_addr.clone().unwrap_or_else(|| "local".to_string());
        let obs = match &opts.trace {
            Some(path) => Obs::to_file(&node, path)?,
            None if opts.stats => Obs::active(&node),
            None => Obs::none(),
        };
        let remote = if opts.peers.is_empty() {
            None
        } else {
            let addr = opts.cluster_addr.as_deref().ok_or_else(|| {
                Error::Config("cluster mode (peers=) needs this node's listen=ADDR".into())
            })?;
            let tier = Arc::new(
                RemoteTier::new(&opts.peers, addr)?
                    .with_faults(opts.faults.clone())
                    .with_replicas(opts.replicas)
                    .with_obs(obs.clone()),
            );
            cache.attach_tier(Arc::clone(&tier));
            Some(tier)
        };
        let workers = opts.service_workers.max(1);
        let inner = Arc::new(Inner {
            opts,
            cache,
            state: Mutex::new(ServiceState::default()),
            cv: Condvar::new(),
            scopes: Mutex::new(HashMap::new()),
            inputs: Mutex::new(HashMap::new()),
            leader: Mutex::new(leader),
            input_launches: AtomicU64::new(0),
            speculative_launches: AtomicU64::new(0),
            spec_launches: Mutex::new(HashMap::new()),
            warm,
            remote,
            obs,
        });
        let mut threads: Vec<JoinHandle<()>> = (0..workers)
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || worker_loop(inner))
            })
            .collect();
        // the `stats=on` digest rides the same join path as the workers,
        // so drain never leaves it printing into a dead service
        if inner.opts.stats {
            if let Some(o) = inner.obs.get().cloned() {
                let inner = Arc::clone(&inner);
                threads.push(std::thread::spawn(move || digest_loop(inner, o)));
            }
        }
        Ok(StudyService {
            inner,
            threads: Mutex::new(threads),
            drained: Mutex::new(None),
            started: Instant::now(),
        })
    }

    /// The shared cache (diagnostics; the service owns its lifetime).
    pub fn cache(&self) -> &Arc<ReuseCache> {
        &self.inner.cache
    }

    /// The per-connection submit window the wire server enforces.
    pub fn submit_window(&self) -> usize {
        self.inner.opts.submit_window.max(1)
    }

    /// The service's fault-injection hook (the wire server consults it
    /// for outbound frame corruption).
    pub fn faults(&self) -> &Faults {
        &self.inner.opts.faults
    }

    /// What the boot-time warm start scanned and admitted (zeros when
    /// warm start was off or no disk tier is configured).
    pub fn warm_start_report(&self) -> WarmStartReport {
        self.inner.warm
    }

    /// The cluster fabric tier (`None` outside cluster mode). Tests and
    /// the wire server reach the ring, the replication hooks, and the
    /// breaker counters through this.
    pub fn remote_tier(&self) -> Option<&Arc<RemoteTier>> {
        self.inner.remote.as_ref()
    }

    /// This node's cluster address (`None` outside cluster mode) — the
    /// `node=` field of a `routed` reply.
    pub fn cluster_addr(&self) -> Option<String> {
        self.inner.remote.as_ref().map(|r| r.self_addr().to_string())
    }

    /// Is front-door routing live on this node? Requires both the
    /// `route=on` flag and cluster mode.
    pub fn route_enabled(&self) -> bool {
        self.inner.opts.route && self.inner.remote.is_some()
    }

    /// Apply a live membership join: grow the `PeerRing` without a
    /// restart. With `relay` (the change arrived from an admin line,
    /// peers=0 on the wire) the join is forwarded best-effort to every
    /// other member of the *new* ring. Owned-key handoff runs as a
    /// low-priority background drain. Returns the new ring size.
    pub fn peer_join(&self, addr: &str, relay: bool) -> Result<u64> {
        let remote = self.remote_or_err()?;
        let size = remote.add_peer(addr)? as u64;
        if relay {
            let msg = Message::PeerJoin { addr: addr.to_string(), peers: size };
            self.relay_membership(remote.ring().peers().to_vec(), &msg);
        }
        self.spawn_handoff();
        Ok(size)
    }

    /// Apply a live membership leave. Relays (admin-originated changes
    /// only) go over the *old* ring snapshot so the departing node
    /// hears it too and collapses its own ring to single-node. Returns
    /// the new ring size.
    pub fn peer_leave(&self, addr: &str, relay: bool) -> Result<u64> {
        let remote = self.remote_or_err()?;
        let old_peers = remote.ring().peers().to_vec();
        let size = remote.remove_peer(addr) as u64;
        if relay {
            let msg = Message::PeerLeave { addr: addr.to_string(), peers: size };
            self.relay_membership(old_peers, &msg);
        }
        self.spawn_handoff();
        Ok(size)
    }

    fn remote_or_err(&self) -> Result<&Arc<RemoteTier>> {
        self.inner.remote.as_ref().ok_or_else(|| {
            Error::Coordinator("membership change on a non-cluster node (no peers=)".into())
        })
    }

    /// Best-effort fan-out of a membership message to every listed peer
    /// except this node. Failures are ignored: an unreachable peer has
    /// either departed already or will learn the ring from the next
    /// change that reaches it.
    fn relay_membership(&self, peers: Vec<String>, msg: &Message) {
        let Some(remote) = &self.inner.remote else { return };
        for peer in &peers {
            if peer != remote.self_addr() {
                let _ = remote.control(peer, msg);
            }
        }
    }

    /// After a membership change, trickle every resident key whose
    /// rendezvous owner is now another node over to that owner — a
    /// detached background drain, throttled to one key per millisecond
    /// so it never competes with live jobs for the wire or the cache.
    /// Idempotent and crash-safe: a key that never arrives just misses
    /// on the new owner and is recomputed there.
    fn spawn_handoff(&self) {
        let Some(remote) = self.inner.remote.clone() else { return };
        let cache = Arc::clone(&self.inner.cache);
        std::thread::spawn(move || {
            for key in cache.resident_keys() {
                let Some(owner) = remote.owner_addr(key) else { continue };
                let Some(state) = cache.peek_state(key) else { continue };
                let _ = remote.publish_to(&owner, key, &state);
                std::thread::sleep(Duration::from_millis(1));
            }
        });
    }

    /// Replication hook, called by the wire server after this node
    /// serves a peer a `found` cache state: the serve that crosses the
    /// hot watermark pushes the key's state to its ring replica, so the
    /// key outlives this node going dark. Best-effort — a failed push
    /// costs nothing but the missed replica.
    pub fn note_remote_served(&self, key: Key) {
        let Some(remote) = &self.inner.remote else { return };
        if !remote.note_served(key) {
            return;
        }
        let Some(replica) = remote.replica_addr(key) else { return };
        let Some(state) = self.inner.cache.peek_state(key) else { return };
        let _ = remote.publish_to(&replica, key, &state);
    }

    /// Predict which peer owns the largest share of a study's chain
    /// keys — the front door's routing decision. Mirrors the planner's
    /// cache probe ([`crate::merging::count_cached`]) without touching
    /// the cache or launching anything: prepare the study, enumerate
    /// every unit's reuse-tree chain keys, and score each task node's
    /// key against the ring. Returns `Some(addr)` only when another
    /// node wins; `None` (execute here) on single-node rings, ties won
    /// by self, or studies whose keys are mostly local.
    pub fn predict_route(&self, cfg: &StudyConfig) -> Option<String> {
        let remote = self.inner.remote.as_ref()?;
        let ring = remote.ring();
        if ring.peers().len() < 2 {
            return None;
        }
        // pin the env-dependent fields exactly as `execute_job` will,
        // so predicted keys match the keys execution computes
        let mut cfg = cfg.clone();
        cfg.engine = EngineMode::Pjrt;
        cfg.artifacts_dir = self.inner.opts.artifacts_dir.clone();
        cfg.workers = self.inner.opts.study_workers;
        cfg.batch_width = self.inner.opts.batch_width;
        let (h, w, art_fp, compare_task) = {
            let leader = self.inner.leader.lock().unwrap();
            let (h, w) = leader.tile_shape();
            let m = leader.manifest();
            (h, w, m.fingerprint(), m.compare_task.clone())
        };
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        let tiles = make_tiles(&cfg, h, w);
        let mut tile_fps = tile_fingerprints(&tiles);
        for fp in tile_fps.values_mut() {
            // the artifact fold `keyed_tile_fps` applies to real keys
            *fp = fold_keys(Key::from(art_fp), *fp);
        }
        let step = self.inner.cache.quantize_step();
        let graph = &prepared.graph;
        let instances = &prepared.instances;
        let mut tally: HashMap<usize, u64> = HashMap::new();
        for unit in &plan.units {
            let rep = &instances[graph.nodes[unit.nodes[0]].rep];
            // comparison keys fold reference-mask fingerprints we can't
            // compute without launches; routing scores the rest
            if rep.tasks.len() == 1 && rep.tasks[0].name == compare_task {
                continue;
            }
            let tile_fp = tile_fps.get(&rep.tile).copied().unwrap_or(Key::from(0u64));
            let base = node_input_key(graph, instances, unit.nodes[0], tile_fp, step);
            let stages = unit_stages(unit, graph, instances);
            let tree = ReuseTree::build(&stages);
            let levels = tree.walk();
            let keys = tree.chain_keys(&levels, base, |level, member| {
                task_cache_sig(&instances[graph.nodes[unit.nodes[member]].rep].tasks[level - 1], step)
            });
            for node in levels.iter().flatten().filter(|n| n.stage.is_none()) {
                *tally.entry(ring.owner_of(keys[node.node])).or_insert(0) += 1;
            }
        }
        let (&winner, _) =
            tally.iter().max_by_key(|&(&idx, &count)| (count, std::cmp::Reverse(idx)))?;
        let addr = ring.addr(winner);
        (addr != remote.self_addr()).then(|| addr.to_string())
    }

    /// Enqueue a study job. Returns its id, or an error once draining
    /// started.
    pub fn submit(&self, job: StudyJob) -> Result<u64> {
        self.submit_payload(job.tenant, JobPayload::Study(job.cfg), None)
    }

    /// [`StudyService::submit`] joining an existing trace: the job's
    /// root `job` span parents under `parent` — for routed jobs, the
    /// front door's `route` span carried on the wire — so a routed
    /// job's spans stitch into one cross-node tree. Ignored with
    /// telemetry off.
    pub fn submit_with_trace(&self, job: StudyJob, parent: Option<WireTrace>) -> Result<u64> {
        self.submit_payload(job.tenant, JobPayload::Study(job.cfg), parent)
    }

    /// Enqueue a tuning job ([`crate::tune`]): an optimizer loop whose
    /// candidate studies all ride the service's shared cache under the
    /// tenant's account. Same admission, caps and billing as studies.
    pub fn submit_tune(
        &self,
        tenant: impl Into<String>,
        cfg: StudyConfig,
        opts: TuneOptions,
    ) -> Result<u64> {
        self.submit_payload(tenant.into(), JobPayload::Tune(cfg, opts), None)
    }

    fn submit_payload(
        &self,
        tenant: String,
        payload: JobPayload,
        parent: Option<WireTrace>,
    ) -> Result<u64> {
        let mut st = self.inner.state.lock().unwrap();
        if st.draining {
            return Err(Error::Coordinator(format!(
                "service is draining; job for tenant `{tenant}` rejected"
            )));
        }
        let id = st.next_id;
        st.next_id += 1;
        // allocate the job's trace — or join the front door's — and
        // emit the admit span before the job can race to completion
        let trace = self.inner.obs.get().map(|o| {
            let root_ctx = SpanCtx {
                trace: parent.map(|w| w.trace).unwrap_or_else(|| o.new_trace()),
                parent: parent.map(|w| w.span).unwrap_or(0),
                tenant: Arc::from(tenant.as_str()),
                job: id,
            };
            let root = o.next_span();
            let ctx = root_ctx.child(root);
            let admit = o.next_span();
            o.emit_timed(&ctx, span::ADMIT, admit, Instant::now(), Duration::ZERO, String::new());
            o.add(CounterId::JobsAdmitted, Some(&tenant), 1);
            JobTrace { root_ctx, ctx, root }
        });
        // a tenant going from idle to busy starts at the current
        // virtual time: waiting earns priority, idling does not
        let busy = st.inflight.get(&tenant).copied().unwrap_or(0) > 0
            || st.queue.iter().any(|q| q.tenant == tenant);
        if !busy {
            let vt = st.virtual_time;
            let pass = st.pass.entry(tenant.clone()).or_insert(vt);
            *pass = (*pass).max(vt);
        }
        st.queue.push_back(Queued { id, tenant, payload, submitted: Instant::now(), trace });
        self.inner.cv.notify_all();
        Ok(id)
    }

    /// The service's telemetry handle (inactive unless `trace=` or
    /// `stats=` was configured). The wire server parents its
    /// `serve-get`/`serve-put`/`route` spans through this.
    pub fn obs(&self) -> &Obs {
        &self.inner.obs
    }

    /// Per-tier diagnostic counters of the shared cache, top of the
    /// stack first (memory, then every attached lower tier). The drain
    /// bill and the `status-report` / `stats-report` wire messages carry
    /// exactly these rows.
    pub fn tier_stats(&self) -> Vec<(String, TierStats)> {
        self.inner.cache.tier_stats().into_iter().map(|(n, s)| (n.to_string(), s)).collect()
    }

    /// Point-in-time telemetry snapshot — the `stats` wire message's
    /// reply. Cheap enough to serve on every request: counters are
    /// relaxed atomic loads, histograms a few hundred of them.
    pub fn stats_snapshot(&self) -> WireStats {
        let (queued, running, done) = {
            let st = self.inner.state.lock().unwrap();
            (
                st.queue.len() as u64,
                st.inflight.values().sum::<usize>() as u64,
                st.results.len() as u64,
            )
        };
        WireStats {
            enabled: self.inner.obs.is_active(),
            snapshot: self.inner.obs.get().map(|o| o.snapshot()).unwrap_or_default(),
            tiers: self
                .tier_stats()
                .into_iter()
                .map(|(tier, stats)| WireTierStats { tier, stats })
                .collect(),
            queued,
            running,
            done,
        }
    }

    /// Jobs currently queued (not yet picked up by a worker).
    pub fn queued(&self) -> usize {
        self.inner.state.lock().unwrap().queue.len()
    }

    /// Jobs currently executing on service workers.
    pub fn in_flight(&self) -> usize {
        self.inner.state.lock().unwrap().inflight.values().sum()
    }

    /// Jobs that have finished (successfully or not).
    pub fn completed(&self) -> usize {
        self.inner.state.lock().unwrap().results.len()
    }

    /// Speculative units queued but not yet picked up by an idle worker
    /// (diagnostics; tests poll this to observe speculation draining).
    pub fn speculative_pending(&self) -> usize {
        self.inner.state.lock().unwrap().spec.len()
    }

    /// Backend launches spent on speculative pre-execution so far.
    pub fn speculative_launches(&self) -> u64 {
        self.inner.speculative_launches.load(Ordering::Relaxed)
    }

    /// Block until job `id` finishes and return its report; `None` when
    /// the service never issued `id`. The wire server's `result`
    /// message is served by this.
    pub fn wait_job(&self, id: u64) -> Option<JobReport> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if id >= st.next_id {
                return None;
            }
            if let Some(j) = st.results.iter().find(|j| j.job == id) {
                return Some(j.clone());
            }
            st = self.inner.cv.wait(st).unwrap();
        }
    }

    /// Graceful drain: stop admitting, let every queued/in-flight study
    /// finish, join the workers, and report. Takes `&self` so a shared
    /// handle (e.g. the wire server's `Arc`) can drain. Safe to call
    /// more than once: the first caller performs the drain, concurrent
    /// and later callers block until it completes and receive the same
    /// report.
    pub fn drain(&self) -> ServiceReport {
        let mut drained = self.drained.lock().unwrap();
        if let Some(report) = &*drained {
            return report.clone();
        }
        let drain_started = Instant::now();
        {
            let mut st = self.inner.state.lock().unwrap();
            st.draining = true;
            self.inner.cv.notify_all();
        }
        let handles: Vec<JoinHandle<()>> = self.threads.lock().unwrap().drain(..).collect();
        join_workers(handles, self.inner.opts.drain_deadline);
        let mut jobs = {
            let st = self.inner.state.lock().unwrap();
            st.results.clone()
        };
        jobs.sort_by_key(|j| j.job);

        let scopes = self.inner.scopes.lock().unwrap();
        let mut tenants: Vec<TenantReport> = scopes
            .iter()
            .map(|(name, scope)| {
                let mine: Vec<&JobReport> = jobs.iter().filter(|j| &j.tenant == name).collect();
                TenantReport {
                    tenant: name.clone(),
                    jobs: mine.len() as u64,
                    failed: mine.iter().filter(|j| !j.ok()).count() as u64,
                    launches: mine.iter().map(|j| j.launches).sum(),
                    cached_tasks: mine.iter().map(|j| j.cached_tasks).sum(),
                    retries: mine.iter().map(|j| j.retries).sum(),
                    pruned: mine.iter().map(|j| j.pruned).sum(),
                    speculative: mine.iter().map(|j| j.speculative).sum(),
                    cache: scope.stats(),
                    bytes_served: scope.state_bytes_served(),
                    quota_bytes: scope.quota_bytes(),
                    queue_wait: mine.iter().map(|j| j.queue_wait).sum(),
                    exec_wall: mine.iter().map(|j| j.exec_wall).sum(),
                }
            })
            .collect();
        tenants.sort_by(|a, b| a.tenant.cmp(&b.tenant));

        let report = ServiceReport {
            jobs,
            tenants,
            cache: self.inner.cache.stats(),
            input_launches: self.inner.input_launches.load(Ordering::Relaxed),
            speculative_launches: self.inner.speculative_launches.load(Ordering::Relaxed),
            warm: self.inner.warm,
            tiers: self.tier_stats(),
            wall: self.started.elapsed(),
        };
        // the drain is service-level work, not any job's: it roots its
        // own one-span trace, then the sink is flushed so a reader that
        // opens the file after drain sees every span
        if let Some(o) = self.inner.obs.get() {
            let ctx = SpanCtx {
                trace: o.new_trace(),
                parent: 0,
                tenant: Arc::from("~service"),
                job: 0,
            };
            let id = o.next_span();
            o.emit_timed(
                &ctx,
                span::DRAIN,
                id,
                drain_started,
                drain_started.elapsed(),
                format!("jobs={}", report.jobs.len()),
            );
            o.flush();
        }
        *drained = Some(report.clone());
        report
    }
}

impl Drop for StudyService {
    /// A service dropped without [`StudyService::drain`] still stops
    /// accepting work and joins its pool, so worker threads never
    /// outlive the handle.
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.draining = true;
            self.inner.cv.notify_all();
        }
        let handles: Vec<JoinHandle<()>> = self.threads.lock().unwrap().drain(..).collect();
        join_workers(handles, self.inner.opts.drain_deadline);
    }
}

/// Join the worker pool with bounded patience: a thread still running
/// when the deadline passes is abandoned (its `JoinHandle` dropped, the
/// thread detached), so one wedged study can never block shutdown.
/// `None` waits forever.
fn join_workers(handles: Vec<JoinHandle<()>>, patience: Option<Duration>) {
    let deadline = patience.map(|p| Instant::now() + p);
    for t in handles {
        match deadline {
            None => {
                let _ = t.join();
            }
            Some(dl) => {
                while !t.is_finished() && Instant::now() < dl {
                    std::thread::sleep(Duration::from_millis(20));
                }
                if t.is_finished() {
                    let _ = t.join();
                }
            }
        }
    }
}

/// What a worker picked up: a real tenant job, or (only when no real
/// job was eligible) a speculative pre-execution unit.
enum Work {
    Real(Queued),
    Spec(SpecJob),
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let work = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if let Some(q) = pop_next(&mut st, &inner.opts) {
                    break Work::Real(q);
                }
                if st.draining {
                    // drain discards speculation outright: it is by
                    // definition work nobody asked for, and executing
                    // it would delay (never wedge, but delay) shutdown
                    st.spec.clear();
                    if st.queue.is_empty() {
                        return;
                    }
                } else if let Some(s) = st.spec.pop_front() {
                    break Work::Spec(s);
                }
                st = inner.cv.wait(st).unwrap();
            }
        };
        match work {
            Work::Real(queued) => {
                let tenant = queued.tenant.clone();
                let report = inner.run_job(queued);
                let mut st = inner.state.lock().unwrap();
                st.results.push(report);
                if let Some(n) = st.inflight.get_mut(&tenant) {
                    *n = n.saturating_sub(1);
                }
                inner.cv.notify_all();
            }
            Work::Spec(spec) => {
                inner.execute_speculative(spec);
                inner.cv.notify_all();
            }
        }
    }
}

impl Inner {
    /// The tenant's service-lifetime counter scope, created on first
    /// touch with the tenant's quota (override, else the default).
    fn scope_of(&self, tenant: &str) -> Arc<ScopedCounters> {
        let mut scopes = self.scopes.lock().unwrap();
        if let Some(scope) = scopes.get(tenant) {
            return Arc::clone(scope);
        }
        let scope = Arc::new(ScopedCounters::with_quota(self.opts.quota_of(tenant)));
        scopes.insert(tenant.to_string(), Arc::clone(&scope));
        scope
    }

    /// Memoized study inputs: built once per distinct workload on the
    /// leader engine. The map lock is held only for get/insert, so jobs
    /// whose inputs are already built never wait behind someone else's
    /// build; same-key racers dedup on the leader lock (the build is
    /// re-checked after acquiring it), which serializes *builds* anyway —
    /// there is exactly one leader engine.
    fn inputs_for(&self, cfg: &StudyConfig, prepared: &PreparedStudy) -> Result<Arc<StudyInputs>> {
        let key = format!("{}|{}|{:?}", cfg.seed, cfg.tiles, cfg.workflow_file);
        if let Some(inputs) = self.inputs.lock().unwrap().get(&key) {
            return Ok(Arc::clone(inputs));
        }
        let mut leader = self.leader.lock().unwrap();
        // a same-key racer may have built while we waited for the engine
        if let Some(inputs) = self.inputs.lock().unwrap().get(&key) {
            return Ok(Arc::clone(inputs));
        }
        let before = leader.timer().launches();
        let inputs = make_inputs_with_engine(cfg, prepared, &mut leader)?;
        let built = leader.timer().launches() - before;
        let inputs = Arc::new(inputs);
        // publish under the leader lock: a same-key racer's re-check
        // above cannot miss it and rebuild
        self.inputs.lock().unwrap().insert(key, Arc::clone(&inputs));
        drop(leader);
        self.input_launches.fetch_add(built, Ordering::Relaxed);
        Ok(inputs)
    }

    fn run_job(&self, queued: Queued) -> JobReport {
        let Queued { id, tenant, payload, submitted, trace } = queued;
        let queue_wait = submitted.elapsed();
        if let Some(o) = self.obs.get() {
            o.observe(HistId::QueueWait, Some(&tenant), queue_wait);
            if let Some(t) = &trace {
                let span_id = o.next_span();
                o.emit_timed(&t.ctx, span::QUEUE, span_id, submitted, queue_wait, String::new());
            }
        }
        let mut report = JobReport {
            job: id,
            tenant: tenant.clone(),
            error: None,
            n_evals: 0,
            launches: 0,
            cached_tasks: 0,
            y: Vec::new(),
            tune: None,
            retries: 0,
            pruned: 0,
            speculative: 0,
            queue_wait,
            exec_wall: Duration::ZERO,
        };
        let max_attempts = u64::from(self.opts.job_retries) + 1;
        let deadline = self.opts.job_deadline.map(|d| Instant::now() + d);
        let mut attempt = 0u64;
        loop {
            attempt += 1;
            let attempt_started = Instant::now();
            // a panicking study must not take the worker (and the
            // tenant's in-flight slot) down with it
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                self.execute_job(id, &tenant, &payload, trace.as_ref().map(|t| &t.ctx))
            }));
            // one schedule span per execution attempt (the backoff
            // between attempts is the retry span's)
            if let (Some(o), Some(t)) = (self.obs.get(), trace.as_ref()) {
                let span_id = o.next_span();
                o.emit_timed(
                    &t.ctx,
                    span::SCHEDULE,
                    span_id,
                    attempt_started,
                    attempt_started.elapsed(),
                    format!("attempt {attempt}"),
                );
            }
            let error = match outcome {
                Ok(Ok(out)) => {
                    report.n_evals = out.n_evals;
                    report.launches = out.launches;
                    report.cached_tasks = out.cached_tasks;
                    report.y = out.y;
                    report.tune = out.tune;
                    report.pruned = out.pruned;
                    report.speculative =
                        self.spec_launches.lock().unwrap().get(&id).copied().unwrap_or(0);
                    report.exec_wall = out.exec_wall;
                    report.error = None;
                    self.finish_job(trace.as_ref(), &report, submitted);
                    return report;
                }
                Ok(Err(e)) => e.to_string(),
                Err(panic) => {
                    let msg = panic
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "study panicked".into());
                    format!("panic: {msg}")
                }
            };
            report.error = Some(error);
            let budget_spent = attempt >= max_attempts;
            let past_deadline = deadline.is_some_and(|dl| Instant::now() >= dl);
            if budget_spent || past_deadline {
                self.finish_job(trace.as_ref(), &report, submitted);
                return report;
            }
            report.retries += 1;
            let backoff = retry_backoff(id, attempt);
            let backoff_started = Instant::now();
            std::thread::sleep(backoff);
            if let Some(o) = self.obs.get() {
                o.add(CounterId::Retries, Some(&tenant), 1);
                o.observe(HistId::RetryBackoff, Some(&tenant), backoff);
                if let Some(t) = &trace {
                    let span_id = o.next_span();
                    o.emit_timed(
                        &t.ctx,
                        span::RETRY,
                        span_id,
                        backoff_started,
                        backoff_started.elapsed(),
                        format!("attempt {attempt} failed; backing off"),
                    );
                }
            }
        }
    }

    /// Completion-side telemetry for one job, success or final failure:
    /// the completed/failed + launch/cached counters, the job-wall
    /// histogram sample, and the root `job` span closing the trace tree.
    fn finish_job(&self, trace: Option<&JobTrace>, report: &JobReport, submitted: Instant) {
        let Some(o) = self.obs.get() else { return };
        let tenant = Some(report.tenant.as_str());
        let done = if report.ok() { CounterId::JobsCompleted } else { CounterId::JobsFailed };
        o.add(done, tenant, 1);
        o.add(CounterId::Launches, tenant, report.launches);
        o.add(CounterId::CachedTasks, tenant, report.cached_tasks);
        let wall = submitted.elapsed();
        o.observe(HistId::JobWall, tenant, wall);
        if let Some(t) = trace {
            let detail = match &report.error {
                Some(e) => format!("failed: {e}"),
                None => format!("ok launches={} cached={}", report.launches, report.cached_tasks),
            };
            o.emit_timed(&t.root_ctx, span::JOB, t.root, submitted, wall, detail);
        }
    }

    fn execute_job(
        &self,
        id: u64,
        tenant: &str,
        payload: &JobPayload,
        trace: Option<&SpanCtx>,
    ) -> Result<ExecOut> {
        // pin the execution environment to the service's
        let base = match payload {
            JobPayload::Study(cfg) => cfg,
            JobPayload::Tune(cfg, _) => cfg,
        };
        let mut cfg = base.clone();
        cfg.engine = EngineMode::Pjrt;
        cfg.artifacts_dir = self.opts.artifacts_dir.clone();
        cfg.workers = self.opts.study_workers;
        cfg.batch_width = self.opts.batch_width;
        cfg.faults = self.opts.faults.clone();
        cfg.obs = self.obs.clone();
        cfg.trace = trace.cloned();

        match payload {
            JobPayload::Study(_) if cfg.adaptive.enabled => {
                // adaptive path: the incremental estimator decides unit
                // by unit; pruned slots are billed, not silently dropped
                let prepared = prepare(&cfg);
                let inputs = self.inputs_for(&cfg, &prepared)?;
                let scope = self.scope_of(tenant);
                let out = run_adaptive_scoped(
                    &cfg,
                    Some(Arc::clone(&self.cache)),
                    Some(scope),
                    &inputs,
                )?;
                Ok(ExecOut {
                    n_evals: prepared.n_evals(),
                    launches: out.launches,
                    cached_tasks: out.cached_tasks,
                    y: out.y,
                    tune: None,
                    pruned: out.pruned,
                    exec_wall: out.wall,
                })
            }
            JobPayload::Study(_) => {
                let prepared = prepare(&cfg);
                let mut plan = prepared.plan(&cfg);
                let inputs = self.inputs_for(&cfg, &prepared)?;
                // planning-time probe: LPT orders by work that will run
                let _ = prune_plan_with_inputs(&prepared, &mut plan, &self.cache, &inputs);
                let scope = self.scope_of(tenant);
                let outcome = run_pjrt_with_inputs_scoped(
                    &cfg,
                    &prepared,
                    &plan,
                    Some(Arc::clone(&self.cache)),
                    Some(scope),
                    &inputs,
                )?;
                Ok(ExecOut {
                    n_evals: prepared.n_evals(),
                    launches: outcome.timer.launches(),
                    cached_tasks: outcome.timer.cached_served(),
                    y: outcome.y,
                    tune: None,
                    pruned: 0,
                    exec_wall: outcome.wall,
                })
            }
            JobPayload::Tune(_, topts) => {
                // the tuning loop shares the leader-built study inputs
                // with plain studies of the same workload (same memo key)
                let probe = prepare_candidates(&cfg, &[default_space().defaults()]);
                let inputs = self.inputs_for(&cfg, &probe)?;
                let scope = self.scope_of(tenant);
                let speculate = self.opts.speculate || topts.speculate;
                let hook = ServiceSpeculation { inner: self, job: id, cfg: cfg.clone() };
                let outcome = run_tune_with_hook(
                    &cfg,
                    topts,
                    Some(Arc::clone(&self.cache)),
                    Some(scope),
                    &inputs,
                    if speculate { Some(&hook) } else { None },
                )?;
                Ok(ExecOut {
                    n_evals: outcome.evaluated * cfg.tiles.max(1),
                    launches: outcome.launches,
                    cached_tasks: outcome.cached_tasks,
                    y: outcome.history.iter().map(|g| g.best_score).collect(),
                    tune: Some(outcome.summary()),
                    pruned: 0,
                    exec_wall: outcome.wall,
                })
            }
        }
    }

    /// Pre-execute one predicted generation under the speculative
    /// pseudo-tenant's scope. Errors and panics are swallowed: a failed
    /// speculation is exactly as harmless as no speculation — the only
    /// durable effect of success is a warmer cache.
    fn execute_speculative(&self, spec: SpecJob) {
        let outcome = catch_unwind(AssertUnwindSafe(|| -> Result<u64> {
            let prepared = prepare_candidates(&spec.cfg, &spec.sets);
            let mut plan = prepared.plan(&spec.cfg);
            let inputs = self.inputs_for(&spec.cfg, &prepared)?;
            let _ = prune_plan_with_inputs(&prepared, &mut plan, &self.cache, &inputs);
            let scope = self.scope_of(SPECULATIVE_TENANT);
            let out = run_pjrt_with_inputs_scoped(
                &spec.cfg,
                &prepared,
                &plan,
                Some(Arc::clone(&self.cache)),
                Some(scope),
                &inputs,
            )?;
            Ok(out.timer.launches())
        }));
        if let Ok(Ok(launches)) = outcome {
            self.speculative_launches.fetch_add(launches, Ordering::Relaxed);
            *self.spec_launches.lock().unwrap().entry(spec.job).or_insert(0) += launches;
        }
    }
}

/// The [`SpeculationHook`] a tune job threads into its optimizer loop:
/// `offer` enqueues the predicted next generation for idle workers.
/// Queued (never executed inline) so prediction never delays the real
/// generation; dropped wholesale once draining starts.
struct ServiceSpeculation<'a> {
    inner: &'a Inner,
    job: u64,
    /// The pinned study config of the tune job (same cache keys).
    cfg: StudyConfig,
}

impl SpeculationHook for ServiceSpeculation<'_> {
    fn offer(&self, candidates: &[ParamSet]) {
        if candidates.is_empty() {
            return;
        }
        let mut st = self.inner.state.lock().unwrap();
        if st.draining {
            return;
        }
        st.spec.push_back(SpecJob {
            job: self.job,
            cfg: self.cfg.clone(),
            sets: candidates.to_vec(),
        });
        self.inner.cv.notify_all();
    }
}

/// The `stats=on` digest thread: one log line whenever the global
/// counters move, checked on every service state change (and at worst
/// every 500 ms); exits as soon as draining starts. Quiet services log
/// nothing — the digest is change-driven, not a heartbeat.
fn digest_loop(inner: Arc<Inner>, o: Arc<ObsInner>) {
    let mut last: Option<MetricsSnapshot> = None;
    loop {
        {
            let st = inner.state.lock().unwrap();
            if st.draining {
                return;
            }
            let (st, _timeout) =
                inner.cv.wait_timeout(st, Duration::from_millis(500)).unwrap();
            if st.draining {
                return;
            }
        }
        let snap = o.snapshot();
        if last.as_ref() == Some(&snap.global) {
            continue;
        }
        eprintln!("[stats {}] {}", snap.node, stats_digest(&snap));
        last = Some(snap.global);
    }
}

/// One-line digest of a telemetry snapshot: the headline counters plus
/// job-wall quantiles (microsecond histograms rendered as milliseconds).
/// Shared by the server log (`stats=on`) and the CLI.
pub fn stats_digest(snap: &ObsSnapshot) -> String {
    let g = &snap.global;
    let ms = |us: u64| us as f64 / 1000.0;
    let jw = g.hist("job_wall_us");
    format!(
        "jobs={} failed={} launches={} cached={} retries={} routed={} \
         job p50={:.1}ms p95={:.1}ms ring={}/{}",
        g.counter("jobs_completed"),
        g.counter("jobs_failed"),
        g.counter("launches"),
        g.counter("cached_tasks"),
        g.counter("retries"),
        g.counter("jobs_routed"),
        ms(jw.and_then(|h| h.quantile_us(0.5)).unwrap_or(0)),
        ms(jw.and_then(|h| h.quantile_us(0.95)).unwrap_or(0)),
        snap.ring_len,
        snap.ring_cap,
    )
}

/// Backoff before retry `attempt + 1` of a job: 10 ms doubling per
/// attempt, capped at 500 ms, plus up to +50% jitter derived
/// deterministically from (job id, attempt) — concurrent retrying jobs
/// de-synchronize, and a chaos seed replays with identical timing
/// structure.
fn retry_backoff(job: u64, attempt: u64) -> Duration {
    let doubled = Duration::from_millis(10) * (1u32 << attempt.saturating_sub(1).min(6) as u32);
    let capped = doubled.min(Duration::from_millis(500));
    let h = crate::testutil::fnv1a64(&[job, attempt]);
    capped + capped * ((h % 50) as u32) / 100
}

/// What [`Inner::execute_job`] hands back to the report builder.
struct ExecOut {
    n_evals: usize,
    launches: u64,
    cached_tasks: u64,
    y: Vec<f64>,
    tune: Option<TuneSummary>,
    /// Adaptive studies: evaluations the pruner cancelled before launch.
    pruned: u64,
    exec_wall: Duration,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SaMethod;
    use crate::merging::FineAlgorithm;

    fn small_cfg() -> StudyConfig {
        StudyConfig {
            method: SaMethod::Moat { r: 1 }, // 16 evaluations
            algorithm: FineAlgorithm::Rtma(7),
            ..StudyConfig::default()
        }
    }

    fn opts(service_workers: usize) -> ServeOptions {
        ServeOptions {
            service_workers,
            tenant_inflight_cap: 1,
            study_workers: 2,
            cache: CacheConfig { capacity_bytes: 512 * 1024 * 1024, ..CacheConfig::default() },
            ..ServeOptions::default()
        }
    }

    #[test]
    fn two_tenants_share_the_cache_and_account_separately() {
        let svc = StudyService::start(opts(2)).expect("service starts");
        svc.submit(StudyJob { tenant: "alice".into(), cfg: small_cfg() }).unwrap();
        svc.submit(StudyJob { tenant: "bob".into(), cfg: small_cfg() }).unwrap();
        let report = svc.drain();

        assert_eq!(report.jobs.len(), 2);
        assert!(report.jobs.iter().all(|j| j.ok()), "jobs: {:?}", report.jobs);
        // identical studies must produce identical results
        assert_eq!(report.jobs[0].y, report.jobs[1].y);
        assert_eq!(report.tenants.len(), 2);
        // tenant scopes sum exactly to the shared cache's globals
        let sums = report.scoped_totals();
        assert_eq!(sums.hits, report.cache.hits);
        assert_eq!(sums.disk_hits, report.cache.disk_hits);
        assert_eq!(sums.misses, report.cache.misses);
        assert_eq!(sums.inserts, report.cache.inserts);
        assert_eq!(sums.metric_hits, report.cache.metric_hits);
        assert_eq!(sums.metric_misses, report.cache.metric_misses);
        // the pair shares one input build
        assert!(report.input_launches > 0);
        assert!(report.total_launches() > 0);
    }

    #[test]
    fn drain_rejects_new_submissions() {
        let svc = StudyService::start(opts(1)).expect("service starts");
        let inner = Arc::clone(&svc.inner);
        inner.state.lock().unwrap().draining = true;
        assert!(svc.submit(StudyJob { tenant: "late".into(), cfg: small_cfg() }).is_err());
        // un-drain so the Drop-join path exercises the empty queue
        inner.state.lock().unwrap().draining = false;
        drop(svc);
    }

    fn queued_job(id: u64, tenant: &str) -> Queued {
        Queued {
            id,
            tenant: tenant.into(),
            payload: JobPayload::Study(StudyConfig::default()),
            submitted: Instant::now(),
            trace: None,
        }
    }

    fn weighted_opts(weights: &[(&str, u32)], cap: usize) -> ServeOptions {
        ServeOptions {
            tenant_inflight_cap: cap,
            tenant_weights: weights.iter().map(|(t, w)| (t.to_string(), *w)).collect(),
            ..ServeOptions::default()
        }
    }

    #[test]
    fn weighted_fair_pop_serves_tenants_proportionally() {
        // a (weight 4) and b (weight 1) both keep 10 jobs queued; over
        // the first 10 pops a is served 4x as often as b
        let opts = weighted_opts(&[("a", 4), ("b", 1)], 100);
        let mut st = ServiceState::default();
        for i in 0..10 {
            st.queue.push_back(queued_job(i, "a"));
        }
        for i in 10..20 {
            st.queue.push_back(queued_job(i, "b"));
        }
        let mut popped = Vec::new();
        for _ in 0..10 {
            popped.push(pop_next(&mut st, &opts).expect("work available").tenant);
        }
        let a = popped.iter().filter(|t| *t == "a").count();
        let b = popped.iter().filter(|t| *t == "b").count();
        assert_eq!((a, b), (8, 2), "4:1 weights serve 8:2 over 10 pops: {popped:?}");
        // within a tenant the order stayed FIFO
        let mut st2 = ServiceState::default();
        st2.queue.push_back(queued_job(0, "a"));
        st2.queue.push_back(queued_job(1, "a"));
        assert_eq!(pop_next(&mut st2, &opts).unwrap().id, 0);
        assert_eq!(pop_next(&mut st2, &opts).unwrap().id, 1);
    }

    #[test]
    fn weighted_fair_pop_never_starves_a_light_tenant() {
        // an absurd weight ratio: the light tenant is still served
        // within bounded delay because every pop advances a pass
        let opts = weighted_opts(&[("heavy", 10_000)], 100);
        let mut st = ServiceState::default();
        for i in 0..200 {
            st.queue.push_back(queued_job(i, "heavy"));
        }
        st.queue.push_back(queued_job(200, "light"));
        let mut light_served_at = None;
        for n in 0..201 {
            let q = pop_next(&mut st, &opts).expect("work available");
            if q.tenant == "light" {
                light_served_at = Some(n);
                break;
            }
        }
        assert!(light_served_at.is_some(), "the weight-1 tenant must be served eventually");
        assert!(st.queue.iter().all(|q| q.tenant == "heavy"));
    }

    #[test]
    fn weighted_fair_pop_respects_the_inflight_cap() {
        let opts = weighted_opts(&[("a", 100)], 1);
        let mut st = ServiceState::default();
        st.queue.push_back(queued_job(0, "a"));
        st.queue.push_back(queued_job(1, "a"));
        st.queue.push_back(queued_job(2, "b"));
        // a's first job takes its only in-flight slot; the next pop must
        // skip a's queued job and serve b despite a's huge weight
        assert_eq!(pop_next(&mut st, &opts).unwrap().tenant, "a");
        assert_eq!(pop_next(&mut st, &opts).unwrap().tenant, "b");
        assert!(pop_next(&mut st, &opts).is_none(), "a is capped, nothing is eligible");
        // a's job finishing frees the slot
        *st.inflight.get_mut("a").unwrap() -= 1;
        assert_eq!(pop_next(&mut st, &opts).unwrap().id, 1);
    }

    #[test]
    fn idle_tenants_do_not_bank_virtual_time() {
        // b idles while a is served many times; when b arrives its pass
        // starts at the current virtual time, not at zero
        let opts = weighted_opts(&[], 100);
        let mut st = ServiceState::default();
        for i in 0..50 {
            st.queue.push_back(queued_job(i, "a"));
        }
        for _ in 0..50 {
            pop_next(&mut st, &opts).expect("work available");
        }
        assert!(st.virtual_time > 0);
        // simulate StudyService::submit's idle-tenant pass reset
        let vt = st.virtual_time;
        st.pass.insert("b".into(), vt);
        st.queue.push_back(queued_job(50, "a"));
        st.queue.push_back(queued_job(51, "b"));
        let order: Vec<String> =
            (0..2).map(|_| pop_next(&mut st, &opts).unwrap().tenant).collect();
        // equal weights from a shared starting point: strict alternation,
        // not a burst of b catching up on banked time
        assert_eq!(order.iter().filter(|t| *t == "b").count(), 1);
    }

    #[test]
    fn tenant_cap_never_exceeds_inflight_limit() {
        // cap 1, one service worker: three jobs of one tenant run
        // strictly one at a time and all complete
        let svc = StudyService::start(opts(1)).expect("service starts");
        for _ in 0..3 {
            svc.submit(StudyJob { tenant: "solo".into(), cfg: small_cfg() }).unwrap();
        }
        let report = svc.drain();
        assert_eq!(report.jobs.len(), 3);
        assert!(report.jobs.iter().all(|j| j.ok()));
        let t = report.tenant("solo").expect("tenant report");
        assert_eq!(t.jobs, 3);
        assert_eq!(t.failed, 0);
        assert!(t.bytes_served > 0, "warm runs are served real state bytes");
        // the 2nd and 3rd runs are warm: far fewer launches than cold
        let (first, rest): (u64, u64) =
            (report.jobs[0].launches, report.jobs[1].launches + report.jobs[2].launches);
        assert!(rest < first, "warm jobs must reuse: cold {first}, warm {rest}");
    }

    #[test]
    fn a_scripted_worker_panic_is_retried_and_billed() {
        let plan = Arc::new(crate::faults::FaultPlan::new().panic_on_launch(1));
        let mut o = opts(1);
        o.faults = Faults::hooked(plan.clone());
        o.job_retries = 2;
        let svc = StudyService::start(o).expect("service starts");
        svc.submit(StudyJob { tenant: "crashy".into(), cfg: small_cfg() }).unwrap();
        let report = svc.drain();

        assert_eq!(report.jobs.len(), 1);
        assert!(report.jobs[0].ok(), "the retry must succeed: {:?}", report.jobs[0].error);
        assert_eq!(report.jobs[0].retries, 1, "one failed attempt was retried");
        assert_eq!(plan.fired().launch_panics, 1, "the scripted panic fired exactly once");
        let t = report.tenant("crashy").expect("tenant report");
        assert_eq!((t.failed, t.retries), (0, 1), "retries billed, job not failed");
    }

    #[test]
    fn retry_budget_exhausts_into_a_failed_job() {
        // every attempt's first launch panics: 1 + 2 retries, then final
        let plan = Arc::new(
            crate::faults::FaultPlan::new()
                .panic_on_launch(1)
                .panic_on_launch(2)
                .panic_on_launch(3),
        );
        let mut o = opts(1);
        o.faults = Faults::hooked(plan.clone());
        o.job_retries = 2;
        let svc = StudyService::start(o).expect("service starts");
        svc.submit(StudyJob { tenant: "doomed".into(), cfg: small_cfg() }).unwrap();
        let report = svc.drain();

        assert!(!report.jobs[0].ok(), "budget exhausted: the failure is final");
        let err = report.jobs[0].error.as_deref().unwrap();
        assert!(err.contains("panic"), "the last attempt's error survives: {err}");
        assert_eq!(report.jobs[0].retries, 2, "exactly the budgeted retries happened");
        assert_eq!(plan.fired().launch_panics, 3);
        assert_eq!(report.tenant("doomed").unwrap().failed, 1);
    }

    #[test]
    fn serve_options_resilience_defaults_and_flag_overrides() {
        let base = ServeOptions::default();
        assert_eq!(base.job_retries, DEFAULT_JOB_RETRIES);
        assert_eq!(base.submit_window, DEFAULT_SUBMIT_WINDOW);
        assert_eq!(base.drain_deadline, Some(DEFAULT_DRAIN_DEADLINE));
        assert_eq!(base.job_deadline, None);
        assert!(!base.speculate, "speculation is opt-in");
        assert!(!base.faults.is_active());
        assert_eq!(base.replicas, 1, "one replica per hot prefix by default");
        assert!(!base.route, "front-door routing is opt-in");

        let args: Vec<String> =
            ["window=3", "retries=0", "speculate=on"].iter().map(|s| s.to_string()).collect();
        let sc = ServeConfig::from_args(&args).unwrap();
        let o = ServeOptions::from_config(&sc);
        assert_eq!(o.submit_window, 3);
        assert_eq!(o.job_retries, 0, "retries=0 disables retry");
        assert!(o.speculate, "speculate=on reaches the options");

        let args: Vec<String> = ["listen=127.0.0.1:0", "peers=127.0.0.1:0,h:2", "replicas=2", "route=on"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let sc = ServeConfig::from_args(&args).unwrap();
        let o = ServeOptions::from_config(&sc);
        assert_eq!(o.replicas, 2, "replicas= reaches the options");
        assert!(o.route, "route=on reaches the options");
        assert_eq!(o.cluster_addr.as_deref(), Some("127.0.0.1:0"));
    }

    /// Pin the execution-environment fields exactly as `execute_job`
    /// would before it builds the speculation hook.
    fn pinned_cfg(svc: &StudyService) -> StudyConfig {
        let mut cfg = small_cfg();
        cfg.engine = EngineMode::Pjrt;
        cfg.artifacts_dir = svc.inner.opts.artifacts_dir.clone();
        cfg.workers = svc.inner.opts.study_workers;
        cfg.batch_width = svc.inner.opts.batch_width;
        cfg
    }

    #[test]
    fn speculative_execution_bills_the_pseudo_tenant_not_a_client() {
        let svc = StudyService::start(opts(1)).expect("service starts");
        let cfg = pinned_cfg(&svc);
        svc.inner.execute_speculative(SpecJob {
            job: 42,
            cfg,
            sets: vec![default_space().defaults()],
        });
        // the cache was cold, so the speculative unit certainly launched;
        // its work lands in the global speculative ledger and the per-job
        // map, never in a client tenant's row
        let spent = svc.speculative_launches();
        assert!(spent > 0, "cold speculation launches");
        assert_eq!(svc.inner.spec_launches.lock().unwrap().get(&42).copied(), Some(spent));

        let report = svc.drain();
        assert_eq!(report.speculative_launches, spent);
        let spec = report.tenant(SPECULATIVE_TENANT).expect("pseudo-tenant row in the bill");
        assert_eq!(spec.jobs, 0, "no client job ran under the pseudo-tenant");
        assert!(
            spec.cache.misses + spec.cache.inserts > 0,
            "the speculative cache traffic is billed to the pseudo-tenant"
        );
        // the pseudo-tenant keeps the ledger arithmetic exact
        let sums = report.scoped_totals();
        assert_eq!(sums.misses, report.cache.misses);
        assert_eq!(sums.inserts, report.cache.inserts);
        assert_eq!(report.total_launches(), report.input_launches + spent);
    }

    #[test]
    fn drain_discards_queued_speculation_without_wedging() {
        let svc = StudyService::start(opts(1)).expect("service starts");
        let cfg = pinned_cfg(&svc);
        {
            // queue speculation and start draining in one critical
            // section, so no worker can slip in and execute it
            let mut st = svc.inner.state.lock().unwrap();
            st.spec.push_back(SpecJob { job: 1, cfg, sets: vec![default_space().defaults()] });
            st.draining = true;
        }
        let report = svc.drain();
        assert_eq!(report.speculative_launches, 0, "discarded speculation never ran");
        assert!(report.tenant(SPECULATIVE_TENANT).is_none(), "no pseudo-tenant scope created");
        assert_eq!(svc.speculative_pending(), 0, "the backlog was cleared");
    }

    #[test]
    fn speculation_offers_are_refused_when_empty_or_draining() {
        let svc = StudyService::start(opts(1)).expect("service starts");
        let hook = ServiceSpeculation { inner: &svc.inner, job: 9, cfg: pinned_cfg(&svc) };
        hook.offer(&[]);
        assert_eq!(svc.speculative_pending(), 0, "an empty prediction is not queued");
        svc.inner.state.lock().unwrap().draining = true;
        hook.offer(&[default_space().defaults()]);
        assert_eq!(svc.speculative_pending(), 0, "draining refuses new speculation");
        // un-drain so the Drop-join path exercises the empty queue
        svc.inner.state.lock().unwrap().draining = false;
    }

    #[test]
    fn retry_backoff_doubles_caps_and_jitters_deterministically() {
        assert!(retry_backoff(1, 1) >= Duration::from_millis(10));
        assert!(retry_backoff(1, 1) < Duration::from_millis(20));
        assert!(retry_backoff(1, 99) <= Duration::from_millis(750), "cap + 50% jitter");
        assert_eq!(retry_backoff(7, 2), retry_backoff(7, 2), "same (job, attempt) → same delay");
        // different jobs de-synchronize at the same attempt (for these
        // inputs; jitter is a hash, not a guarantee for every pair)
        assert_ne!(retry_backoff(1, 3), retry_backoff(2, 3));
    }
}
