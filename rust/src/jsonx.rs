//! Minimal JSON parser/serializer (this build environment has no network
//! access and no vendored serde, so the crate carries its own).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP. Numbers parse as `f64`. Used for `artifacts/manifest.json`, the
//! stage descriptor files (paper Fig. 7), study configs, and result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    /// Parse a JSON document (must consume all non-whitespace input).
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    v.write(out, indent, level + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                if !o.is_empty() {
                    newline_indent(out, indent, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience: build an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> ParseError {
        ParseError { offset: self.pos, message: message.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"tasks": [{"name": "t1", "n": 3.5}], "ok": true, "z": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(128.0).to_string_compact(), "128");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 世界"));
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 5, "s": "x", "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(5));
        assert_eq!(v.get("s").unwrap().as_f64(), None);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 1);
        assert!(v.get("missing").is_none());
    }
}
