//! `rtf-reuse` — the leader entrypoint.
//!
//! Subcommands (all take `key=value` options; see `rtf-reuse help`):
//!
//! * `run-sa`             — execute an SA study for real on PJRT workers
//! * `tune`               — optimizer-driven parameter search (simplex
//!                          or genetic) riding the reuse cache
//! * `serve`              — multi-tenant study service: many studies,
//!                          one shared reuse cache
//! * `simulate`           — same plan through the discrete-event cluster
//! * `merge-plan`         — print the reuse plan an algorithm produces
//! * `reuse-audit`        — maximum reuse potential per sampler (Table 4)
//! * `profile-tasks`      — measure per-task costs (Table 6) and emit a
//!                          cost-model JSON
//! * `gen-tiles`          — describe the synthetic tiles of a study
//! * `inspect-artifacts`  — show the AOT artifact manifest

use rtf_reuse::analysis::sobol_indices;
use rtf_reuse::benchx::{fmt_secs, Table};
use rtf_reuse::config::{EngineMode, SaMethod, StudyConfig};
use rtf_reuse::data::{synth_tile, SynthConfig};
use rtf_reuse::driver::{
    self, make_tiles, prepare, reference_masks, run_pjrt, run_sim, SampleInfo,
};
use rtf_reuse::merging::UnitKind;
use rtf_reuse::runtime::PjrtEngine;
use rtf_reuse::sampling::default_space;
use rtf_reuse::simulate::{default_cost_model, CostModel};
use rtf_reuse::workflow::paper_workflow;
use rtf_reuse::{Error, Result};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let r = match cmd {
        "run-sa" => cmd_run_sa(rest),
        "tune" => cmd_tune(rest),
        "serve" => cmd_serve(rest),
        "simulate" => cmd_simulate(rest),
        "merge-plan" => cmd_merge_plan(rest),
        "reuse-audit" => cmd_reuse_audit(rest),
        "profile-tasks" => cmd_profile_tasks(rest),
        "gen-tiles" => cmd_gen_tiles(rest),
        "inspect-artifacts" => cmd_inspect(rest),
        "gen-stage" => cmd_gen_stage(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown command `{other}` (commands: run-sa, tune, serve, simulate, merge-plan, \
             reuse-audit, profile-tasks, gen-tiles, gen-stage, inspect-artifacts; try `help`)"
        ))),
    };
    if let Err(e) = r {
        eprintln!("rtf-reuse: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "rtf-reuse — multi-level computation reuse for SA studies\n\
         \n\
         usage: rtf-reuse <command> [key=value ...]\n\
         \n\
         commands:\n\
           run-sa             run an SA study on real PJRT workers\n\
           tune               optimize the parameters (simplex/genetic) on the cache\n\
           serve              run many tenants' studies against ONE shared cache\n\
           simulate           run the study through the cluster simulator\n\
           merge-plan         print the reuse plan for a config\n\
           reuse-audit        reuse potential per sampler (paper Table 4)\n\
           profile-tasks      measure per-task costs (paper Table 6)\n\
           gen-tiles          describe the synthetic tiles of a study\n\
           gen-stage          emit Rust code from a workflow descriptor\n\
           inspect-artifacts  show the AOT artifact manifest\n\
         \n\
         common options:\n\
           method=moat|vbd  r=10  n=200  k-active=8  sampler=qmc|mc|lhs\n\
           algo=none|naive|sca|rtma|trtma  mbs=7  max-buckets=N\n\
           coarse=on|off  engine=pjrt|sim  workers=2  batch-width=16\n\
           tiles=1  seed=42\n\
           artifacts=DIR (default: the crate's artifacts/ dir)\n\
           cache=on|off  cache-mb=256  cache-quant=0  cache-shards=8  cache-dir=DIR\n\
           adaptive=on|off    online pruning: cancel not-yet-launched units once a\n\
                              parameter's CI is non-significant (surviving results\n\
                              stay bit-identical to the full run)\n\
           threshold=0.05     adaptive CI cutoff (mu*/S_i upper bound below it prunes)\n\
           min-samples=4      units observed per parameter before pruning may start\n\
         \n\
         tune options (plus any study option above; cache defaults ON here):\n\
           tuner=ga|nm        genetic algorithm / Nelder-Mead simplex\n\
           budget=64          candidate-evaluation budget (generations are atomic)\n\
           population=12      GA population size\n\
           k-active=8         tune the top-k MOAT-screened parameters ...\n\
           active=G1,G2       ... or an explicit comma-separated name list\n\
           objective=dice     dice|jaccard vs. the reference masks\n\
           cost-lambda=0      chain-cost penalty (constant within one fixed workflow)\n\
           mutation=0.25      GA per-gene mutation probability\n\
           init=LO:HI         initial-population grid-fraction window (default 0:1)\n\
           speculate=on|off   hint: served tune jobs pre-execute the predicted next\n\
                              generation on idle workers (cache warming only)\n\
         \n\
         serve options (plus any study option above as the per-job default):\n\
           serve-workers=2    concurrent studies in flight\n\
           tenant-cap=1       max in-flight studies per tenant\n\
           priority=T:W       admission weight for tenant T (weighted fair, default 1)\n\
           quota=MB           per-tenant memory-tier byte quota (quota=T:MB overrides)\n\
           warm-start=on|off  pre-admit disk-tier entries at boot (default: on with cache-dir)\n\
           retries=2          extra attempts a failed job gets before it is billed FAILED\n\
           window=64          per-connection submit window (undelivered jobs; wire mode)\n\
           speculate=on|off   idle workers pre-execute tuning jobs' predicted next\n\
                              generations (billed as speculative, never to a tenant)\n\
           tenants=2          demo mode: N tenants ...\n\
           jobs-per-tenant=1  ... each submitting this many identical studies\n\
           jobs=FILE          per-line jobs: `tenant=NAME [kind=study|tune] [opts]`\n\
           listen=ADDR        serve the wire protocol on ADDR (e.g. 127.0.0.1:7070)\n\
           addr-file=PATH     with listen=: write the bound address to PATH\n\
           peers=ADDR,...     cluster mode: the full node list (must include this\n\
                              node's listen= address); the 128-bit key space is\n\
                              partitioned across peers over cache-get/cache-put\n\
           replicas=1         cluster mode: hot keys served to peers twice are\n\
                              pushed to the ring's next peer (0 disables)\n\
           route=on|off       cluster mode: front-door routing — submits are\n\
                              forwarded to the peer owning most of the study's\n\
                              predicted chain keys (default off)\n\
           submit=ADDR        client mode: send jobs=FILE to a listening service\n\
           drain=on           client mode: drain the service and print its bill\n\
                              (jobs files may carry `peers add=ADDR` /\n\
                              `peers remove=ADDR` admin lines: live membership —\n\
                              and a bare `stats` line: fetch + print a telemetry\n\
                              snapshot at that point of the sequence)\n\
           trace=FILE         serving side: stream structured JSONL spans (job,\n\
                              admit, queue, schedule, level, lookup, launch,\n\
                              retry, drain, route, serve-get/put) to FILE\n\
           stats=on|off       keep the metrics registry live; serving side logs a\n\
                              one-line digest on change, client mode prints a\n\
                              final Prometheus-style dump (default off)\n\
         \n\
         docs/SERVING.md is the operator's guide + wire-protocol spec;\n\
         docs/OBSERVABILITY.md covers tracing, metrics and the stats surface"
    );
}

fn cmd_run_sa(args: &[String]) -> Result<()> {
    let cfg = StudyConfig::from_args(args)?;
    println!("study: {}", cfg.describe());
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    print_plan_summary(&cfg, &prepared, &plan);

    if cfg.engine == EngineMode::Sim {
        let opts = rtf_reuse::simulate::SimOptions::new(cfg.workers)
            .with_cores(cfg.cores)
            .with_batch(cfg.batch_width, rtf_reuse::merging::DEFAULT_LAUNCH_COST_SECS);
        let report = run_sim(&prepared, &plan, &default_cost_model(), &opts);
        println!(
            "simulated: makespan {}  utilization {:.1}%  tasks {}",
            fmt_secs(report.makespan),
            report.utilization() * 100.0,
            report.tasks
        );
        return Ok(());
    }

    if cfg.adaptive.enabled {
        return run_sa_adaptive(&cfg, &prepared);
    }

    let outcome = run_pjrt(&cfg, &prepared, &plan)?;
    println!(
        "executed: wall {}  peak state {} KiB",
        fmt_secs(outcome.wall.as_secs_f64()),
        outcome.peak_state_bytes / 1024
    );
    if let Some(stats) = &outcome.cache {
        println!(
            "cache: {} state hits ({} from disk), {} misses, {} metric hits, \
             {:.1}% hit rate, resident {} KiB (peak {} KiB)",
            stats.hits + stats.disk_hits,
            stats.disk_hits,
            stats.misses,
            stats.metric_hits,
            stats.hit_rate() * 100.0,
            stats.resident_bytes / 1024,
            stats.peak_bytes / 1024
        );
    }

    match &prepared.sample {
        SampleInfo::Moat(_) => {
            let (idx, top) = driver::moat_screen(&cfg, &prepared, &outcome.y, 8);
            let space = &prepared.space;
            let mut t = Table::new(&["param", "mean EE", "mu*", "sigma"]);
            for p in 0..space.dim() {
                t.row(&[
                    space.params[p].name.clone(),
                    format!("{:+.4}", idx.mean[p]),
                    format!("{:.4}", idx.mu_star[p]),
                    format!("{:.4}", idx.sigma[p]),
                ]);
            }
            t.print("MOAT elementary effects (paper Table 2, left)");
            let names: Vec<&str> =
                top.iter().map(|&p| space.params[p].name.as_str()).collect();
            println!("top-8 screen: {}", names.join(", "));
        }
        SampleInfo::Vbd(sample, active) => {
            let y = driver::y_per_set(&outcome.y, sample.sets.len(), cfg.tiles);
            let idx = sobol_indices(sample, &y);
            let mut t = Table::new(&["param", "S_i (main)", "ST_i (total)"]);
            for (i, &p) in active.iter().enumerate() {
                t.row(&[
                    prepared.space.params[p].name.clone(),
                    format!("{:.4}", idx.first[i]),
                    format!("{:.4}", idx.total[i]),
                ]);
            }
            t.print("VBD Sobol indices (paper Table 2, right)");
        }
        SampleInfo::Explicit(n) => {
            // run-sa never prepares explicit candidate lists (that is
            // the tune subsystem's entry), but the match stays total
            println!("explicit candidate study: {n} sets (no SA estimator applies)");
        }
    }
    Ok(())
}

/// The adaptive `run-sa` path (`adaptive=on`): units execute one at a
/// time through the incremental estimator, which prunes the rest of a
/// parameter's work once its CI upper bound falls below `threshold=`.
/// Surviving evaluations are bit-identical to the full run's.
fn run_sa_adaptive(cfg: &StudyConfig, prepared: &driver::PreparedStudy) -> Result<()> {
    use rtf_reuse::adaptive::{run_adaptive, AdaptiveEstimate};

    let out = run_adaptive(cfg)?;
    let survived = out.survived.iter().filter(|&&s| s).count();
    println!(
        "adaptive: executed {} of {} sets ({} evals pruned), {} launches \
         ({} cache-served), wall {}",
        survived,
        out.survived.len(),
        out.pruned,
        out.launches,
        out.cached_tasks,
        fmt_secs(out.wall.as_secs_f64())
    );
    let space = &prepared.space;
    if !out.pruned_params.is_empty() {
        let names: Vec<&str> =
            out.pruned_params.iter().map(|&p| pruned_param_name(prepared, p)).collect();
        println!(
            "pruned parameters (CI upper bound < {}): {}",
            cfg.adaptive.threshold,
            names.join(", ")
        );
    }
    match &out.estimate {
        AdaptiveEstimate::Moat(idx) => {
            let mut t = Table::new(&["param", "mean EE", "mu*", "sigma", "units"]);
            for p in 0..space.dim() {
                t.row(&[
                    space.params[p].name.clone(),
                    format!("{:+.4}", idx.mean[p]),
                    format!("{:.4}", idx.mu_star[p]),
                    format!("{:.4}", idx.sigma[p]),
                    idx.count[p].to_string(),
                ]);
            }
            t.print("MOAT elementary effects (adaptive, partial counts for pruned params)");
        }
        AdaptiveEstimate::Vbd(idx) => {
            let active = match &prepared.sample {
                SampleInfo::Vbd(_, active) => active.clone(),
                _ => (0..idx.first.len()).collect(),
            };
            let mut t = Table::new(&["param", "S_i (main)", "ST_i (total)"]);
            for (i, &p) in active.iter().enumerate() {
                t.row(&[
                    space.params[p].name.clone(),
                    format!("{:.4}", idx.first[i]),
                    format!("{:.4}", idx.total[i]),
                ]);
            }
            t.print("VBD Sobol indices (adaptive, pruned params estimated on observed blocks)");
        }
    }
    Ok(())
}

/// Map a pruned index back to a parameter name: MOAT prunes over the
/// full space, VBD over its active subset.
fn pruned_param_name(prepared: &driver::PreparedStudy, p: usize) -> &str {
    let p = match &prepared.sample {
        SampleInfo::Vbd(_, active) => active[p],
        _ => p,
    };
    prepared.space.params[p].name.as_str()
}

/// `tune`: optimizer-driven parameter search — a Nelder-Mead simplex or
/// a genetic algorithm proposes candidate parameter sets, each
/// generation runs as ONE batched study, revisited quantized points are
/// memoized, and the whole loop rides the (default-on) reuse cache.
fn cmd_tune(args: &[String]) -> Result<()> {
    use rtf_reuse::config::TuneConfig;
    use rtf_reuse::tune::run_tune_standalone;

    let tc = TuneConfig::from_args(args)?;
    let opts = &tc.options;
    let space = default_space();
    let active = opts.active_params();
    let names: Vec<&str> = active.iter().map(|&p| space.params[p].name.as_str()).collect();
    println!(
        "tune: {} budget={} population={} objective={} lambda={} active=[{}]",
        opts.method.name(),
        opts.budget,
        opts.population,
        opts.objective.name(),
        opts.cost_lambda,
        names.join(", ")
    );
    println!("candidate study: {}", tc.study.describe());

    let outcome = run_tune_standalone(&tc.study, &tc.options)?;

    let mut t = Table::new(&["gen", "asked", "evaluated", "memo hits", "best score"]);
    for g in &outcome.history {
        t.row(&[
            g.gen.to_string(),
            g.asked.to_string(),
            g.evaluated.to_string(),
            g.memo_hits.to_string(),
            format!("{:.6}", g.best_score),
        ]);
    }
    t.print("tuning progress (one batched study per generation)");

    let defaults = space.defaults();
    let mut p = Table::new(&["param", "tuned", "default"]);
    for (i, def) in space.params.iter().enumerate() {
        let marker = if active.contains(&i) { "" } else { " (pinned)" };
        p.row(&[
            format!("{}{marker}", def.name),
            outcome.best_params[i].to_string(),
            defaults[i].to_string(),
        ]);
    }
    p.print("best parameter set");

    println!(
        "best {}: {:.6} (initial best {:.6}, improved: {})",
        opts.objective.name(),
        outcome.best_score,
        outcome.initial_best_score,
        if outcome.improved() { "yes" } else { "no" }
    );
    println!(
        "evaluated {} of {} proposed candidates ({} memo hits) in {} launches \
         ({} cache-served), wall {}",
        outcome.evaluated,
        outcome.asked,
        outcome.memo_hits,
        outcome.launches,
        outcome.cached_tasks,
        fmt_secs(outcome.wall.as_secs_f64())
    );
    if let Some(stats) = &outcome.cache {
        println!(
            "cache: {} state hits ({} from disk), {} misses, {} metric hits, {:.1}% hit rate",
            stats.hits + stats.disk_hits,
            stats.disk_hits,
            stats.misses,
            stats.metric_hits,
            stats.hit_rate() * 100.0
        );
    }
    Ok(())
}

/// `serve`: three modes behind one command (see `docs/SERVING.md`).
/// In-process (default): submit the demo workload or a `jobs=FILE` and
/// drain. `listen=ADDR`: serve the wire protocol over TCP until a
/// client drains. `submit=ADDR`: be the wire client for a `jobs=FILE`.
/// Every served job runs against ONE shared reuse cache; the per-tenant
/// bill shows who paid for launches and who rode the cache.
fn cmd_serve(args: &[String]) -> Result<()> {
    use rtf_reuse::config::ServeConfig;
    use rtf_reuse::serve::{
        parse_job_lines, render_prometheus, run_lines, JobLine, ServeOptions, StudyJob,
        StudyService, WireServer, PROTOCOL_VERSION,
    };

    let sc = ServeConfig::from_args(args)?;

    // ---- client mode ------------------------------------------------
    if let Some(addr) = &sc.submit {
        let path = sc.jobs_file.as_ref().ok_or_else(|| {
            Error::Config("client mode needs jobs=FILE (`tenant=NAME [opts]` per line)".into())
        })?;
        let text = std::fs::read_to_string(path)?;
        let mut lines = parse_job_lines(&text, &sc.study_args)?;
        if sc.stats {
            // stats=on in client mode: one final snapshot after the
            // whole sequence, printed as the Prometheus-style dump
            lines.push(JobLine::Stats);
        }
        let n = lines.iter().filter(|l| matches!(l, JobLine::Job(_))).count();
        println!("client: submitting {n} jobs to {addr} (protocol v{PROTOCOL_VERSION})");
        let outcome = run_lines(addr, &lines, sc.drain)?;
        for j in &outcome.jobs {
            let status = if j.ok() { "ok" } else { "FAILED" };
            println!(
                "job {} tenant={} {status} launches={} cached={} retries={} pruned={} \
                 speculative={} evals={} wall={}",
                j.job,
                j.tenant,
                j.launches,
                j.cached_tasks,
                j.retries,
                j.pruned,
                j.speculative,
                j.n_evals,
                fmt_secs(j.exec_wall_secs)
            );
            if let Some(e) = &j.error {
                println!("  error: {e}");
            }
            if let Some(ts) = &j.tune {
                println!(
                    "  tuned[{}]: best {:.4} (initial {:.4}) over {} generations, \
                     {} evaluated, {} memo hits",
                    ts.method,
                    ts.best_score,
                    ts.initial_best_score,
                    ts.generations,
                    ts.evaluated,
                    ts.memo_hits
                );
            }
        }
        for s in &outcome.stats {
            print!("{}", render_prometheus(s));
        }
        if let Some(bill) = &outcome.bill {
            let mut t = Table::new(&[
                "tenant", "jobs", "launches", "cached", "retries", "pruned", "spec",
                "hits", "misses", "quota MiB", "resident KiB",
            ]);
            for ten in &bill.tenants {
                t.row(&[
                    ten.tenant.clone(),
                    ten.jobs.to_string(),
                    ten.launches.to_string(),
                    ten.cached_tasks.to_string(),
                    ten.retries.to_string(),
                    ten.pruned.to_string(),
                    ten.speculative.to_string(),
                    (ten.cache.hits + ten.cache.disk_hits).to_string(),
                    ten.cache.misses.to_string(),
                    fmt_quota(ten.quota_bytes),
                    (ten.cache.resident_bytes / 1024).to_string(),
                ]);
            }
            t.print("drain bill (per tenant, from the drained service)");
            if !bill.tiers.is_empty() {
                let mut t = Table::new(&[
                    "tier", "hits", "stores", "resident KiB", "breaker o/c", "replica hits",
                ]);
                for tr in &bill.tiers {
                    t.row(&[
                        tr.tier.clone(),
                        tr.stats.hits.to_string(),
                        tr.stats.stores.to_string(),
                        (tr.stats.resident_bytes / 1024).to_string(),
                        format!("{}/{}", tr.stats.breaker_opens, tr.stats.breaker_closes),
                        tr.stats.replica_hits.to_string(),
                    ]);
                }
                t.print("per-tier cache counters (rtfp v7)");
            }
            println!(
                "drain bill: {} jobs ({} failed, {} retried attempts, {} evals pruned), \
                 {} total launches ({} speculative), service wall {}",
                bill.jobs,
                bill.failed,
                bill.retries,
                bill.pruned,
                bill.total_launches,
                bill.speculative_launches,
                fmt_secs(bill.wall_secs)
            );
        }
        return Ok(());
    }

    // ---- service modes ----------------------------------------------
    let opts = ServeOptions::from_config(&sc);
    println!(
        "serve: {} service workers, tenant cap {}, {} study workers, cache {} MiB{}{}{}{}",
        opts.service_workers,
        opts.tenant_inflight_cap,
        opts.study_workers,
        opts.cache.capacity_bytes / (1024 * 1024),
        match opts.tenant_quota_bytes {
            Some(q) => format!(", tenant quota {} MiB", q / (1024 * 1024)),
            None => String::new(),
        },
        if opts.warm_start { ", warm-start on" } else { "" },
        if opts.peers.is_empty() {
            String::new()
        } else {
            format!(
                ", cluster of {} peers (replicas={}{})",
                opts.peers.len(),
                opts.replicas,
                if opts.route { ", front-door routing" } else { "" }
            )
        },
        match (&opts.trace, opts.stats) {
            (Some(path), _) => format!(", tracing to {path}"),
            (None, true) => ", stats on".to_string(),
            (None, false) => String::new(),
        }
    );
    let svc = StudyService::start(opts)?;
    let warm = svc.warm_start_report();
    if warm.scanned > 0 || warm.swept > 0 {
        println!(
            "warm-start: scanned {} disk entries, admitted {} ({} KiB) into memory, \
             swept {} crash debris, reloaded {} comparison metrics",
            warm.scanned,
            warm.admitted,
            warm.admitted_bytes / 1024,
            warm.swept,
            warm.metrics_loaded
        );
    }

    if let Some(listen_addr) = &sc.listen {
        let server = WireServer::bind(svc, listen_addr)?;
        let bound = server.local_addr()?;
        println!("serve: listening on {bound} (protocol v{PROTOCOL_VERSION}); drain to stop");
        if let Some(path) = &sc.addr_file {
            std::fs::write(path, bound.to_string())?;
        }
        let report = server.run()?;
        print_service_report(&report);
        return Ok(());
    }

    let mut submitted = 0usize;
    match &sc.jobs_file {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            for line in parse_job_lines(&text, &sc.study_args)? {
                match line {
                    JobLine::Job(spec) => {
                        if spec.tune {
                            let tc = rtf_reuse::config::TuneConfig::from_args(&spec.args)?;
                            svc.submit_tune(spec.tenant, tc.study, tc.options)?;
                        } else {
                            let cfg = StudyConfig::from_args(&spec.args)?;
                            svc.submit(StudyJob { tenant: spec.tenant, cfg })?;
                        }
                        submitted += 1;
                    }
                    // admin lines work in-process too: apply + relay,
                    // exactly as a wire peer-join/peer-leave would
                    JobLine::PeerAdd(peer) => {
                        let size = svc.peer_join(&peer, true)?;
                        println!("peers: {peer} joined, ring size {size}");
                    }
                    JobLine::PeerRemove(peer) => {
                        let size = svc.peer_leave(&peer, true)?;
                        println!("peers: {peer} left, ring size {size}");
                    }
                    // in-process stats: snapshot the service directly,
                    // same dump the wire client prints
                    JobLine::Stats => print!("{}", render_prometheus(&svc.stats_snapshot())),
                }
            }
        }
        None => {
            for t in 0..sc.tenants {
                for _ in 0..sc.jobs_per_tenant {
                    let job = StudyJob { tenant: format!("tenant-{t}"), cfg: sc.study.clone() };
                    svc.submit(job)?;
                }
                submitted += sc.jobs_per_tenant;
            }
        }
    }
    println!("submitted {submitted} studies; draining...");
    let report = svc.drain();
    print_service_report(&report);
    Ok(())
}

fn fmt_quota(quota_bytes: u64) -> String {
    if quota_bytes == 0 {
        "-".into()
    } else {
        (quota_bytes / (1024 * 1024)).to_string()
    }
}

/// The drained service's bill, as printed by every serve mode.
fn print_service_report(report: &rtf_reuse::serve::ServiceReport) {
    let mut t = Table::new(&[
        "tenant", "jobs", "failed", "retries", "pruned", "spec", "launches", "cached", "hits",
        "misses", "hit %", "served KiB", "quota MiB", "resident KiB", "evict", "exec wall",
    ]);
    for ten in &report.tenants {
        t.row(&[
            ten.tenant.clone(),
            ten.jobs.to_string(),
            ten.failed.to_string(),
            ten.retries.to_string(),
            ten.pruned.to_string(),
            ten.speculative.to_string(),
            ten.launches.to_string(),
            ten.cached_tasks.to_string(),
            (ten.cache.hits + ten.cache.disk_hits).to_string(),
            ten.cache.misses.to_string(),
            format!("{:.1}", ten.cache.hit_rate() * 100.0),
            (ten.bytes_served / 1024).to_string(),
            fmt_quota(ten.quota_bytes),
            (ten.cache.resident_bytes / 1024).to_string(),
            ten.cache.evictions.to_string(),
            fmt_secs(ten.exec_wall.as_secs_f64()),
        ]);
    }
    t.print("per-tenant bill (one shared reuse cache)");
    if !report.tiers.is_empty() {
        let mut t = Table::new(&[
            "tier", "hits", "stores", "resident KiB", "breaker o/c", "replica hits",
        ]);
        for (tier, s) in &report.tiers {
            t.row(&[
                tier.clone(),
                s.hits.to_string(),
                s.stores.to_string(),
                (s.resident_bytes / 1024).to_string(),
                format!("{}/{}", s.breaker_opens, s.breaker_closes),
                s.replica_hits.to_string(),
            ]);
        }
        t.print("per-tier cache counters");
    }
    let retried: u64 = report.jobs.iter().map(|j| j.retries).sum();
    let pruned: u64 = report.jobs.iter().map(|j| j.pruned).sum();
    println!(
        "service: {} jobs ({retried} retried attempts, {pruned} evals pruned), \
         {} total launches ({} shared input, {} speculative), wall {}",
        report.jobs.len(),
        report.total_launches(),
        report.input_launches,
        report.speculative_launches,
        fmt_secs(report.wall.as_secs_f64())
    );
    if report.warm.scanned > 0 || report.warm.swept > 0 {
        println!(
            "warm-start: {} of {} scanned disk entries were pre-admitted ({} KiB), \
             {} crash debris swept, {} comparison metrics reloaded",
            report.warm.admitted,
            report.warm.scanned,
            report.warm.admitted_bytes / 1024,
            report.warm.swept,
            report.warm.metrics_loaded
        );
    }
    let g = report.cache;
    println!(
        "shared cache: {} state hits ({} disk, {} remote), {} misses, {} metric hits, \
         {:.1}% hit rate, resident {} KiB (peak {} KiB)",
        g.hits + g.disk_hits + g.remote_hits,
        g.disk_hits,
        g.remote_hits,
        g.misses,
        g.metric_hits,
        g.hit_rate() * 100.0,
        g.resident_bytes / 1024,
        g.peak_bytes / 1024
    );
    for j in report.jobs.iter().filter(|j| !j.ok()) {
        let reason = j.error.as_deref().unwrap_or("?");
        println!("job {} (tenant {}) FAILED: {reason}", j.job, j.tenant);
    }
}

fn cmd_simulate(args: &[String]) -> Result<()> {
    let mut cfg = StudyConfig::from_args(args)?;
    cfg.engine = EngineMode::Sim;
    println!("study: {}", cfg.describe());
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    print_plan_summary(&cfg, &prepared, &plan);
    let model = load_cost_model();
    // the simulated cluster models frontier batching like the real one:
    // one launch-overhead charge per width-sized cohort. batch-width=1
    // prices node-at-a-time launches (one per task node) — launch-aware,
    // unlike the overhead-free pre-batching model that SimOptions::new
    // still defaults to for API users
    let opts = rtf_reuse::simulate::SimOptions::new(cfg.workers)
        .with_cores(cfg.cores)
        .with_batch(cfg.batch_width, rtf_reuse::merging::DEFAULT_LAUNCH_COST_SECS);
    let report = run_sim(&prepared, &plan, &model, &opts);
    println!(
        "simulated on {} workers: makespan {}  total work {}  utilization {:.1}%",
        cfg.workers,
        fmt_secs(report.makespan),
        fmt_secs(report.total_work),
        report.utilization() * 100.0
    );
    Ok(())
}

fn cmd_merge_plan(args: &[String]) -> Result<()> {
    let cfg = StudyConfig::from_args(args)?;
    println!("study: {}", cfg.describe());
    let prepared = prepare(&cfg);
    let plan = prepared.plan(&cfg);
    print_plan_summary(&cfg, &prepared, &plan);

    let mut t = Table::new(&["unit", "stage", "kind", "stages", "unique tasks"]);
    for u in plan.units.iter().take(40) {
        t.row(&[
            u.id.to_string(),
            u.stage.clone(),
            format!("{:?}", u.kind),
            u.nodes.len().to_string(),
            u.task_cost.to_string(),
        ]);
    }
    t.print(&format!(
        "schedule units (first 40 of {}; merge took {})",
        plan.units.len(),
        fmt_secs(plan.merge_time.as_secs_f64())
    ));
    Ok(())
}

fn cmd_reuse_audit(args: &[String]) -> Result<()> {
    use rtf_reuse::config::SamplerKind;
    use rtf_reuse::merging::{FineAlgorithm, TrtmaOptions};
    let base = StudyConfig::from_args(args)?;
    let mut t = Table::new(&["sampler", "sample", "coarse saved", "fine reuse %"]);
    for kind in [SamplerKind::Mc, SamplerKind::Lhs, SamplerKind::Qmc] {
        let cfg = StudyConfig {
            sampler: kind,
            // maximum reuse potential: one bucket per merge group
            algorithm: FineAlgorithm::Trtma(TrtmaOptions::new(1)),
            ..base.clone()
        };
        let prepared = prepare(&cfg);
        let plan = prepared.plan(&cfg);
        t.row(&[
            kind.name().to_string(),
            prepared.sample.n_sets().to_string(),
            plan.coarse_saved.to_string(),
            format!("{:.2}", plan.fine_reuse() * 100.0),
        ]);
    }
    t.print("maximum fine-grain reuse potential (paper Table 4)");
    Ok(())
}

fn cmd_profile_tasks(args: &[String]) -> Result<()> {
    let cfg = StudyConfig::from_args(args)?;
    let mut engine = PjrtEngine::load(&cfg.artifacts_dir)?;
    let (h, w) = engine.tile_shape();
    let space = default_space();
    let wf = paper_workflow();
    let tiles = make_tiles(&cfg, h, w);
    // several repetitions for stable means
    for rep in 0..5 {
        let _ = rep;
        let _ = reference_masks(&mut engine, &space, &wf, &tiles)?;
    }
    let rows = engine.timer().summary();
    let total: f64 = rows.iter().map(|(_, m, _)| m).sum();
    let mut t = Table::new(&["task", "mean", "share %", "runs"]);
    for (name, mean, n) in &rows {
        t.row(&[
            name.clone(),
            fmt_secs(*mean),
            format!("{:.2}", mean / total * 100.0),
            n.to_string(),
        ]);
    }
    t.print("per-task execution cost (paper Table 6 analog)");
    let model = CostModel::from_timer(engine.timer());
    let json = model.to_json().to_string_pretty();
    std::fs::create_dir_all("assets")?;
    std::fs::write("assets/task_costs.json", &json)?;
    println!("cost model written to assets/task_costs.json");
    Ok(())
}

fn cmd_gen_tiles(args: &[String]) -> Result<()> {
    let cfg = StudyConfig::from_args(args)?;
    let mut t = Table::new(&["tile", "size", "mean R", "mean G", "mean B"]);
    for id in 0..cfg.tiles as u64 {
        let tile = synth_tile(&SynthConfig::new(128, 128, cfg.seed ^ (id << 17) ^ 0x7469));
        t.row(&[
            id.to_string(),
            format!("{}x{}", tile.r.height(), tile.r.width()),
            format!("{:.1}", tile.r.mean()),
            format!("{:.1}", tile.g.mean()),
            format!("{:.1}", tile.b.mean()),
        ]);
    }
    t.print("synthetic tissue tiles");
    Ok(())
}

fn cmd_inspect(args: &[String]) -> Result<()> {
    let cfg = StudyConfig::from_args(args)?;
    let engine = PjrtEngine::load(&cfg.artifacts_dir)?;
    let m = engine.manifest();
    println!(
        "artifacts at {}: {}x{} tile, {} params, {} tasks",
        m.dir.display(),
        m.height,
        m.width,
        m.n_params,
        m.tasks.len()
    );
    let mut t = Table::new(&["task", "file", "in", "out", "kind", "sha16"]);
    for a in &m.tasks {
        t.row(&[
            a.name.clone(),
            a.file.clone(),
            a.image_inputs.to_string(),
            a.outputs.to_string(),
            a.output_kind.clone(),
            a.sha256_16.clone(),
        ]);
    }
    t.print("artifact manifest");
    Ok(())
}

fn print_plan_summary(
    cfg: &StudyConfig,
    prepared: &rtf_reuse::driver::PreparedStudy,
    plan: &rtf_reuse::merging::StudyPlan,
) {
    let merged = plan.units.iter().filter(|u| u.kind == UnitKind::Merged).count();
    println!(
        "plan: {} evals -> {} compact nodes ({} coarse-saved) -> {} units ({merged} merged), \
         fine reuse {:.1}%, merge time {}",
        prepared.n_evals(),
        prepared.graph.nodes.len(),
        plan.coarse_saved,
        plan.units.len(),
        plan.fine_reuse() * 100.0,
        fmt_secs(plan.merge_time.as_secs_f64())
    );
    match cfg.method {
        SaMethod::Moat { r } => println!("design: MOAT r={r} -> {} sets", prepared.sample.n_sets()),
        SaMethod::Vbd { n, k_active } => {
            println!("design: VBD n={n} k={k_active} -> {} sets", prepared.sample.n_sets())
        }
    }
}

fn cmd_gen_stage(args: &[String]) -> Result<()> {
    // gen-stage file=<descriptor.json> [out=<file.rs>]
    let mut file = None;
    let mut out = None;
    for a in args {
        match a.split_once('=') {
            Some(("file", v)) => file = Some(v.to_string()),
            Some(("out", v)) => out = Some(v.to_string()),
            _ => return Err(Error::Config(format!("gen-stage: unknown option `{a}`"))),
        }
    }
    let file = file.ok_or_else(|| Error::Config("gen-stage needs file=<descriptor.json>".into()))?;
    let text = std::fs::read_to_string(&file)?;
    let space = default_space();
    let wf = rtf_reuse::workflow::parse_workflow_file(&text, &space)?;
    let code = rtf_reuse::workflow::generate_workflow_code(&wf, &space);
    match out {
        Some(path) => {
            std::fs::write(&path, &code)?;
            println!("wrote {} bytes of generated workflow code to {path}", code.len());
        }
        None => print!("{code}"),
    }
    Ok(())
}

fn load_cost_model() -> CostModel {
    std::fs::read_to_string("assets/task_costs.json")
        .ok()
        .and_then(|text| rtf_reuse::jsonx::Json::parse(&text).ok())
        .and_then(|j| CostModel::from_json(&j).ok())
        .unwrap_or_else(default_cost_model)
}
