//! Workflow/stage/task specifications.

use crate::sampling::space::idx;
use crate::{Error, Result};

/// A fine-grain task: an external library call plus the indices of the
/// global parameters it consumes (in the order the artifact expects them).
#[derive(Clone, Debug, PartialEq)]
pub struct TaskSpec {
    /// Task name — matches the AOT artifact name (`norm`, `t1`.. `t7`).
    pub name: String,
    /// The external operation this task calls (paper Fig. 7:
    /// `nscale::segmentNucleiStg1` etc.; here the artifact id).
    pub lib_call: String,
    /// Indices into the canonical 15-parameter set.
    pub param_indices: Vec<usize>,
}

impl TaskSpec {
    pub fn new(name: &str, lib_call: &str, param_indices: Vec<usize>) -> Self {
        Self { name: name.into(), lib_call: lib_call.into(), param_indices }
    }

    /// Extract this task's parameter vector from a full parameter set.
    pub fn project(&self, set: &[f64]) -> Vec<f64> {
        self.param_indices.iter().map(|&i| set[i]).collect()
    }
}

/// A coarse-grain stage: an ordered list of tasks (linear dependency
/// chain within the stage, matching the segmentation pipeline).
#[derive(Clone, Debug, PartialEq)]
pub struct StageSpec {
    pub name: String,
    pub tasks: Vec<TaskSpec>,
}

impl StageSpec {
    pub fn new(name: &str, tasks: Vec<TaskSpec>) -> Self {
        Self { name: name.into(), tasks }
    }

    /// All global parameter indices any task of this stage consumes.
    pub fn param_indices(&self) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.tasks.iter().flat_map(|t| t.param_indices.iter().copied()).collect();
        v.sort();
        v.dedup();
        v
    }
}

/// A workflow: a linear chain of stages (normalization → segmentation →
/// comparison in the paper's application).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkflowSpec {
    pub name: String,
    pub stages: Vec<StageSpec>,
}

impl WorkflowSpec {
    pub fn new(name: &str, stages: Vec<StageSpec>) -> Self {
        Self { name: name.into(), stages }
    }

    pub fn stage(&self, name: &str) -> Result<&StageSpec> {
        self.stages
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| Error::Workflow(format!("unknown stage `{name}`")))
    }

    /// Total fine-grain tasks per evaluation.
    pub fn tasks_per_evaluation(&self) -> usize {
        self.stages.iter().map(|s| s.tasks.len()).sum()
    }

    /// Sanity checks: non-empty stages, unique task names, valid param
    /// indices for a space of dimension `dim`.
    pub fn validate(&self, dim: usize) -> Result<()> {
        if self.stages.is_empty() {
            return Err(Error::Workflow("workflow has no stages".into()));
        }
        let mut names = std::collections::HashSet::new();
        for s in &self.stages {
            if s.tasks.is_empty() {
                return Err(Error::Workflow(format!("stage `{}` has no tasks", s.name)));
            }
            for t in &s.tasks {
                if !names.insert(t.name.clone()) {
                    return Err(Error::Workflow(format!("duplicate task `{}`", t.name)));
                }
                if let Some(&bad) = t.param_indices.iter().find(|&&i| i >= dim) {
                    return Err(Error::Workflow(format!(
                        "task `{}` references parameter {bad} outside space dim {dim}",
                        t.name
                    )));
                }
            }
        }
        Ok(())
    }
}

/// The paper's microscopy workflow: a parameter-free normalization stage,
/// the 7-task segmentation stage carrying all 15 parameters of Table 1,
/// and the comparison stage (paper Fig. 1; task→parameter mapping in
/// DESIGN.md §2.1).
pub fn paper_workflow() -> WorkflowSpec {
    WorkflowSpec::new(
        "microscopy-segmentation",
        vec![
            StageSpec::new(
                "normalization",
                vec![TaskSpec::new("norm", "nscale::normalize", vec![])],
            ),
            StageSpec::new(
                "segmentation",
                vec![
                    TaskSpec::new(
                        "t1",
                        "nscale::segmentNucleiStg1",
                        vec![idx::B, idx::G, idx::R, idx::T1, idx::T2],
                    ),
                    TaskSpec::new("t2", "nscale::segmentNucleiStg2", vec![idx::G1, idx::RECON]),
                    TaskSpec::new("t3", "nscale::segmentNucleiStg3", vec![idx::FILL_HOLES]),
                    TaskSpec::new(
                        "t4",
                        "nscale::segmentNucleiStg4",
                        vec![idx::G2, idx::MIN_SIZE, idx::MAX_SIZE],
                    ),
                    TaskSpec::new("t5", "nscale::segmentNucleiStg5", vec![idx::MIN_SIZE_PL]),
                    TaskSpec::new("t6", "nscale::segmentNucleiStg6", vec![idx::WATERSHED]),
                    TaskSpec::new(
                        "t7",
                        "nscale::segmentNucleiStg7",
                        vec![idx::MIN_SIZE_SEG, idx::MAX_SIZE_SEG],
                    ),
                ],
            ),
            StageSpec::new("comparison", vec![TaskSpec::new("cmp", "nscale::diffMask", vec![])]),
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::default_space;

    #[test]
    fn paper_workflow_validates() {
        let wf = paper_workflow();
        wf.validate(default_space().dim()).unwrap();
        assert_eq!(wf.stages.len(), 3);
        assert_eq!(wf.tasks_per_evaluation(), 9);
        assert_eq!(wf.stage("segmentation").unwrap().tasks.len(), 7);
    }

    #[test]
    fn segmentation_covers_all_15_params() {
        let wf = paper_workflow();
        let covered = wf.stage("segmentation").unwrap().param_indices();
        assert_eq!(covered, (0..15).collect::<Vec<_>>());
    }

    #[test]
    fn project_extracts_in_task_order() {
        let wf = paper_workflow();
        let set: Vec<f64> = (0..15).map(|i| i as f64 * 10.0).collect();
        let t4 = &wf.stage("segmentation").unwrap().tasks[3];
        assert_eq!(t4.project(&set), vec![60.0, 70.0, 80.0]); // G2, minS, maxS
    }

    #[test]
    fn validate_catches_bad_param_index() {
        let wf = WorkflowSpec::new(
            "bad",
            vec![StageSpec::new("s", vec![TaskSpec::new("t", "x", vec![99])])],
        );
        assert!(wf.validate(15).is_err());
    }

    #[test]
    fn validate_catches_duplicate_tasks() {
        let wf = WorkflowSpec::new(
            "bad",
            vec![StageSpec::new(
                "s",
                vec![TaskSpec::new("t", "x", vec![]), TaskSpec::new("t", "y", vec![])],
            )],
        );
        assert!(wf.validate(15).is_err());
    }
}
