//! JSON stage-descriptor parsing (paper Fig. 7 / §3.1).
//!
//! The paper's GUI + code generator let domain experts define stages in a
//! descriptor file ("name", external library, inputs, tasks with their
//! argument lists) and compose workflows in Taverna. This module is the
//! runtime half of that generator: it turns descriptor JSON into
//! [`StageSpec`]s / [`WorkflowSpec`]s so new workflows can be deployed
//! without recompiling the framework.
//!
//! Example stage descriptor (same shape as the paper's Fig. 7):
//!
//! ```json
//! {
//!   "name": "segmentation",
//!   "lib": "nscale",
//!   "tasks": [
//!     {"call": "segmentNucleiStg1", "name": "t1",
//!      "args": ["B", "G", "R", "T1", "T2"]},
//!     {"call": "segmentNucleiStg2", "name": "t2", "args": ["G1", "reconConn"]}
//!   ]
//! }
//! ```

use crate::jsonx::Json;
use crate::sampling::ParamSpace;
use crate::{Error, Result};

use super::spec::{StageSpec, TaskSpec, WorkflowSpec};

/// Parse one stage descriptor object. Task `args` name parameters of
/// `space` (resolved to canonical indices); unknown names are an error.
pub fn parse_stage_descriptor(json: &Json, space: &ParamSpace) -> Result<StageSpec> {
    let name = json
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Workflow("stage descriptor: missing `name`".into()))?;
    let lib = json.get("lib").and_then(Json::as_str).unwrap_or("local");
    let tasks_json = json
        .get("tasks")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Workflow(format!("stage `{name}`: missing `tasks`")))?;
    if tasks_json.is_empty() {
        return Err(Error::Workflow(format!("stage `{name}`: empty `tasks`")));
    }
    let mut tasks = Vec::with_capacity(tasks_json.len());
    for (i, tj) in tasks_json.iter().enumerate() {
        let call = tj
            .get("call")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Workflow(format!("stage `{name}` task {i}: missing `call`")))?;
        let tname = tj
            .get("name")
            .and_then(Json::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("{name}.{i}"));
        let mut param_indices = Vec::new();
        if let Some(args) = tj.get("args").and_then(Json::as_arr) {
            for a in args {
                let pname = a.as_str().ok_or_else(|| {
                    Error::Workflow(format!("stage `{name}` task `{tname}`: non-string arg"))
                })?;
                param_indices.push(space.index_of(pname)?);
            }
        }
        tasks.push(TaskSpec::new(&tname, &format!("{lib}::{call}"), param_indices));
    }
    Ok(StageSpec::new(name, tasks))
}

/// Parse a workflow file: `{"name": ..., "stages": [<descriptor>, ...]}`
/// (the role the Taverna parser played in the paper).
pub fn parse_workflow_file(text: &str, space: &ParamSpace) -> Result<WorkflowSpec> {
    let json = Json::parse(text)?;
    let name = json.get("name").and_then(Json::as_str).unwrap_or("workflow");
    let stages_json = json
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Workflow("workflow file: missing `stages`".into()))?;
    let mut stages = Vec::with_capacity(stages_json.len());
    for sj in stages_json {
        stages.push(parse_stage_descriptor(sj, space)?);
    }
    let wf = WorkflowSpec::new(name, stages);
    wf.validate(space.dim())?;
    Ok(wf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::default_space;

    const DESCRIPTOR: &str = r#"
    {
      "name": "segmentation",
      "lib": "nscale",
      "tasks": [
        {"call": "segmentNucleiStg1", "name": "t1",
         "args": ["B", "G", "R", "T1", "T2"]},
        {"call": "segmentNucleiStg2", "name": "t2", "args": ["G1", "reconConn"]}
      ]
    }"#;

    #[test]
    fn parses_fig7_style_descriptor() {
        let space = default_space();
        let stage =
            parse_stage_descriptor(&Json::parse(DESCRIPTOR).unwrap(), &space).unwrap();
        assert_eq!(stage.name, "segmentation");
        assert_eq!(stage.tasks.len(), 2);
        assert_eq!(stage.tasks[0].lib_call, "nscale::segmentNucleiStg1");
        assert_eq!(stage.tasks[0].param_indices, vec![0, 1, 2, 3, 4]);
        assert_eq!(stage.tasks[1].param_indices, vec![5, 13]);
    }

    #[test]
    fn unknown_parameter_is_error() {
        let space = default_space();
        let bad = r#"{"name": "s", "tasks": [{"call": "c", "args": ["NOPE"]}]}"#;
        assert!(parse_stage_descriptor(&Json::parse(bad).unwrap(), &space).is_err());
    }

    #[test]
    fn workflow_file_roundtrip() {
        let space = default_space();
        let text = format!(
            r#"{{"name": "wf", "stages": [
                 {{"name": "norm", "lib": "nscale",
                   "tasks": [{{"call": "normalize", "name": "norm"}}]}},
                 {DESCRIPTOR}
               ]}}"#
        );
        let wf = parse_workflow_file(&text, &space).unwrap();
        assert_eq!(wf.stages.len(), 2);
        assert_eq!(wf.tasks_per_evaluation(), 3);
    }

    #[test]
    fn shipped_descriptor_matches_builtin_workflow() {
        // assets/workflows/microscopy.json is the paper workflow as a
        // Fig-7-style descriptor; parsing it must reproduce
        // `paper_workflow()` exactly.
        let path = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("assets/workflows/microscopy.json");
        let text = std::fs::read_to_string(path).unwrap();
        let space = default_space();
        let wf = parse_workflow_file(&text, &space).unwrap();
        assert_eq!(wf, crate::workflow::paper_workflow());
    }

    #[test]
    fn missing_tasks_is_error() {
        let space = default_space();
        assert!(parse_stage_descriptor(&Json::parse(r#"{"name":"s"}"#).unwrap(), &space).is_err());
        assert!(parse_stage_descriptor(
            &Json::parse(r#"{"name":"s","tasks":[]}"#).unwrap(),
            &space
        )
        .is_err());
    }
}
