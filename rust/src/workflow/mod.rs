//! Hierarchical workflow model (paper §2.3, §3.1).
//!
//! A workflow is a chain of coarse-grain **stages**, each composed of
//! fine-grain **tasks**. Stages are the unit of distribution (one stage
//! instance runs on one worker node); tasks are the unit of local
//! scheduling and of fine-grain reuse. Stages are described by JSON
//! descriptor files (paper Fig. 7) from which the task-based stage code
//! generator builds the executable workflow — here the descriptor parser
//! plus [`spec::paper_workflow`] play that role.

mod codegen;
mod descriptor;
mod instance;
mod spec;

pub use codegen::{generate_stage_code, generate_workflow_code};
pub use descriptor::{parse_stage_descriptor, parse_workflow_file};
pub use instance::{instantiate_study, sig_hash, str_bits, Evaluation, StageInstance, TaskInstance};
pub use spec::{paper_workflow, StageSpec, TaskSpec, WorkflowSpec};
