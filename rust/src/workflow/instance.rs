//! Workflow instantiation: parameter sets → stage/task instances.
//!
//! This is where reuse becomes *visible*: every task instance carries a
//! signature (task identity + its own parameter values), every stage
//! instance carries its input signature (chained from the upstream stage)
//! and a full signature. Two task executions are interchangeable exactly
//! when their stage input signatures and task-signature *prefixes* match;
//! two stage instances are interchangeable when their full signatures
//! match (coarse-grain reuse, Algorithm 1).

use crate::sampling::ParamSet;

use super::spec::WorkflowSpec;

/// FNV-1a 64-bit over a byte stream — stable, dependency-free hashing for
/// reuse signatures.
pub fn sig_hash(parts: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for p in parts {
        for b in p.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// FNV signature of a string (shared with the cross-study cache keys).
pub fn str_bits(s: &str) -> u64 {
    sig_hash(&s.bytes().map(|b| b as u64).collect::<Vec<_>>())
}

/// One requested workflow run: a tile and a full parameter set.
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub id: usize,
    pub tile: u64,
    pub params: ParamSet,
}

/// A fine-grain task instance inside a stage instance.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskInstance {
    pub name: String,
    pub lib_call: String,
    /// This task's own parameter values (artifact argument order).
    pub params: Vec<f64>,
    /// Signature of (task identity, params). Reuse of the task requires
    /// equal signatures *and* an equal upstream prefix.
    pub sig: u64,
}

/// A coarse-grain stage instance.
#[derive(Clone, Debug)]
pub struct StageInstance {
    /// Globally unique instance id (index into the study's instance list).
    pub id: usize,
    /// Evaluation this instance belongs to.
    pub eval: usize,
    pub stage: String,
    /// Position of the stage in the workflow chain.
    pub stage_idx: usize,
    pub tile: u64,
    pub tasks: Vec<TaskInstance>,
    /// Signature of the stage's input (tile for the first stage, the
    /// upstream stage's `full_sig` otherwise).
    pub input_sig: u64,
    /// Signature of (stage identity, input, all task sigs) — the
    /// coarse-grain reuse key.
    pub full_sig: u64,
}

impl StageInstance {
    /// The reuse-tree path of this instance: task signatures level by
    /// level. Instances with equal `input_sig` share (and may reuse) any
    /// common prefix of this path.
    pub fn task_path(&self) -> Vec<u64> {
        self.tasks.iter().map(|t| t.sig).collect()
    }
}

/// Instantiate every stage of every evaluation. Returns instances grouped
/// in evaluation-major order (eval 0's stages, then eval 1's, ...).
pub fn instantiate_study(wf: &WorkflowSpec, evals: &[Evaluation]) -> Vec<StageInstance> {
    let mut out = Vec::with_capacity(evals.len() * wf.stages.len());
    for ev in evals {
        let mut upstream = sig_hash(&[0x7469_6c65, ev.tile]); // "tile"
        for (stage_idx, s) in wf.stages.iter().enumerate() {
            let tasks: Vec<TaskInstance> = s
                .tasks
                .iter()
                .map(|t| {
                    let params = t.project(&ev.params);
                    let mut parts = vec![str_bits(&t.name), str_bits(&t.lib_call)];
                    parts.extend(params.iter().map(|v| v.to_bits()));
                    TaskInstance {
                        name: t.name.clone(),
                        lib_call: t.lib_call.clone(),
                        params,
                        sig: sig_hash(&parts),
                    }
                })
                .collect();
            let mut parts = vec![str_bits(&s.name), upstream];
            parts.extend(tasks.iter().map(|t| t.sig));
            let full_sig = sig_hash(&parts);
            out.push(StageInstance {
                id: out.len(),
                eval: ev.id,
                stage: s.name.clone(),
                stage_idx,
                tile: ev.tile,
                tasks,
                input_sig: upstream,
                full_sig,
            });
            upstream = full_sig;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sampling::default_space;
    use crate::workflow::paper_workflow;

    fn evals(param_sets: Vec<ParamSet>) -> Vec<Evaluation> {
        param_sets
            .into_iter()
            .enumerate()
            .map(|(id, params)| Evaluation { id, tile: 0, params })
            .collect()
    }

    #[test]
    fn instance_count_and_chaining() {
        let wf = paper_workflow();
        let space = default_space();
        let insts = instantiate_study(&wf, &evals(vec![space.defaults(), space.defaults()]));
        assert_eq!(insts.len(), 6); // 2 evals x 3 stages
        // chain: each stage's input is the upstream full signature
        assert_eq!(insts[1].input_sig, insts[0].full_sig);
        assert_eq!(insts[2].input_sig, insts[1].full_sig);
        // identical evaluations produce identical signatures
        assert_eq!(insts[0].full_sig, insts[3].full_sig);
        assert_eq!(insts[2].full_sig, insts[5].full_sig);
    }

    #[test]
    fn norm_stage_reusable_across_different_params() {
        let wf = paper_workflow();
        let space = default_space();
        let mut p2 = space.defaults();
        p2[5] = 80.0; // G1
        let insts = instantiate_study(&wf, &evals(vec![space.defaults(), p2]));
        // normalization has no parameters: both instances identical
        assert_eq!(insts[0].full_sig, insts[3].full_sig);
        // segmentation differs
        assert_ne!(insts[1].full_sig, insts[4].full_sig);
        // and so does comparison (depends on segmentation output)
        assert_ne!(insts[2].full_sig, insts[5].full_sig);
    }

    #[test]
    fn task_prefix_reflects_changed_parameter() {
        let wf = paper_workflow();
        let space = default_space();
        let mut p2 = space.defaults();
        p2[9] = 80.0; // minSizePl — consumed by t5
        let insts = instantiate_study(&wf, &evals(vec![space.defaults(), p2]));
        let a = insts[1].task_path();
        let b = insts[4].task_path();
        assert_eq!(a[..4], b[..4], "t1..t4 unchanged");
        assert_ne!(a[4], b[4], "t5 differs");
        assert_eq!(a[5..], b[5..], "t6/t7 signatures equal (same own params)");
    }

    #[test]
    fn different_tiles_never_share_input_sig() {
        let wf = paper_workflow();
        let space = default_space();
        let mut ev = evals(vec![space.defaults(), space.defaults()]);
        ev[1].tile = 7;
        let insts = instantiate_study(&wf, &ev);
        assert_ne!(insts[0].input_sig, insts[3].input_sig);
        assert_ne!(insts[0].full_sig, insts[3].full_sig);
    }

    #[test]
    fn sig_hash_is_stable_and_sensitive() {
        let a = sig_hash(&[1, 2, 3]);
        assert_eq!(a, sig_hash(&[1, 2, 3]));
        assert_ne!(a, sig_hash(&[1, 2, 4]));
        assert_ne!(a, sig_hash(&[3, 2, 1]));
        assert_ne!(sig_hash(&[]), sig_hash(&[0]));
    }
}
