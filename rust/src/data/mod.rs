//! Region-template data layer.
//!
//! The paper's RTF interchanges data between stages as *region templates*
//! containing *data regions* (2-D planes here). This module provides the
//! plane type the PJRT runtime transfers, the region-template container
//! with its pluggable storage levels, and the deterministic synthetic
//! tissue-tile generator that substitutes for the paper's proprietary
//! whole-slide images (see DESIGN.md §Substitutions).

mod plane;
mod region;
pub(crate) mod synth;

pub use plane::Plane;
pub use region::{DataRegion, RegionTemplate, StorageKind, StorageStats};
pub use synth::{synth_tile, SplitMix64, SynthConfig, TileSet};
