//! Region-template data abstraction (paper §2.3).
//!
//! A [`RegionTemplate`] is a container for a spatially/temporally bounded
//! region; its [`DataRegion`]s are the storage materializations that
//! stages consume and produce. The RTF delegates placement to the storage
//! layer — here two levels are modeled: in-memory and disk-spill (the
//! paper used node RAM + a cluster file system). The coordinator moves
//! regions between stages through this layer, never by direct
//! stage-to-stage transfer.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::{Error, Result};

use super::Plane;

/// Where a data region currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StorageKind {
    /// Node-local RAM.
    Memory,
    /// Spilled to the shared file system (Lustre/Pylon in the paper).
    Disk,
}

/// A named, versioned 2-D data region.
#[derive(Debug)]
pub struct DataRegion {
    pub name: String,
    /// Version tag: output of which parameter-set evaluation.
    pub version: u64,
    storage: RegionStorage,
}

#[derive(Debug)]
enum RegionStorage {
    Memory(Plane),
    Disk { path: PathBuf, height: usize, width: usize },
}

impl DataRegion {
    /// Create an in-memory region.
    pub fn in_memory(name: impl Into<String>, version: u64, plane: Plane) -> Self {
        Self { name: name.into(), version, storage: RegionStorage::Memory(plane) }
    }

    pub fn kind(&self) -> StorageKind {
        match self.storage {
            RegionStorage::Memory(_) => StorageKind::Memory,
            RegionStorage::Disk { .. } => StorageKind::Disk,
        }
    }

    /// Bytes resident in RAM for this region.
    pub fn resident_bytes(&self) -> usize {
        match &self.storage {
            RegionStorage::Memory(p) => p.nbytes(),
            RegionStorage::Disk { .. } => 0,
        }
    }

    /// Spill the region to `dir`, freeing RAM.
    pub fn spill(&mut self, dir: &std::path::Path) -> Result<()> {
        if let RegionStorage::Memory(plane) = &self.storage {
            std::fs::create_dir_all(dir)?;
            let path = dir.join(format!("{}-v{}.bin", self.name.replace('/', "_"), self.version));
            let bytes: Vec<u8> = plane.data().iter().flat_map(|v| v.to_le_bytes()).collect();
            std::fs::write(&path, bytes)?;
            self.storage =
                RegionStorage::Disk { path, height: plane.height(), width: plane.width() };
        }
        Ok(())
    }

    /// Materialize the region back into RAM (reads from disk if spilled).
    pub fn fetch(&mut self) -> Result<&Plane> {
        if let RegionStorage::Disk { path, height, width } = &self.storage {
            let bytes = std::fs::read(path)?;
            if bytes.len() != height * width * 4 {
                return Err(Error::Workflow(format!(
                    "spilled region {} has {} bytes, want {}",
                    self.name,
                    bytes.len(),
                    height * width * 4
                )));
            }
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let plane = Plane::new(data, *height, *width)?;
            self.storage = RegionStorage::Memory(plane);
        }
        match &self.storage {
            RegionStorage::Memory(p) => Ok(p),
            RegionStorage::Disk { .. } => unreachable!(),
        }
    }

    /// Borrow the plane if resident in memory.
    pub fn plane(&self) -> Option<&Plane> {
        match &self.storage {
            RegionStorage::Memory(p) => Some(p),
            RegionStorage::Disk { .. } => None,
        }
    }
}

/// Aggregate statistics over a region template's storage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StorageStats {
    pub regions: usize,
    pub resident_bytes: usize,
    pub spilled_regions: usize,
}

/// Container of data regions keyed by name (paper: one RT instance may
/// hold multiple data regions).
#[derive(Debug, Default)]
pub struct RegionTemplate {
    regions: HashMap<String, DataRegion>,
}

impl RegionTemplate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert (or replace) a region.
    pub fn insert(&mut self, region: DataRegion) {
        self.regions.insert(region.name.clone(), region);
    }

    pub fn get(&self, name: &str) -> Option<&DataRegion> {
        self.regions.get(name)
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut DataRegion> {
        self.regions.get_mut(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<DataRegion> {
        self.regions.remove(name)
    }

    pub fn stats(&self) -> StorageStats {
        StorageStats {
            regions: self.regions.len(),
            resident_bytes: self.regions.values().map(|r| r.resident_bytes()).sum(),
            spilled_regions: self
                .regions
                .values()
                .filter(|r| r.kind() == StorageKind::Disk)
                .count(),
        }
    }

    /// Spill every resident region larger than `threshold_bytes`.
    pub fn spill_over(&mut self, threshold_bytes: usize, dir: &std::path::Path) -> Result<usize> {
        let mut spilled = 0;
        for r in self.regions.values_mut() {
            if r.resident_bytes() > threshold_bytes {
                r.spill(dir)?;
                spilled += 1;
            }
        }
        Ok(spilled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plane() -> Plane {
        Plane::new((0..12).map(|i| i as f32).collect(), 3, 4).unwrap()
    }

    #[test]
    fn memory_region_roundtrip() {
        let mut rt = RegionTemplate::new();
        rt.insert(DataRegion::in_memory("seg/mask", 3, plane()));
        assert_eq!(rt.get("seg/mask").unwrap().version, 3);
        assert_eq!(rt.stats().regions, 1);
        assert_eq!(rt.stats().resident_bytes, 48);
    }

    #[test]
    fn spill_and_fetch_roundtrip() {
        let dir = std::env::temp_dir().join("rtf_reuse_test_spill");
        let mut region = DataRegion::in_memory("x", 0, plane());
        region.spill(&dir).unwrap();
        assert_eq!(region.kind(), StorageKind::Disk);
        assert_eq!(region.resident_bytes(), 0);
        let p = region.fetch().unwrap();
        assert_eq!(p.get(2, 3), 11.0);
        assert_eq!(region.kind(), StorageKind::Memory);
    }

    #[test]
    fn spill_over_threshold() {
        let dir = std::env::temp_dir().join("rtf_reuse_test_spill2");
        let mut rt = RegionTemplate::new();
        rt.insert(DataRegion::in_memory("big", 0, Plane::zeros(64, 64)));
        rt.insert(DataRegion::in_memory("small", 0, Plane::zeros(2, 2)));
        let n = rt.spill_over(1024, &dir).unwrap();
        assert_eq!(n, 1);
        let stats = rt.stats();
        assert_eq!(stats.spilled_regions, 1);
        assert_eq!(stats.resident_bytes, 16);
    }
}
