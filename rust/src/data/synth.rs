//! Deterministic synthetic tissue-tile generator.
//!
//! Substitutes for the paper's brain-cancer WSIs (4K×4K tiles from TCGA
//! studies): bright eosin-ish background, dark hematoxylin-stained nuclei
//! with per-nucleus stain intensity (so the G1/G2 prominence thresholds
//! are discriminating), strongly-red RBC discs with per-disc redness (so
//! T1/T2 are discriminating), a 2-px blur skirt (so thresholds see
//! gradients, not step edges) and Gaussian noise. The python test fixture
//! (`python/tests/conftest.py`) mirrors this recipe.
//!
//! Randomness is a self-contained SplitMix64 so tiles are reproducible
//! across runs and across the python/rust boundary is *not* required —
//! the reference mask is always computed by this same pipeline with
//! default parameters (as in the paper).

use super::Plane;

/// SplitMix64 PRNG — deterministic, dependency-free.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        crate::testutil::splitmix64(&mut self.state)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [lo, hi).
    pub fn uniform_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.next_u64() % (hi - lo).max(1) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub height: usize,
    pub width: usize,
    /// nuclei per pixel-area (paper tiles average ~100 nuclei / 4K tile
    /// region; the default reproduces a similar density at small sizes)
    pub nuclei_per_px: f64,
    pub noise_sigma: f64,
    pub seed: u64,
}

impl SynthConfig {
    pub fn new(height: usize, width: usize, seed: u64) -> Self {
        Self { height, width, nuclei_per_px: 1.0 / 700.0, noise_sigma: 2.0, seed }
    }
}

/// The three raw channel planes of one synthetic tile.
#[derive(Clone, Debug)]
pub struct TileSet {
    pub r: Plane,
    pub g: Plane,
    pub b: Plane,
}

fn blur3(x: &Plane) -> Plane {
    let (h, w) = (x.height(), x.width());
    let mut out = Plane::zeros(h, w);
    for y in 0..h {
        for xx in 0..w {
            let mut acc = 0.0f32;
            for dy in -1i64..=1 {
                for dx in -1i64..=1 {
                    // edge replication
                    let sy = (y as i64 + dy).clamp(0, h as i64 - 1) as usize;
                    let sx = (xx as i64 + dx).clamp(0, w as i64 - 1) as usize;
                    acc += x.get(sy, sx);
                }
            }
            out.set(y, xx, acc / 9.0);
        }
    }
    out
}

/// Generate one synthetic tissue tile.
pub fn synth_tile(cfg: &SynthConfig) -> TileSet {
    let (h, w) = (cfg.height, cfg.width);
    let mut rng = SplitMix64::new(cfg.seed);
    let mut r = Plane::filled(230.0, h, w);
    let mut g = Plane::filled(225.0, h, w);
    let mut b = Plane::filled(228.0, h, w);

    let n_nuclei = ((h * w) as f64 * cfg.nuclei_per_px).max(3.0) as usize;
    let max_rad = (h.min(w) / 10).max(4);
    for _ in 0..n_nuclei {
        let cy = rng.uniform_usize(4, h.saturating_sub(4).max(5));
        let cx = rng.uniform_usize(4, w.saturating_sub(4).max(5));
        let rad = rng.uniform_usize(3, max_rad) as i64;
        let stain = rng.uniform(0.05, 1.0) as f32;
        paint_disc(&mut r, cy, cx, rad, 120.0, stain);
        paint_disc(&mut g, cy, cx, rad, 90.0, stain);
        paint_disc(&mut b, cy, cx, rad, 160.0, stain);
    }
    for _ in 0..(n_nuclei / 4).max(1) {
        let cy = rng.uniform_usize(3, h.saturating_sub(3).max(4));
        let cx = rng.uniform_usize(3, w.saturating_sub(3).max(4));
        let redness = rng.uniform(0.6, 1.0) as f32;
        set_disc(&mut r, cy, cx, 3, 140.0 + 70.0 * redness);
        set_disc(&mut g, cy, cx, 3, 90.0 - 55.0 * redness);
        set_disc(&mut b, cy, cx, 3, 90.0 - 55.0 * redness);
    }

    let mut planes = [r, g, b];
    for p in planes.iter_mut() {
        let blurred = blur3(&blur3(p));
        *p = blurred;
        for v in p.data_mut() {
            *v = (*v + (rng.normal() * cfg.noise_sigma) as f32).clamp(0.0, 255.0);
        }
    }
    let [r, g, b] = planes;
    TileSet { r, g, b }
}

fn paint_disc(p: &mut Plane, cy: usize, cx: usize, rad: i64, dark: f32, stain: f32) {
    for_disc(p, cy, cx, rad, |v| v + (dark - v) * stain);
}

fn set_disc(p: &mut Plane, cy: usize, cx: usize, rad: i64, value: f32) {
    for_disc(p, cy, cx, rad, |_| value);
}

fn for_disc(p: &mut Plane, cy: usize, cx: usize, rad: i64, f: impl Fn(f32) -> f32) {
    let (h, w) = (p.height() as i64, p.width() as i64);
    let (cy, cx) = (cy as i64, cx as i64);
    for y in (cy - rad).max(0)..=(cy + rad).min(h - 1) {
        for x in (cx - rad).max(0)..=(cx + rad).min(w - 1) {
            if (y - cy) * (y - cy) + (x - cx) * (x - cx) <= rad * rad {
                let v = p.get(y as usize, x as usize);
                p.set(y as usize, x as usize, f(v));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let cfg = SynthConfig::new(32, 32, 42);
        let a = synth_tile(&cfg);
        let b = synth_tile(&cfg);
        assert_eq!(a.r, b.r);
        assert_eq!(a.g, b.g);
        assert_eq!(a.b, b.b);
    }

    #[test]
    fn different_seed_differs() {
        let a = synth_tile(&SynthConfig::new(32, 32, 1));
        let b = synth_tile(&SynthConfig::new(32, 32, 2));
        assert_ne!(a.r, b.r);
    }

    #[test]
    fn contains_background_and_nuclei() {
        let t = synth_tile(&SynthConfig::new(64, 64, 7));
        // background stays bright, nuclei are dark: wide dynamic range
        let bright = t.r.count_above(200.0);
        let dark = t.r.data().iter().filter(|&&v| v < 160.0).count();
        assert!(bright > 64 * 64 / 2, "background dominates");
        assert!(dark > 20, "some dark nuclei pixels exist: {dark}");
    }

    #[test]
    fn values_clamped() {
        let t = synth_tile(&SynthConfig::new(48, 48, 3));
        for p in [&t.r, &t.g, &t.b] {
            assert!(p.data().iter().all(|&v| (0.0..=255.0).contains(&v)));
        }
    }

    #[test]
    fn splitmix_uniform_range() {
        let mut rng = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = rng.uniform(2.0, 3.0);
            assert!((2.0..3.0).contains(&v));
            let u = rng.uniform_usize(5, 10);
            assert!((5..10).contains(&u));
        }
    }

    #[test]
    fn splitmix_normal_moments() {
        let mut rng = SplitMix64::new(11);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
