//! A 2-D `f32` image plane — the unit of data the task artifacts consume
//! and produce (three planes of state flow through the segmentation
//! chain; see `python/compile/model.py`).

use crate::{Error, Result};

/// Row-major 2-D `f32` array.
#[derive(Clone, Debug, PartialEq)]
pub struct Plane {
    data: Vec<f32>,
    height: usize,
    width: usize,
}

impl Plane {
    /// Create a plane from row-major data.
    pub fn new(data: Vec<f32>, height: usize, width: usize) -> Result<Self> {
        if data.len() != height * width {
            return Err(Error::Workflow(format!(
                "plane data length {} != {height}x{width}",
                data.len()
            )));
        }
        Ok(Self { data, height, width })
    }

    /// A plane filled with a constant value.
    pub fn filled(value: f32, height: usize, width: usize) -> Self {
        Self { data: vec![value; height * width], height, width }
    }

    /// A zeroed plane.
    pub fn zeros(height: usize, width: usize) -> Self {
        Self::filled(0.0, height, width)
    }

    pub fn height(&self) -> usize {
        self.height
    }

    pub fn width(&self) -> usize {
        self.width
    }

    /// Row-major backing slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major backing slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Pixel accessor (row, col).
    pub fn get(&self, y: usize, x: usize) -> f32 {
        self.data[y * self.width + x]
    }

    /// Pixel mutator (row, col).
    pub fn set(&mut self, y: usize, x: usize, v: f32) {
        self.data[y * self.width + x] = v;
    }

    /// Sum of all pixels.
    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&v| v as f64).sum()
    }

    /// Number of pixels strictly above `thr`.
    pub fn count_above(&self, thr: f32) -> usize {
        self.data.iter().filter(|&&v| v > thr).count()
    }

    /// Mean pixel value.
    pub fn mean(&self) -> f64 {
        self.sum() / (self.data.len().max(1) as f64)
    }

    /// In-memory size in bytes (for storage accounting / MaxBucketSize
    /// memory-pressure reasoning).
    pub fn nbytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_length() {
        assert!(Plane::new(vec![0.0; 6], 2, 3).is_ok());
        assert!(Plane::new(vec![0.0; 5], 2, 3).is_err());
    }

    #[test]
    fn accessors_roundtrip() {
        let mut p = Plane::zeros(3, 4);
        p.set(2, 1, 7.5);
        assert_eq!(p.get(2, 1), 7.5);
        assert_eq!(p.data()[2 * 4 + 1], 7.5);
        assert_eq!(p.sum(), 7.5);
        assert_eq!(p.count_above(7.0), 1);
        assert_eq!(p.nbytes(), 48);
    }

    #[test]
    fn filled_and_mean() {
        let p = Plane::filled(2.0, 4, 4);
        assert_eq!(p.mean(), 2.0);
        assert_eq!(p.count_above(1.0), 16);
    }
}
