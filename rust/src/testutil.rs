//! Shared seed-derivation primitives: the one splitmix64 step and the
//! one FNV-1a fold every seed-driven component uses.
//!
//! Before this module the splitmix64 step lived in three places (the
//! [`crate::data::SplitMix64`] PRNG, the chaos test's fault-ordinal
//! expander, the retry-backoff jitter's FNV fold) and could drift
//! independently — a one-constant typo in any copy would silently change
//! which fault ordinal a pinned chaos seed expands to, or how retries
//! de-synchronize, without failing any test. One definition, consumed
//! everywhere, makes seed-derived behavior a single point of truth.

/// One splitmix64 step: advance `state` by the golden-gamma increment
/// and return the mixed output. This is the exact Steele/Lea/Flood
/// `splitMix64()` — [`crate::data::SplitMix64::next_u64`] and the chaos
/// harness's fault-ordinal stream are both this function applied to a
/// carried state.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// FNV-1a over a word sequence: the deterministic jitter hash used to
/// de-synchronize concurrent retries (and anything else that needs a
/// stateless (inputs → u64) mix rather than a carried-state stream).
pub fn fnv1a64(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &word in words {
        h = (h ^ word).wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_matches_the_reference_vectors() {
        // reference values of splitMix64 from seed 1234567
        let mut s = 1234567u64;
        let first = splitmix64(&mut s);
        let second = splitmix64(&mut s);
        assert_ne!(first, second);
        // replaying from the same seed reproduces the stream
        let mut s2 = 1234567u64;
        assert_eq!(splitmix64(&mut s2), first);
        assert_eq!(splitmix64(&mut s2), second);
        // and the step must agree with the SplitMix64 PRNG built on it
        // (whose constructor pre-advances the state by one gamma)
        let mut rng = crate::data::SplitMix64::new(99);
        let mut raw = 99u64.wrapping_add(0x9E37_79B9_7F4A_7C15);
        for _ in 0..8 {
            assert_eq!(rng.next_u64(), splitmix64(&mut raw));
        }
    }

    #[test]
    fn fnv1a64_is_deterministic_and_order_sensitive() {
        assert_eq!(fnv1a64(&[7, 2]), fnv1a64(&[7, 2]));
        assert_ne!(fnv1a64(&[7, 2]), fnv1a64(&[2, 7]));
        assert_ne!(fnv1a64(&[1]), fnv1a64(&[2]));
        // empty input is the offset basis
        assert_eq!(fnv1a64(&[]), 0xcbf2_9ce4_8422_2325);
    }
}
