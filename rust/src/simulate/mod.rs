//! Discrete-event cluster simulator (paper Figs 22/23, Table 5).
//!
//! The paper's 8–256-worker scaling experiments ran on TACC Stampede;
//! here the same [`crate::merging::StudyPlan`]s drive a demand-driven
//! manager/worker simulation whose per-task costs come from a cost model
//! measured on the real PJRT execution (Table-6 analog). The scheduling
//! policy is exactly the RTF's: workers request the next ready schedule
//! unit whenever idle; a unit occupies one worker for the sum of its
//! unique task costs.
//!
//! Because reuse fraction, makespan and load balance are functions of the
//! merge plan plus the task-cost distribution — not of Infiniband — the
//! paper's who-wins/crossover shapes are preserved (DESIGN.md
//! §Substitutions).

mod cost;
mod des;
mod pats;

pub use cost::{default_cost_model, CostModel};
pub use des::{simulate_plan, SimOptions, SimReport};
pub use pats::{hetero_unit_makespan, DeviceModel, SchedulePolicy};
