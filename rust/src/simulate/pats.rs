//! PATS — Performance-Aware Task Scheduling (paper §2.3, refs [27, 35-39]).
//!
//! The RTF's worker nodes are hybrid (CPU cores + accelerators: GPUs on
//! Keeneland, Xeon Phi on Stampede). Tasks attain *different* speedups
//! on the accelerator — the irregular-wavefront tasks (t2, t6)
//! accelerate well, the threshold filters barely. PATS assigns each
//! ready task to a device class based on its estimated acceleration and
//! the current device load: when an accelerator frees up it takes the
//! ready task with the **highest** speedup; a CPU core takes the one
//! with the **lowest** — so scarce accelerator cycles go where they pay.
//!
//! This module simulates one schedule unit's reuse tree on such a node,
//! either with PATS or with plain FCFS assignment (the ablation
//! baseline the PATS papers compare against).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashMap;

use crate::merging::reuse_tree::ReuseTree;
use crate::merging::{CompactGraph, MergeStage, ScheduleUnit};
use crate::simulate::CostModel;
use crate::workflow::StageInstance;

/// A hybrid worker node: CPU cores plus accelerator slots with
/// per-task-name speedups (relative to one CPU core).
#[derive(Clone, Debug)]
pub struct DeviceModel {
    pub cpu_cores: usize,
    pub accelerators: usize,
    /// Task name → accelerator speedup (≥ 1 accelerates, < 1 slows
    /// down; missing = 1.0, i.e. no benefit).
    pub speedup: HashMap<String, f64>,
}

impl DeviceModel {
    pub fn new(cpu_cores: usize, accelerators: usize) -> Self {
        Self { cpu_cores: cpu_cores.max(1), accelerators, speedup: HashMap::new() }
    }

    pub fn with_speedup(mut self, task: &str, s: f64) -> Self {
        self.speedup.insert(task.to_string(), s);
        self
    }

    /// Accelerator speedup for `task`.
    pub fn speedup_of(&self, task: &str) -> f64 {
        self.speedup.get(task).copied().unwrap_or(1.0)
    }

    /// The paper's application profile: the irregular-wavefront
    /// operators accelerate strongly (refs [37, 39] report 7–15× for
    /// reconstruction/watershed on GPUs), elementwise thresholds
    /// moderately, area filters barely.
    pub fn paper_profile(cpu_cores: usize, accelerators: usize) -> Self {
        let mut m = Self::new(cpu_cores, accelerators);
        for (t, s) in [
            ("norm", 4.0),
            ("t1", 3.0),
            ("t2", 9.0),
            ("t3", 6.0),
            ("t4", 1.5),
            ("t5", 5.0),
            ("t6", 11.0),
            ("t7", 1.5),
            ("cmp", 2.0),
        ] {
            m.speedup.insert(t.to_string(), s);
        }
        m
    }
}

/// Task-to-device assignment policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Speedup-aware: accelerators take the highest-speedup ready task,
    /// CPUs the lowest (the PATS policy).
    Pats,
    /// First-come-first-served: any free device takes the oldest ready
    /// task (the baseline PATS is compared against).
    Fcfs,
}

/// Makespan of one schedule unit's reuse tree on a hybrid node.
///
/// Tree task nodes become ready when their parent finishes; each runs
/// on one CPU core (cost) or one accelerator (cost / speedup).
pub fn hetero_unit_makespan(
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    model: &CostModel,
    devices: &DeviceModel,
    policy: SchedulePolicy,
) -> f64 {
    let stages: Vec<MergeStage> = unit
        .nodes
        .iter()
        .map(|&n| MergeStage::new(n, instances[graph.nodes[n].rep].task_path()))
        .collect();
    let rep = &instances[graph.nodes[unit.nodes[0]].rep];
    let tree = ReuseTree::build(&stages);
    let is_task = |id: usize| id != tree.root && !tree.nodes[id].is_leaf();

    // per-node base cost and accelerator speedup
    let mut cost = vec![0.0f64; tree.nodes.len()];
    let mut accel = vec![1.0f64; tree.nodes.len()];
    for (id, node) in tree.nodes.iter().enumerate() {
        if !is_task(id) {
            continue;
        }
        let name = &rep.tasks[node.level - 1].name;
        cost[id] = model.cost_of(name);
        accel[id] = devices.speedup_of(name);
    }

    // ready list: (arrival order, node)
    let mut ready: Vec<(usize, usize)> = Vec::new();
    let mut arrival = 0usize;
    for &c in &tree.nodes[tree.root].children {
        if is_task(c) {
            ready.push((arrival, c));
            arrival += 1;
        }
    }
    let mut events: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let to_ns = |s: f64| (s * 1e9).round() as u64;
    let mut idle_cpu = devices.cpu_cores;
    let mut idle_acc = devices.accelerators;
    let mut now = 0.0f64;
    let n_tasks = (0..tree.nodes.len()).filter(|&i| is_task(i)).count();
    let mut done = 0usize;

    while done < n_tasks {
        // dispatch while any device is free and work is ready
        while !ready.is_empty() && (idle_cpu > 0 || idle_acc > 0) {
            let pick = match policy {
                SchedulePolicy::Fcfs => {
                    // oldest task, first free device class (accel first)
                    let i = ready
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, &(a, _))| a)
                        .map(|(i, _)| i)
                        .unwrap();
                    let (_, node) = ready.swap_remove(i);
                    let on_acc = idle_acc > 0;
                    (node, on_acc)
                }
                SchedulePolicy::Pats => {
                    if idle_acc > 0 {
                        // accelerator takes the highest-speedup task
                        let i = ready
                            .iter()
                            .enumerate()
                            .max_by(|(_, &(_, a)), (_, &(_, b))| {
                                accel[a].partial_cmp(&accel[b]).unwrap()
                            })
                            .map(|(i, _)| i)
                            .unwrap();
                        let (_, node) = ready.swap_remove(i);
                        (node, true)
                    } else {
                        // CPU takes the lowest-speedup task
                        let i = ready
                            .iter()
                            .enumerate()
                            .min_by(|(_, &(_, a)), (_, &(_, b))| {
                                accel[a].partial_cmp(&accel[b]).unwrap()
                            })
                            .map(|(i, _)| i)
                            .unwrap();
                        let (_, node) = ready.swap_remove(i);
                        (node, false)
                    }
                }
            };
            let (node, on_acc) = pick;
            let dur = if on_acc {
                idle_acc -= 1;
                cost[node] / accel[node].max(1e-9)
            } else {
                idle_cpu -= 1;
                cost[node]
            };
            // encode device class in the event (bit 0 of a side flag)
            events.push(Reverse((to_ns(now + dur), node * 2 + on_acc as usize)));
        }
        let Some(Reverse((t_ns, packed))) = events.pop() else {
            unreachable!("hetero schedule stalled");
        };
        now = t_ns as f64 / 1e9;
        let node = packed / 2;
        if packed % 2 == 1 {
            idle_acc += 1;
        } else {
            idle_cpu += 1;
        }
        done += 1;
        for &c in &tree.nodes[node].children {
            if is_task(c) {
                ready.push((arrival, c));
                arrival += 1;
            }
        }
    }
    now
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SaMethod, StudyConfig};
    use crate::driver::prepare;
    use crate::merging::FineAlgorithm;
    use crate::simulate::default_cost_model;

    fn seg_units() -> (crate::merging::StudyPlan, crate::driver::PreparedStudy) {
        let cfg = StudyConfig {
            method: SaMethod::Moat { r: 4 },
            algorithm: FineAlgorithm::Rtma(7),
            ..StudyConfig::default()
        };
        let p = prepare(&cfg);
        let plan = p.plan(&cfg);
        (plan, p)
    }

    #[test]
    fn pats_never_slower_than_fcfs_on_merged_units() {
        let (plan, p) = seg_units();
        let model = default_cost_model();
        let devices = DeviceModel::paper_profile(4, 1);
        let mut compared = 0;
        for u in plan.units.iter().filter(|u| u.nodes.len() >= 3) {
            let pats = hetero_unit_makespan(
                u, &p.graph, &p.instances, &model, &devices, SchedulePolicy::Pats,
            );
            let fcfs = hetero_unit_makespan(
                u, &p.graph, &p.instances, &model, &devices, SchedulePolicy::Fcfs,
            );
            assert!(pats <= fcfs * 1.3 + 1e-9, "pats {pats} vs fcfs {fcfs}");
            compared += 1;
        }
        assert!(compared > 0, "need merged units to compare");
    }

    #[test]
    fn accelerator_helps_wavefront_heavy_units() {
        let (plan, p) = seg_units();
        let model = default_cost_model();
        let cpu_only = DeviceModel::new(4, 0);
        let hybrid = DeviceModel::paper_profile(4, 2);
        let u = plan
            .units
            .iter()
            .max_by_key(|u| u.task_cost)
            .expect("some unit");
        let base = hetero_unit_makespan(
            u, &p.graph, &p.instances, &model, &cpu_only, SchedulePolicy::Pats,
        );
        let acc = hetero_unit_makespan(
            u, &p.graph, &p.instances, &model, &hybrid, SchedulePolicy::Pats,
        );
        assert!(acc < base, "accelerators must help: {acc} vs {base}");
    }

    #[test]
    fn single_cpu_equals_serial_cost_sum() {
        let (plan, p) = seg_units();
        let model = default_cost_model();
        let one = DeviceModel::new(1, 0);
        for u in plan.units.iter().take(5) {
            let mk = hetero_unit_makespan(
                u, &p.graph, &p.instances, &model, &one, SchedulePolicy::Fcfs,
            );
            // serial sum of unique task costs (compare via weighted trie)
            let stages: Vec<MergeStage> = u
                .nodes
                .iter()
                .map(|&n| MergeStage::new(n, p.instances[p.graph.nodes[n].rep].task_path()))
                .collect();
            let rep = &p.instances[p.graph.nodes[u.nodes[0]].rep];
            let level_costs: Vec<f64> =
                rep.tasks.iter().map(|t| model.cost_of(&t.name)).collect();
            let all: Vec<usize> = (0..stages.len()).collect();
            let serial = crate::merging::weighted_tasks(&stages, &all, &level_costs);
            assert!((mk - serial).abs() < 1e-6, "{mk} vs {serial}");
        }
    }

    #[test]
    fn profile_prioritizes_wavefront_tasks() {
        let d = DeviceModel::paper_profile(8, 2);
        assert!(d.speedup_of("t6") > d.speedup_of("t4"));
        assert!(d.speedup_of("t2") > d.speedup_of("t1"));
        assert_eq!(d.speedup_of("unknown"), 1.0);
    }
}
