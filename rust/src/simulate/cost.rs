//! Per-task cost model (paper Table 6).

use std::collections::HashMap;

use crate::jsonx::{obj, Json};
use crate::runtime::TaskTimer;
use crate::{Error, Result};

/// Mean execution cost (seconds) per fine-grain task name, with an
/// optional multiplicative variance for imbalance source (iii) of
/// paper §4.5.1 (same task, different input → different cost).
#[derive(Clone, Debug)]
pub struct CostModel {
    costs: HashMap<String, f64>,
    /// Fallback for task names without a measurement.
    pub default_cost: f64,
}

impl CostModel {
    pub fn new(costs: HashMap<String, f64>, default_cost: f64) -> Self {
        Self { costs, default_cost }
    }

    /// Mean cost of one execution of `task`.
    pub fn cost_of(&self, task: &str) -> f64 {
        self.costs.get(task).copied().unwrap_or(self.default_cost)
    }

    /// Build from real measurements (`rtf-reuse profile-tasks`); the
    /// tuning objective layer ([`crate::tune::Objective`]) prices
    /// candidate task chains with the resulting model.
    ///
    /// ```
    /// use std::time::Duration;
    ///
    /// use rtf_reuse::runtime::TaskTimer;
    /// use rtf_reuse::simulate::CostModel;
    ///
    /// let mut timer = TaskTimer::with_tasks(vec!["t1".into()]);
    /// timer.record(0, false, Duration::from_millis(200));
    /// timer.record(0, false, Duration::from_millis(400));
    /// let model = CostModel::from_timer(&timer);
    /// assert!((model.cost_of("t1") - 0.3).abs() < 1e-9);
    /// assert_eq!(model.cost_of("unmeasured"), model.default_cost);
    /// ```
    pub fn from_timer(timer: &TaskTimer) -> Self {
        let mut costs = HashMap::new();
        for (name, mean, _) in timer.summary() {
            costs.insert(name, mean);
        }
        let default_cost = if costs.is_empty() {
            1.0
        } else {
            costs.values().sum::<f64>() / costs.len() as f64
        };
        Self { costs, default_cost }
    }

    /// All (task, cost) rows sorted by task name (Table-6 report).
    pub fn rows(&self) -> Vec<(String, f64)> {
        let mut rows: Vec<(String, f64)> =
            self.costs.iter().map(|(k, v)| (k.clone(), *v)).collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    /// Total cost of one full stage execution (sum over tasks).
    pub fn total(&self) -> f64 {
        self.costs.values().sum()
    }

    /// Serialize as JSON (persisted in `assets/task_costs.json`).
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows()
            .into_iter()
            .map(|(name, cost)| {
                obj(vec![("task", Json::Str(name)), ("mean_secs", Json::Num(cost))])
            })
            .collect();
        obj(vec![
            ("default_secs", Json::Num(self.default_cost)),
            ("tasks", Json::Arr(rows)),
        ])
    }

    /// Parse the JSON produced by [`CostModel::to_json`].
    pub fn from_json(v: &Json) -> Result<Self> {
        let default_cost = v
            .get("default_secs")
            .and_then(Json::as_f64)
            .ok_or_else(|| Error::Json("cost model: missing `default_secs`".into()))?;
        let mut costs = HashMap::new();
        for row in v
            .get("tasks")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Json("cost model: missing `tasks`".into()))?
        {
            let name = row
                .get("task")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::Json("cost row: missing `task`".into()))?;
            let cost = row
                .get("mean_secs")
                .and_then(Json::as_f64)
                .ok_or_else(|| Error::Json("cost row: missing `mean_secs`".into()))?;
            costs.insert(name.to_string(), cost);
        }
        Ok(Self { costs, default_cost })
    }
}

/// The paper's empirical task costs (Table 6: t1 1.14 s … t7 0.86 s,
/// Σ = 9.51 s) plus modest normalization/comparison costs, used whenever
/// no measured model is supplied.
pub fn default_cost_model() -> CostModel {
    let mut costs = HashMap::new();
    for (name, cost) in [
        ("norm", 0.48),
        ("t1", 1.14),
        ("t2", 1.99),
        ("t3", 0.65),
        ("t4", 0.33),
        ("t5", 0.76),
        ("t6", 3.76),
        ("t7", 0.86),
        ("cmp", 0.21),
    ] {
        costs.insert(name.to_string(), cost);
    }
    CostModel::new(costs, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_costs() {
        let m = default_cost_model();
        assert_eq!(m.cost_of("t6"), 3.76);
        assert_eq!(m.cost_of("t4"), 0.33);
        // paper Table 6 prints a 9.51 s total, but its per-task values
        // sum to 9.49 s — we use the per-task values as ground truth
        let seg: f64 = (1..=7).map(|i| m.cost_of(&format!("t{i}"))).sum();
        assert!((seg - 9.49).abs() < 1e-9, "{seg}");
        // t6 is ~39.6% of a stage (paper: 39.59%)
        assert!((m.cost_of("t6") / seg - 0.3959).abs() < 0.01);
    }

    #[test]
    fn unknown_task_uses_default() {
        let m = default_cost_model();
        assert_eq!(m.cost_of("no-such-task"), 1.0);
    }

    #[test]
    fn json_round_trip() {
        let m = default_cost_model();
        let j = m.to_json();
        let text = j.to_string_pretty();
        let back = CostModel::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.rows(), m.rows());
        assert_eq!(back.default_cost, m.default_cost);
    }

    #[test]
    fn from_timer_means() {
        use std::time::Duration;
        let mut t = TaskTimer::with_tasks(vec!["t1".into(), "t2".into()]);
        t.record(0, false, Duration::from_millis(100));
        t.record(0, false, Duration::from_millis(300));
        t.record(1, false, Duration::from_millis(50));
        let m = CostModel::from_timer(&t);
        assert!((m.cost_of("t1") - 0.2).abs() < 1e-9);
        assert!((m.cost_of("t2") - 0.05).abs() < 1e-9);
    }
}
