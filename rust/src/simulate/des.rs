//! The discrete-event simulation of a study plan on a worker cluster.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::VecDeque;

use crate::data::SplitMix64;
use crate::merging::{ScheduleUnit, StudyPlan};
use crate::merging::reuse_tree::ReuseTree;
use crate::merging::{CompactGraph, MergeStage};
use crate::simulate::CostModel;
use crate::workflow::StageInstance;

/// Simulation knobs.
#[derive(Clone, Copy, Debug)]
pub struct SimOptions {
    /// Worker-process count (the paper's WP).
    pub workers: usize,
    /// Cores per worker node: a unit's reuse-tree tasks are scheduled
    /// across these, the RTF's fine-grain task scheduling (paper Fig. 4;
    /// Stampede nodes expose 16 cores). 1 = serial stage execution.
    pub cores: usize,
    /// Coefficient of variation of per-task-execution cost, modelling
    /// imbalance source (iii) of §4.5.1 (same task, variable cost over
    /// different inputs). 0 = deterministic costs.
    pub cost_cv: f64,
    /// Seed for the cost jitter.
    pub seed: u64,
    /// Frontier batch width the simulated workers execute with (≥ 1): a
    /// unit's per-level task nodes run in `ceil(n / width)` batched
    /// launches, each paying `launch_overhead` once.
    pub batch_width: usize,
    /// Fixed per-launch overhead in seconds. 0 (the default) restores
    /// the pre-batching cost model exactly.
    pub launch_overhead: f64,
}

impl SimOptions {
    pub fn new(workers: usize) -> Self {
        Self { workers, cores: 1, cost_cv: 0.0, seed: 0, batch_width: 1, launch_overhead: 0.0 }
    }

    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores.max(1);
        self
    }

    pub fn with_cv(mut self, cv: f64, seed: u64) -> Self {
        self.cost_cv = cv;
        self.seed = seed;
        self
    }

    /// Model frontier batching: `width`-wide launches, each charging
    /// `launch_overhead` seconds once — the `launch + B·marginal` model
    /// of [`crate::merging::batched_unit_cost`]. Unit durations (and
    /// therefore the LPT dispatch order of the simulation) then price
    /// batched cost, not task count.
    pub fn with_batch(mut self, width: usize, launch_overhead: f64) -> Self {
        self.batch_width = width.max(1);
        self.launch_overhead = launch_overhead.max(0.0);
        self
    }
}

/// Outcome of one simulated study execution.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Simulated wall time to drain the plan (seconds).
    pub makespan: f64,
    /// Busy seconds per worker.
    pub worker_busy: Vec<f64>,
    /// Units executed.
    pub units: usize,
    /// Fine-grain task executions performed.
    pub tasks: usize,
    /// Σ of all unit durations (serial work).
    pub total_work: f64,
}

impl SimReport {
    /// Mean worker utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.makespan <= 0.0 || self.worker_busy.is_empty() {
            return 0.0;
        }
        self.worker_busy.iter().sum::<f64>()
            / (self.makespan * self.worker_busy.len() as f64)
    }

    /// Speedup of this report over `base` (same plan semantics assumed).
    pub fn speedup_over(&self, base: &SimReport) -> f64 {
        base.makespan / self.makespan
    }

    /// Parallel efficiency of this run vs. a run on `other` with
    /// `factor`× fewer workers (paper Fig. 23: consecutive WP doublings
    /// ⇒ factor 2).
    pub fn parallel_efficiency(&self, prev: &SimReport, factor: f64) -> f64 {
        prev.makespan / (self.makespan * factor)
    }
}

/// Duration of one schedule unit: the bucket's reuse tree is scheduled
/// over the worker's cores (task nodes depend on their tree parent —
/// the RTF's per-node fine-grain task scheduling, paper Fig. 4). With
/// one core this degenerates to the sum of unique task costs.
fn unit_duration(
    unit: &ScheduleUnit,
    graph: &CompactGraph,
    instances: &[StageInstance],
    model: &CostModel,
    opts: &SimOptions,
    tasks_out: &mut usize,
) -> f64 {
    let stages: Vec<MergeStage> = unit
        .nodes
        .iter()
        .map(|&n| MergeStage::new(n, instances[graph.nodes[n].rep].task_path()))
        .collect();
    let rep = &instances[graph.nodes[unit.nodes[0]].rep];
    let tree = ReuseTree::build(&stages);

    // per-task-node cost (leaves and root carry no work)
    let mut cost = vec![0.0f64; tree.nodes.len()];
    for (id, node) in tree.nodes.iter().enumerate() {
        if id == tree.root || node.is_leaf() {
            continue;
        }
        let name = &rep.tasks[node.level - 1].name;
        let mut c = model.cost_of(name);
        if opts.cost_cv > 0.0 {
            let mut rng =
                SplitMix64::new(opts.seed ^ node.sig ^ ((node.level as u64) << 32));
            c *= (1.0 + opts.cost_cv * rng.normal()).max(0.05);
        }
        cost[id] = c;
        *tasks_out += 1;
    }

    // list-schedule the tree on `cores` respecting parent dependencies
    let is_task = |id: usize| id != tree.root && !tree.nodes[id].is_leaf();
    let mut ready: VecDeque<usize> = tree.nodes[tree.root]
        .children
        .iter()
        .copied()
        .filter(|&c| is_task(c))
        .collect();
    let mut events: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let to_ns = |s: f64| (s * 1e9).round() as u64;
    let mut idle = opts.cores;
    let mut now = 0.0f64;
    let mut done = 0usize;
    let n_tasks = (0..tree.nodes.len()).filter(|&id| is_task(id)).count();
    while done < n_tasks {
        while idle > 0 && !ready.is_empty() {
            let t = ready.pop_front().unwrap();
            idle -= 1;
            events.push(Reverse((to_ns(now + cost[t]), t)));
        }
        let Some(Reverse((t_ns, t))) = events.pop() else {
            unreachable!("tree schedule stalled");
        };
        now = t_ns as f64 / 1e9;
        idle += 1;
        done += 1;
        for &c in &tree.nodes[t].children {
            if is_task(c) {
                ready.push_back(c);
            }
        }
    }
    if opts.launch_overhead > 0.0 {
        // frontier batching: the unit's tree levels execute in
        // width-sized cohorts, one fixed launch charge each — the same
        // launch + B·marginal pricing LPT dispatch orders units by
        // (`merging::unit_launch_count` semantics, counted on the tree
        // this function already built; empty task paths cost 1 launch)
        let launches: usize = if stages.first().map(|s| s.path.is_empty()).unwrap_or(true) {
            1
        } else {
            tree.walk()
                .iter()
                .map(|level| {
                    let tasks = level.iter().filter(|n| n.stage.is_none()).count();
                    tasks.div_ceil(opts.batch_width)
                })
                .sum()
        };
        now += launches as f64 * opts.launch_overhead;
    }
    now
}

/// Run the demand-driven list-scheduling simulation: whenever a worker is
/// idle and a unit is ready (all deps complete), the unit starts; units
/// become ready the instant their last dependency finishes. Among ready
/// units the manager dispatches the *costliest first* (LPT) — merged
/// buckets are longer than singleton stages, and largest-first dispatch
/// keeps them off the straggler tail at low units-per-worker ratios
/// (without it, FIFO order can push TRTMA below NR at WP 256, which
/// contradicts the paper's Table 5).
pub fn simulate_plan(
    plan: &StudyPlan,
    graph: &CompactGraph,
    instances: &[StageInstance],
    model: &CostModel,
    opts: &SimOptions,
) -> SimReport {
    assert!(opts.workers >= 1);
    let n = plan.units.len();
    let mut tasks = 0usize;
    let durations: Vec<f64> = plan
        .units
        .iter()
        .map(|u| unit_duration(u, graph, instances, model, opts, &mut tasks))
        .collect();
    let total_work: f64 = durations.iter().sum();

    // dependency bookkeeping
    let mut indeg: Vec<usize> = plan.units.iter().map(|u| u.deps.len()).collect();
    let mut children: Vec<Vec<usize>> = vec![Vec::new(); n];
    for u in &plan.units {
        for &d in &u.deps {
            children[d].push(u.id);
        }
    }

    let to_ns = |s: f64| (s * 1e9).round() as u64;
    // ready units ordered costliest-first (ties by unit id for
    // determinism)
    let mut ready: BinaryHeap<(u64, std::cmp::Reverse<usize>)> = (0..n)
        .filter(|&i| indeg[i] == 0)
        .map(|i| (to_ns(durations[i]), std::cmp::Reverse(i)))
        .collect();
    // idle workers (ids) and the completion event queue
    let mut idle: Vec<usize> = (0..opts.workers).collect();
    // event tuples are (finish_ns, unit, worker)
    let mut events: BinaryHeap<Reverse<(u64, usize, usize)>> = BinaryHeap::new();

    let mut worker_busy = vec![0.0f64; opts.workers];
    let mut now = 0.0f64;
    let mut done = 0usize;

    while done < n {
        // start everything startable
        while !ready.is_empty() && !idle.is_empty() {
            let (_, std::cmp::Reverse(u)) = ready.pop().unwrap();
            let w = idle.pop().unwrap();
            let dur = durations[u];
            worker_busy[w] += dur;
            events.push(Reverse((to_ns(now + dur), u, w)));
        }
        // advance to the next completion
        let Some(Reverse((t_ns, u, w))) = events.pop() else {
            panic!("deadlock: {} of {} units stuck (cyclic deps?)", n - done, n);
        };
        now = t_ns as f64 / 1e9;
        idle.push(w);
        done += 1;
        for &c in &children[u] {
            indeg[c] -= 1;
            if indeg[c] == 0 {
                ready.push((to_ns(durations[c]), std::cmp::Reverse(c)));
            }
        }
    }

    SimReport { makespan: now, worker_busy, units: n, tasks, total_work }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::merging::{plan_study, FineAlgorithm};
    use crate::sampling::default_space;
    use crate::simulate::default_cost_model;
    use crate::workflow::{instantiate_study, paper_workflow, Evaluation};

    fn study(n: usize, vary: impl Fn(usize, &mut Vec<f64>)) -> (CompactGraph, Vec<StageInstance>) {
        let wf = paper_workflow();
        let space = default_space();
        let evals: Vec<Evaluation> = (0..n)
            .map(|id| {
                let mut params = space.defaults();
                vary(id, &mut params);
                Evaluation { id, tile: 0, params }
            })
            .collect();
        let insts = instantiate_study(&wf, &evals);
        (CompactGraph::build(&insts, true), insts)
    }

    #[test]
    fn single_worker_makespan_is_total_work() {
        let (g, insts) = study(6, |id, p| p[5] = 5.0 * (id + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(3));
        let model = default_cost_model();
        let r = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(1));
        assert!((r.makespan - r.total_work).abs() < 1e-6);
        assert!((r.utilization() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn more_workers_never_slower() {
        let (g, insts) = study(24, |id, p| {
            p[5] = 5.0 * (id % 6 + 1) as f64;
            p[9] = 5.0 * (id % 4 + 1) as f64;
        });
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(4));
        let model = default_cost_model();
        let mut last = f64::INFINITY;
        for wp in [1usize, 2, 4, 8, 16] {
            let r = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(wp));
            assert!(r.makespan <= last + 1e-9, "wp={wp}: {} > {last}", r.makespan);
            last = r.makespan;
        }
    }

    #[test]
    fn reuse_reduces_simulated_makespan() {
        let (g, insts) = study(30, |id, p| p[9] = 5.0 * (id % 16 + 1) as f64);
        let model = default_cost_model();
        let nr = plan_study(&g, &insts, FineAlgorithm::None);
        let rt = plan_study(&g, &insts, FineAlgorithm::Rtma(7));
        // worker nodes expose cores: merged buckets fan their reuse-tree
        // branches across them (paper Fig. 4)
        let opts = SimOptions::new(4).with_cores(8);
        let r_nr = simulate_plan(&nr, &g, &insts, &model, &opts);
        let r_rt = simulate_plan(&rt, &g, &insts, &model, &opts);
        assert!(
            r_rt.makespan < r_nr.makespan,
            "rtma {} vs nr {}",
            r_rt.makespan,
            r_nr.makespan
        );
        assert!(r_rt.speedup_over(&r_nr) > 1.0);
    }

    #[test]
    fn task_count_matches_plan() {
        let (g, insts) = study(10, |id, p| p[5] = 5.0 * (id % 5 + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(5));
        let model = default_cost_model();
        let r = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(2));
        assert_eq!(r.tasks, plan.tasks_to_execute());
        assert_eq!(r.units, plan.units.len());
    }

    #[test]
    fn excess_merging_hurts_at_high_worker_counts() {
        // the paper's core scalability finding (Fig 22): with few buckets
        // and many workers, RTMA's reduced parallelism wastes resources
        let (g, insts) = study(64, |id, p| {
            p[9] = 5.0 * (id % 16 + 1) as f64;
            p[10] = 2.0 * (id % 4 + 1) as f64;
        });
        let model = default_cost_model();
        let nr = plan_study(&g, &insts, FineAlgorithm::None);
        let rt = plan_study(&g, &insts, FineAlgorithm::Rtma(64));
        let wp = 48;
        let r_nr = simulate_plan(&nr, &g, &insts, &model, &SimOptions::new(wp));
        let r_rt = simulate_plan(&rt, &g, &insts, &model, &SimOptions::new(wp));
        // massive merging - few big buckets - worse makespan than NR
        assert!(
            r_rt.makespan > r_nr.makespan,
            "over-merged rtma {} should lose to nr {} at wp={wp}",
            r_rt.makespan,
            r_nr.makespan
        );
    }

    #[test]
    fn launch_overhead_prices_batching() {
        let (g, insts) = study(12, |id, p| p[5] = 5.0 * (id % 6 + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(4));
        let model = default_cost_model();
        let base = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(1));
        let narrow =
            simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(1).with_batch(1, 0.05));
        let wide =
            simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(1).with_batch(16, 0.05));
        // overhead costs something, wider batches amortize it away
        assert!(narrow.makespan > base.makespan);
        assert!(
            wide.makespan < narrow.makespan,
            "wide {} narrow {}",
            wide.makespan,
            narrow.makespan
        );
        assert!(wide.makespan >= base.makespan);
        // the default options reproduce the pre-batching model exactly
        let default_again = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(1));
        assert_eq!(base.makespan, default_again.makespan);
    }

    #[test]
    fn jitter_changes_makespan_deterministically() {
        let (g, insts) = study(12, |id, p| p[5] = 5.0 * (id % 6 + 1) as f64);
        let plan = plan_study(&g, &insts, FineAlgorithm::Rtma(4));
        let model = default_cost_model();
        let a = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(4).with_cv(0.3, 7));
        let b = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(4).with_cv(0.3, 7));
        let c = simulate_plan(&plan, &g, &insts, &model, &SimOptions::new(4).with_cv(0.3, 8));
        assert_eq!(a.makespan, b.makespan);
        assert_ne!(a.makespan, c.makespan);
    }
}
